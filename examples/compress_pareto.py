"""Pareto sweep (paper Fig. 4/6) on the repro.sweep orchestrator: run
the joint search at several regularization strengths with warm-start
continuation, persist every point into a durable PlanStore, print the
accuracy-vs-cost front, and export the best model's deployment plan
(Fig. 3 reordering + per-precision sub-layers + NE16 refinement)
straight from its stored CompressionPlan.

Because the points live in a PlanStore, the sweep is resumable (rerun
the same command after a kill and finished points load instead of
retraining) and the store serves directly:

    PYTHONPATH=src python examples/compress_pareto.py --bench gsc \
        --store pareto_store
    # then, for an lm-track store:  python -m repro.launch.fleet \
    #     --tiers store:pareto_store

Also demonstrates registering a custom cost model by name: pass
``--cost sram4k`` to optimize a size model that prices every byte of a
layer beyond a 4 kB per-layer SRAM tile 8x higher.
"""
import argparse
import os
import tempfile

import jax.numpy as jnp
import numpy as np

from repro import api, sweep
from repro.core import costs, discretize
from repro.models import cnn


class SramTileCost:
    """Custom hardware model: layer bytes with an 8x penalty on every byte
    past a 4 kB per-layer SRAM tile (both faces return bytes).

    Registered by name below -- the search picks it up through the cost
    registry without any change to repro.core.
    """

    name = "sram4k"
    tile_bytes = 4 * 1024

    def expected(self, geom, gammas, deltas, pw, px, ctx):
        b = costs.size_cost(geom, gammas, deltas, pw, px, ctx)
        return b + 8.0 * jnp.maximum(b - self.tile_bytes, 0.0)

    def discrete(self, geom, channel_bits, cin_eff, act_bits=8):
        b = costs.size_bytes_discrete(geom, channel_bits, cin_eff)
        return b + 8.0 * max(b - self.tile_bytes, 0.0)


api.register_cost_model(SramTileCost())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="gsc",
                    choices=list(sweep.available_benches()))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--cost", default="size",
                    choices=list(api.available_cost_models()))
    ap.add_argument("--lams", default="2,8,20")
    ap.add_argument("--adaptive", type=int, default=0,
                    help="extra bisection points in the largest front "
                         "gaps")
    ap.add_argument("--cold", action="store_true",
                    help="restart every point from scratch instead of "
                         "warm-start continuation")
    ap.add_argument("--store", default=None,
                    help="PlanStore directory (default: a temp dir; "
                         "pass a path to make the sweep resumable)")
    args = ap.parse_args()

    root = args.store or tempfile.mkdtemp(prefix="pareto_")
    spec = sweep.SweepSpec(
        name="pareto", track="cnn", bench=args.bench,
        cost_model=args.cost,
        lams=tuple(float(x) for x in args.lams.split(",")),
        adaptive_points=args.adaptive, warm_start=not args.cold,
        warmup_steps=args.steps, search_steps=args.steps,
        finetune_steps=args.steps // 2, batch=32)
    store = sweep.PlanStore(os.path.join(root, "store"))
    runner = sweep.SweepRunner(spec, store,
                               os.path.join(root, "work"))
    summary = runner.run()
    print(f"\n{summary['executed']} points trained, "
          f"{summary['loaded']} loaded from {store.root}, "
          f"{summary['steps_saved']} steps saved by warm starts")

    front = store.front(store.query(kind="point", sweep=spec.name),
                        cost_key=args.cost)
    print("accuracy-vs-cost front (cost ascending):")
    for e in front:
        m, lin = e["metrics"], e["lineage"]
        print(f"  lambda={lin['lam']:6.1f}: acc={m['score']:.3f} "
              f"cost={e['costs'][args.cost]/1024:7.2f} kB "
              f"pruned={100*m['prune_fraction']:4.1f}%  "
              f"[{e['name']} @ {e['plan'][:12]}]")

    # export the most accurate front point's deployment plan, reloaded
    # from the content-addressed store (provenance round-trip)
    best = max(front, key=lambda e: (e["metrics"]["score"],
                                     -e["costs"][args.cost]))
    plan = store.get(best["plan"])
    print(f"\ndeployment plan of {best['name']} (Fig. 3: per-precision "
          "sub-layers after channel reordering):")
    for grp, segs in plan.sublayer_split().items():
        desc = ", ".join(f"{b}-bit x{stop-start}" for b, start, stop in segs)
        print(f"  {grp:6s} -> [{desc}]")
    g, _ = sweep.runner._BENCHES[args.bench](spec.width)
    geoms = cnn.cost_geoms(g)
    refined, promoted = discretize.ne16_refine(
        geoms, {"gamma": {k: np.asarray(v)
                          for k, v in plan.channel_bits.items()},
                "delta": plan.act_bits, "alpha": plan.alphas})
    print(f"\nNE16 post-search refinement promoted {promoted} channels "
          f"(32-lane alignment)")


if __name__ == "__main__":
    main()
