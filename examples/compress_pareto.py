"""Pareto sweep (paper Fig. 4/6) on the composable API: run the joint
search at several regularization strengths, print the accuracy-vs-cost
front, and export the best model's deployment plan (Fig. 3 reordering +
per-precision sub-layers + NE16 refinement) straight from its
CompressionPlan.

Also demonstrates registering a custom cost model by name: pass
``--cost sram4k`` to optimize a size model that prices every byte of a
layer beyond a 4 kB per-layer SRAM tile 8x higher.

    PYTHONPATH=src python examples/compress_pareto.py --bench gsc
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import costs, discretize
from repro.data import synthetic
from repro.models import cnn

BENCH = {"cifar10": (cnn.resnet9, synthetic.CIFAR10_LIKE),
         "gsc": (cnn.dscnn, synthetic.GSC_LIKE)}


class SramTileCost:
    """Custom hardware model: layer bytes with an 8x penalty on every byte
    past a 4 kB per-layer SRAM tile (both faces return bytes).

    Registered by name below -- the search picks it up through the cost
    registry without any change to repro.core.
    """

    name = "sram4k"
    tile_bytes = 4 * 1024

    def expected(self, geom, gammas, deltas, pw, px, ctx):
        b = costs.size_cost(geom, gammas, deltas, pw, px, ctx)
        return b + 8.0 * jnp.maximum(b - self.tile_bytes, 0.0)

    def discrete(self, geom, channel_bits, cin_eff, act_bits=8):
        b = costs.size_bytes_discrete(geom, channel_bits, cin_eff)
        return b + 8.0 * max(b - self.tile_bytes, 0.0)


api.register_cost_model(SramTileCost())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="gsc", choices=list(BENCH))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--cost", default="size",
                    choices=list(api.available_cost_models()))
    ap.add_argument("--lams", default="2,8,20")
    args = ap.parse_args()
    builder, spec = BENCH[args.bench]
    g = builder(width=8)
    geoms = cnn.cost_geoms(g)
    comp = api.Compressor(g, spec, pw=(0, 2, 4, 8), px=(8,), batch=32)

    front = []
    for lam in [float(x) for x in args.lams.split(",")]:
        res = comp.run([
            api.Warmup(steps=args.steps),
            api.JointSearch(steps=args.steps, lam=lam,
                            cost_model=args.cost,
                            ne16_refine=(args.cost == "ne16")),
            api.Finetune(steps=args.steps // 2)])
        front.append((lam, res))
        print(f"lambda={lam:6.1f}: acc={res.acc_final:.3f} "
              f"size={res.size_bytes/1024:7.2f} kB "
              f"pruned={100*res.prune_fraction:4.1f}%")

    # export the most accurate compressed model's deployment plan
    best = max(front, key=lambda t: (t[1].acc_final, -t[1].size_bytes))[1]
    plan = best.plan
    print("\ndeployment plan (Fig. 3: per-precision sub-layers after "
          "channel reordering):")
    for grp, segs in plan.sublayer_split().items():
        desc = ", ".join(f"{b}-bit x{stop-start}" for b, start, stop in segs)
        print(f"  {grp:6s} -> [{desc}]")
    refined, promoted = discretize.ne16_refine(
        geoms, {"gamma": {k: np.asarray(v)
                          for k, v in plan.channel_bits.items()},
                "delta": plan.act_bits, "alpha": plan.alphas})
    print(f"\nNE16 post-search refinement promoted {promoted} channels "
          f"(32-lane alignment)")


if __name__ == "__main__":
    main()
