"""Pareto sweep (paper Fig. 4/6): run the joint search at several
regularization strengths and cost models, print the accuracy-vs-cost front,
and export the best model's mixed-precision deployment plan (Fig. 3
reordering + per-precision sub-layers + NE16 refinement).

    PYTHONPATH=src python examples/compress_pareto.py --bench gsc
"""
import argparse

import numpy as np

from repro.core import costs, discretize, pipeline
from repro.data import synthetic
from repro.models import cnn

BENCH = {"cifar10": (cnn.resnet9, synthetic.CIFAR10_LIKE),
         "gsc": (cnn.dscnn, synthetic.GSC_LIKE)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="gsc", choices=list(BENCH))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--cost", default="size")
    ap.add_argument("--lams", default="2,8,20")
    args = ap.parse_args()
    builder, spec = BENCH[args.bench]
    g = builder(width=8)
    geoms = cnn.cost_geoms(g)

    front = []
    for lam in [float(x) for x in args.lams.split(",")]:
        cfg = pipeline.SearchConfig(
            warmup_steps=args.steps, search_steps=args.steps,
            finetune_steps=args.steps // 2, batch=32, lam=lam,
            cost_model=args.cost, ne16_refine=(args.cost == "ne16"))
        res = pipeline.run_pipeline(g, spec, cfg)
        front.append((lam, res))
        print(f"lambda={lam:6.1f}: acc={res['acc_final']:.3f} "
              f"size={res['size_bytes']/1024:7.2f} kB "
              f"pruned={100*res['prune_fraction']:4.1f}%")

    # export the most accurate compressed model's deployment plan
    best = max(front, key=lambda t: (t[1]["acc_final"],
                                     -t[1]["size_bytes"]))[1]
    assign = best["assignment"]
    split = discretize.sublayer_split(assign, (0, 2, 4, 8))
    print("\ndeployment plan (Fig. 3: per-precision sub-layers after "
          "channel reordering):")
    for grp, segs in split.items():
        desc = ", ".join(f"{b}-bit x{stop-start}" for b, start, stop in segs)
        print(f"  {grp:6s} -> [{desc}]")
    refined, promoted = discretize.ne16_refine(geoms, {
        "gamma": {k: np.asarray(v) for k, v in assign["gamma"].items()},
        "delta": assign["delta"], "alpha": assign["alpha"]})
    print(f"\nNE16 post-search refinement promoted {promoted} channels "
          f"(32-lane alignment)")


if __name__ == "__main__":
    main()
