"""End-to-end LM training driver: train a ~100M-param llama-style model for
a few hundred steps on synthetic token data, with fault-tolerant
checkpointing (atomic, auto-resume) and optional joint MPS+pruning search.

    PYTHONPATH=src python examples/train_lm.py --steps 200          # tiny
    PYTHONPATH=src python examples/train_lm.py --full --steps 300   # ~100M
    # kill it mid-run and re-run: it resumes from the last checkpoint
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig
from repro.core import mps
from repro.data import synthetic
from repro.models import lm
from repro.optim import grad as gradlib
from repro.optim import optimizers, schedules

TINY = ArchConfig(name="lm-tiny", family="dense", n_layers=4, d_model=256,
                  n_heads=4, n_kv_heads=2, d_ff=1024, vocab=2048,
                  head_dim=64, remat=False)
FULL_100M = ArchConfig(name="lm-100m", family="dense", n_layers=12,
                       d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                       vocab=32000, head_dim=64, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true", help="~100M params")
    ap.add_argument("--search", action="store_true",
                    help="joint MPS+pruning search (the paper's technique)")
    ap.add_argument("--lam", type=float, default=1e-8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = FULL_100M if args.full else TINY
    params = lm.init_params(cfg, jax.random.key(0), mps_on=args.search)
    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"model: {cfg.name}, {n_params/1e6:.1f}M params, "
          f"search={'on' if args.search else 'off'}")

    lr = schedules.cosine(3e-4, args.steps, warmup_steps=args.steps // 20)
    opt = optimizers.adam(lr)
    opt_state = opt.init(params)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    state = {"params": params, "opt": opt_state}
    restored, meta = mgr.restore_latest(state)
    start = 0
    if restored is not None:
        state = restored
        start = meta["step"] + 1
        print(f"resumed from checkpoint at step {meta['step']}")

    @jax.jit
    def train_step(state, step):
        batch = synthetic.lm_batch(cfg.vocab, args.seq + 1, args.batch,
                                   step)

        def loss_fn(p):
            ctx = mps.SearchCtx(tau=1.0) if args.search else None
            return lm.loss_fn(cfg, p, batch, ctx=ctx,
                              lam=args.lam if args.search else 0.0)

        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        grads, gnorm = gradlib.clip_by_global_norm(grads, 1.0)
        new_params, new_opt = opt.update(grads, state["opt"],
                                         state["params"], step)
        return {"params": new_params, "opt": new_opt}, loss, gnorm

    t0 = time.time()
    for step in range(start, args.steps):
        state, loss, gnorm = train_step(state, step)
        if step % 20 == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq * max(step - start, 1) \
                / max(time.time() - t0, 1e-9)
            print(f"step {step:5d} loss {float(loss):7.4f} "
                  f"gnorm {float(gnorm):6.2f} tok/s {tok_s:7.0f}")
        if step % args.ckpt_every == 0 and step > start:
            mgr.save(step, state, blocking=False)
    mgr.wait()
    mgr.save(args.steps - 1, state)
    print(f"done in {time.time()-t0:.1f}s; final loss {float(loss):.4f} "
          f"(uniform = {jnp.log(cfg.vocab):.2f})")
    if args.search:
        ctx = mps.SearchCtx(tau=0.02)
        size = float(lm.mps_size_cost(cfg, state["params"], ctx))
        print(f"expected compressed weight size: {size/1e6:.1f} MB")


if __name__ == "__main__":
    main()
