"""Quickstart on the composable Compressor API: joint pruning +
channel-wise mixed-precision search on the paper's CIFAR-10 reference
ResNet (synthetic data stand-in), end to end:

  Warmup -> JointSearch -> Finetune  ==>  CompressionPlan

then the plan round-trips through save/load and drives the quantized
serving export -- the loaded plan packs byte-identical layers.

    PYTHONPATH=src python examples/quickstart.py [--steps 150] [--lam 10]
"""
import argparse
import os
import tempfile

import jax
import numpy as np

from repro import api
from repro.data import synthetic
from repro.models import cnn
from repro.serve import engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--lam", type=float, default=10.0,
                    help="regularization strength (normalized cost)")
    ap.add_argument("--width", type=int, default=8,
                    help="16 = the paper's full ResNet-9")
    ap.add_argument("--cost", default="size",
                    choices=list(api.available_cost_models()))
    args = ap.parse_args()

    g = cnn.resnet9(width=args.width)
    print(f"ResNet-9 (width {args.width}) | cost model: {args.cost} | "
          f"lambda {args.lam}")

    # ---- the paper's 3-phase recipe as an explicit phase composition
    comp = api.Compressor(g, synthetic.CIFAR10_LIKE, pw=(0, 2, 4, 8),
                          px=(8,), batch=32, seed=0)
    res = comp.run(
        [api.Warmup(steps=args.steps),
         api.JointSearch(steps=args.steps, lam=args.lam,
                         cost_model=args.cost),
         api.Finetune(steps=args.steps // 2)],
        hooks=[api.MetricsLog(every=100)])
    plan = res.plan

    w8_kb = sum(int(v["w"].size) for v in
                cnn.init_params(g, jax.random.key(0)).values()) / 1024
    print(f"\nfloat accuracy    : {res.acc_float:.3f}")
    print(f"final accuracy    : {res.acc_final:.3f} (discretized + "
          f"fine-tuned)")
    print(f"model size        : {res.size_bytes/1024:.2f} kB "
          f"(w8a8 baseline: {w8_kb:.2f} kB -> "
          f"{100*(1-res.size_bytes/1024/w8_kb):.1f}% smaller)")
    print(f"channels pruned   : {100*res.prune_fraction:.1f}%")
    print("\nper-layer bit-width shares (paper Fig. 7):")
    for grp, h in res.bits_histogram.items():
        shares = " ".join(f"{b}b:{v:.2f}" for b, v in h.items() if v > 0)
        print(f"  {grp:6s} {shares}")

    # ---- the plan is a portable artifact: save -> load -> serve
    stem = os.path.join(tempfile.mkdtemp(prefix="repro_plan_"), "plan")
    npz_path = plan.save(stem)
    loaded = api.CompressionPlan.load(npz_path)
    print(f"\nplan artifact     : {npz_path} (+ .json)")
    print(f"round-trip intact : {plan.equals(loaded)}")
    print(f"provenance        : cost_model={loaded.meta['cost_model']} "
          f"lam={loaded.meta['lam']} sampler={loaded.meta['sampler']}")

    # one representative layer per gamma group, reshaped to (C_out, C_in*k*k)
    weights = {}
    for node in g.weight_nodes():
        grp = node.group()
        if grp not in weights:
            w = np.asarray(res.net[node.name]["w"])
            weights[grp] = w.reshape(w.shape[0], -1)
    packed_mem = engine.export_plan_layers(plan, weights)
    packed_load = engine.export_plan_layers(loaded, weights)
    identical = all(
        len(a) == len(b) and all(
            ba == bb and np.array_equal(wa, wb) and np.array_equal(sa, sb)
            for (ba, wa, sa), (bb, wb, sb) in zip(a, b))
        for (a, _, _), (b, _, _) in
        ((packed_mem[grp], packed_load[grp]) for grp in weights))
    print(f"serving export    : loaded plan packs identically -> "
          f"{identical}")


if __name__ == "__main__":
    main()
