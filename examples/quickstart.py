"""Quickstart: joint pruning + channel-wise mixed-precision search on the
paper's CIFAR-10 reference ResNet (synthetic data stand-in), end to end:
warmup -> search -> discretize -> fine-tune -> report.

    PYTHONPATH=src python examples/quickstart.py [--steps 150] [--lam 10]
"""
import argparse

from repro.core import pipeline
from repro.data import synthetic
from repro.models import cnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--lam", type=float, default=10.0,
                    help="regularization strength (normalized cost)")
    ap.add_argument("--width", type=int, default=8,
                    help="16 = the paper's full ResNet-9")
    ap.add_argument("--cost", default="size",
                    choices=["size", "bitops", "mpic", "ne16", "tpu"])
    args = ap.parse_args()

    g = cnn.resnet9(width=args.width)
    cfg = pipeline.SearchConfig(
        warmup_steps=args.steps, search_steps=args.steps,
        finetune_steps=args.steps // 2, batch=32, lam=args.lam,
        cost_model=args.cost)
    print(f"ResNet-9 (width {args.width}) | cost model: {args.cost} | "
          f"lambda {args.lam}")
    res = pipeline.run_pipeline(g, synthetic.CIFAR10_LIKE, cfg, verbose=True)

    w8_kb = sum(int(v["w"].size) for v in
                cnn.init_params(g, __import__("jax").random.key(0)).values()
                ) / 1024
    print(f"\nfloat accuracy    : {res['acc_float']:.3f}")
    print(f"final accuracy    : {res['acc_final']:.3f} (discretized + "
          f"fine-tuned)")
    print(f"model size        : {res['size_bytes']/1024:.2f} kB "
          f"(w8a8 baseline: {w8_kb:.2f} kB -> "
          f"{100*(1-res['size_bytes']/1024/w8_kb):.1f}% smaller)")
    print(f"channels pruned   : {100*res['prune_fraction']:.1f}%")
    print("\nper-layer bit-width shares (paper Fig. 7):")
    for grp, h in res["bits_histogram"].items():
        shares = " ".join(f"{b}b:{v:.2f}" for b, v in h.items() if v > 0)
        print(f"  {grp:6s} {shares}")


if __name__ == "__main__":
    main()
