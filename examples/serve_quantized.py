"""Serve a small model with batched requests through the mixed-precision
quantized path (paper Fig. 3 / Sec. 4.5): channels reordered into
per-precision groups, weights bit-packed, each group served by the
quant_matmul kernel (int8 MXU on TPU; oracle on CPU).

    PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import lm
from repro.serve import engine


def main():
    # 1) batched LM serving (greedy decode with KV caches)
    cfg = registry.reduced(registry.ARCHS["llama3.2-1b"])
    params = lm.init_params(cfg, jax.random.key(0))
    eng = engine.ServeEngine(cfg, params, max_len=64)
    prompts = np.asarray([[3, 1, 4, 1, 5], [2, 7, 1, 8, 2],
                          [1, 1, 2, 3, 5], [9, 8, 7, 6, 5]], np.int32)
    t0 = time.time()
    out = eng.generate(prompts, n_tokens=12)
    dt = time.time() - t0
    print(f"batched decode: {out.shape[0]} requests x {out.shape[1]} "
          f"tokens in {dt:.2f}s")
    for i, row in enumerate(out):
        print(f"  req{i}: {list(row)}")

    # 2) a mixed-precision layer served through the quantized kernel path
    rng = np.random.default_rng(0)
    w = rng.normal(size=(128, 256)).astype(np.float32) * 0.1
    channel_bits = rng.choice([0, 2, 4, 8], size=128,
                              p=[0.15, 0.2, 0.3, 0.35])
    packed, perm, kept = engine.export_mixed_precision_layer(w, channel_bits)
    x = jnp.asarray(rng.normal(size=(16, 256)).astype(np.float32))
    y = engine.mixed_precision_matmul(x, packed)
    # deployment-consistency reference: the discretized fake-quant layer
    # (what the fine-tuned model actually computes)
    from repro.core import quantizers
    w_perm = w[perm]
    bits_perm = np.asarray(channel_bits)[perm]
    rows = [np.asarray(quantizers.quantize_weights_symmetric(
        jnp.asarray(w_perm[i:i + 1]), int(b), 0))[0]
        for i, b in enumerate(bits_perm) if b > 0]
    ref = x @ jnp.asarray(np.stack(rows)).T
    rel = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
    packed_bytes = sum(int(p[1].size) for p in packed)
    hist = {b: int((np.asarray(channel_bits) == b).sum())
            for b in (0, 2, 4, 8)}
    print(f"\nmixed-precision layer: {kept}/128 channels kept ({hist})")
    print(f"packed weight bytes: {packed_bytes} "
          f"(fp32 baseline: {w.size*4}; "
          f"{w.size*4/packed_bytes:.1f}x smaller)")
    print(f"kernel-vs-fakequant deployment error: {100*rel:.2f}% "
          f"(int8 activation quantization only)")


if __name__ == "__main__":
    main()
