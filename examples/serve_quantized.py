"""Plan-driven quantized serving (paper Fig. 3 / Sec. 4.5).

The full loop the paper implies but never ships: a CompressionPlan (the
artifact ``api.Compressor`` produces) is bound into an LM and served --
continuous batching, fused prefill, per-request sampling -- with every
planned projection running bit-packed through the quant_matmul kernel
(int8 MXU on TPU; oracle on CPU), and the KV cache **paged**: a fixed
page pool + per-request block tables (``cache="paged"``), so the
runtime cache memory scales with live tokens the same way the packed
weights scale with the searched bit-widths.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import lm
from repro.serve import engine
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import Request


def main():
    cfg = registry.get("llama3.2-1b-smoke")
    params = lm.init_params(cfg, jax.random.key(0))

    # 1) a CompressionPlan for the LM's projection groups.  Here: a demo
    # mixed-precision assignment; a searched plan comes out of
    # lm.extract_plan after a make_train_step(search=True) run, or -- on
    # the CNN track -- api.Compressor.run(...).plan.  Plans round-trip
    # through disk, so search and serving can live on different machines.
    plan = engine.synthetic_plan(cfg, params, bits=None, seed=0)
    stem = "/tmp/serve_quantized_plan"
    plan.save(stem)
    from repro.api.plan import CompressionPlan
    loaded = CompressionPlan.load(stem)
    print(f"plan: {loaded.summary()}")

    # 2) per-layer view: plan.bind packs each group's weight (Fig. 3
    # reorder + bit-pack); this is exactly what the server binds inside
    # the forward, so the bytes below are what decode actually reads
    weights = lm.serve_weight_groups(cfg, params)
    packed = loaded.bind(weights)
    packed_bytes = sum(int(w.size) for layers, _, _ in packed.values()
                       for _, w, _ in layers)
    float_bytes = sum(w.size * 4 for w in weights.values())
    print(f"packed projection bytes: {packed_bytes} "
          f"(fp32 baseline {float_bytes}; "
          f"{float_bytes / packed_bytes:.1f}x smaller)")

    # 3) serve through the quantized path WITH a paged KV cache: requests
    # arriving over time, admitted into free decode slots only when the
    # page pool can hold their prompt + a reservation (the memory-aware
    # admission contract), sampled on-device at temperature 0.7
    server = engine.InferenceServer(cfg, params, plan=loaded,
                                    max_len=64, max_batch=2,
                                    cache="paged", page_size=8, pages=12)
    rng = np.random.default_rng(0)
    sp = SamplingParams(temperature=0.7, top_k=40, max_tokens=10, seed=1)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, size=4 + 3 * i
                                        ).astype(np.int32),
                    sampling=sp, arrival=2 * i)
            for i in range(4)]
    t0 = time.time()
    out = server.serve(reqs)
    dt = time.time() - t0
    total = sum(len(v) for v in out.values())
    print(f"\nquantized paged continuous-batching decode: {len(reqs)} "
          f"requests, {total} tokens in {dt:.2f}s "
          f"({server.stats['decode_steps']} decode steps, 2 slots, "
          f"{server.stats['preemptions']} preemptions)")
    for i in range(len(reqs)):
        print(f"  req{i} (arrived step {reqs[i].arrival}): "
              f"{[int(t) for t in out[i]]}")

    # 4) the backend's own accounting: pages in flight peaked well below
    # the dense max_batch*max_len pin, and everything was freed on retire
    mem = server.stats["memory"]
    print(f"\nmemory_report: {mem}")
    print(f"peak cache bytes {mem['peak_cache_bytes']} "
          f"(dense equivalent {mem['dense_equivalent_bytes']}; "
          f"{mem['dense_equivalent_bytes'] / mem['peak_cache_bytes']:.1f}x"
          f" smaller), {mem['pages_in_use']} pages still held")


if __name__ == "__main__":
    main()
