"""Deterministic synthetic datasets.

The container is offline, so the paper's datasets (CIFAR-10, GSC v2,
Tiny ImageNet) are replaced by synthetic sets with the *same tensor shapes
and class cardinalities* and enough structure to be learnable: each class
has a fixed smooth template; samples are template + noise + random shift.
Every batch is a pure function of (seed, step), which makes the input
pipeline stateless and trivially resumable after preemption (fault
tolerance) and identically shardable across hosts.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ClassificationSpec:
    name: str
    shape: tuple[int, int, int]
    num_classes: int
    noise: float = 0.35


CIFAR10_LIKE = ClassificationSpec("cifar10-like", (32, 32, 3), 10)
GSC_LIKE = ClassificationSpec("gsc-like", (49, 10, 1), 12)
TINYIMAGENET_LIKE = ClassificationSpec("tinyimagenet-like", (64, 64, 3), 200)

DATASETS = {"cifar10": CIFAR10_LIKE, "gsc": GSC_LIKE,
            "tinyimagenet": TINYIMAGENET_LIKE}


def _templates(spec: ClassificationSpec) -> jax.Array:
    """Smooth per-class templates, fixed by the dataset name."""
    key = jax.random.key(abs(hash(spec.name)) % (2 ** 31))
    h, w, c = spec.shape
    # low-frequency template: upsampled coarse noise
    coarse = jax.random.normal(key, (spec.num_classes, max(h // 4, 1),
                                     max(w // 4, 1), c))
    t = jax.image.resize(coarse, (spec.num_classes, h, w, c), "linear")
    return t / jnp.maximum(jnp.std(t), 1e-6)


def class_batch(spec: ClassificationSpec, step: int, batch: int,
                seed: int = 0):
    """Pure function (spec, step, batch, seed) -> (x, y)."""
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.key(seed), step), 1)
    ky, kn, ks = jax.random.split(key, 3)
    y = jax.random.randint(ky, (batch,), 0, spec.num_classes)
    temps = _templates(spec)[y]
    noise = spec.noise * jax.random.normal(kn, (batch,) + spec.shape)
    shift = jax.random.randint(ks, (batch,), -2, 3)
    x = temps + noise
    x = jax.vmap(lambda img, s: jnp.roll(img, s, axis=1))(x, shift)
    return x, y


def eval_set(spec: ClassificationSpec, n_batches: int, batch: int,
             seed: int = 10_000):
    return [class_batch(spec, 10_000_000 + i, batch, seed)
            for i in range(n_batches)]


# ---------------------------------------------------------------------------
# LM token stream (for the 100M-scale end-to-end driver)
# ---------------------------------------------------------------------------

def lm_batch(vocab: int, seq_len: int, batch: int, step: int,
             seed: int = 0, structure: float = 0.9):
    """Deterministic learnable token stream.

    Tokens follow a noisy affine recurrence t[i+1] = (a*t[i] + b) % vocab
    with per-sequence (a, b) drawn from a tiny set, so a model can reduce
    loss well below uniform. Returns {"tokens", "targets"} of
    (batch, seq_len) int32.
    """
    key = jax.random.fold_in(jax.random.key(seed), step)
    k0, k1, k2, k3 = jax.random.split(key, 4)
    a = jnp.asarray([3, 5, 7, 11])[jax.random.randint(k0, (batch,), 0, 4)]
    b = jax.random.randint(k1, (batch,), 0, 13)
    t0 = jax.random.randint(k2, (batch,), 0, vocab)

    def step_fn(t, _):
        nxt = (a * t + b) % vocab
        return nxt, nxt

    _, toks = jax.lax.scan(step_fn, t0, None, length=seq_len)
    toks = jnp.swapaxes(toks, 0, 1)                      # (B, S)
    noise_mask = jax.random.bernoulli(k3, 1 - structure, toks.shape)
    noise = jax.random.randint(jax.random.fold_in(k3, 1), toks.shape, 0,
                               vocab)
    toks = jnp.where(noise_mask, noise, toks).astype(jnp.int32)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
