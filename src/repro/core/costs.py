"""Differentiable complexity regularizers (paper Sec. 4.3).

Every cost model consumes the same structural description of the network — a
list of :class:`LayerGeom` records built by the model definition — plus the
current selection parameters, and returns a scalar differentiable cost.

Models:
  * size   (Eq. 9)       -- bytes of weight memory, hardware-agnostic
  * bitops (Sec. 5.5.2)  -- MACs * px * pw, hardware-agnostic latency proxy
  * mpic   (Eq. 10-11)   -- LUT-based cycles on the MPIC RISC-V core
  * ne16   (Sec. 4.3.3)  -- 3-term analytical cycles on the NE16 accelerator
  * tpu    (ours)        -- TPU-v5e roofline latency (max(MXU, HBM) per layer)

``C_in,eff`` (Eq. 9) is the *expected un-pruned* channel count of the
producer layer; pruning an output channel therefore also pays off in every
consumer layer.

Dispatch goes through the pluggable registry in
``repro.api.cost_models``: each model above is registered by name with a
differentiable ``expected`` face (the functions here) and a ``discrete``
face (the ``*_discrete`` functions below) for deployment reporting.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import mps

COST_MODELS = ("size", "bitops", "mpic", "ne16", "tpu")


@dataclasses.dataclass(frozen=True)
class LayerGeom:
    """Static geometry of one quantizable layer (conv or linear)."""
    name: str
    kind: str                      # "conv" | "dwconv" | "linear"
    cin: int
    cout: int
    kx: int = 1
    ky: int = 1
    out_h: int = 1
    out_w: int = 1
    gamma: str = ""                # key of this layer's gamma in the pytree
    in_gamma: Optional[str] = None  # producer's gamma key (for C_in_eff)
    in_delta: Optional[str] = None  # input activation's delta key

    @property
    def macs(self) -> float:
        cin = 1 if self.kind == "dwconv" else self.cin
        return float(self.kx * self.ky * cin * self.cout
                     * self.out_h * self.out_w)

    @property
    def n_weights(self) -> float:
        cin = 1 if self.kind == "dwconv" else self.cin
        return float(self.kx * self.ky * cin * self.cout)


def _ste_ceil(x: jax.Array) -> jax.Array:
    """ceil() with identity gradient (keeps HW-granularity steps in the
    forward cost while remaining trainable)."""
    return x + jax.lax.stop_gradient(jnp.ceil(x) - x)


def _group_count(count: jax.Array, group: float) -> jax.Array:
    """Number of `group`-sized HW channel groups for a soft channel count.
    Counts below half a channel round to zero groups (otherwise every
    precision pays one phantom PE group from numerically-tiny probs)."""
    return _ste_ceil(jnp.maximum(count - 0.5, 0.0) / group)


def _cin_eff(geom: LayerGeom, gammas: dict, pw: tuple[int, ...],
             ctx: mps.SearchCtx) -> jax.Array:
    """Effective (expected non-pruned) input channel count."""
    if geom.kind == "dwconv":
        return jnp.asarray(1.0)
    if geom.in_gamma is None or geom.in_gamma not in gammas:
        return jnp.asarray(float(geom.cin))
    keep = mps.keep_probability(gammas[geom.in_gamma], pw, ctx)
    if keep.shape[0] == 1:      # layer-wise gamma: one row for all channels
        return keep[0] * float(geom.cin)
    return jnp.sum(keep)


def _soft_channel_counts(geom: LayerGeom, gammas: dict,
                         pw: tuple[int, ...], ctx: mps.SearchCtx
                         ) -> jax.Array:
    """Expected number of output channels at each precision: (|P_W|,)."""
    probs = mps.gamma_probs(gammas[geom.gamma], ctx)  # (C_out, |P|)
    if probs.shape[0] == 1:     # layer-wise gamma
        return probs[0] * float(geom.cout)
    return jnp.sum(probs, axis=0)


def _act_probs(geom: LayerGeom, deltas: dict, px: tuple[int, ...],
               ctx: mps.SearchCtx) -> jax.Array:
    if geom.in_delta is None or geom.in_delta not in deltas:
        # fixed 8-bit activations
        one_hot = jnp.asarray([1.0 if p == 8 else 0.0 for p in px])
        if not any(p == 8 for p in px):
            one_hot = jax.nn.one_hot(len(px) - 1, len(px))
        return one_hot
    return mps.delta_probs(deltas[geom.in_delta], ctx)


# --------------------------------------------------------------------------
# size (Eq. 9)
# --------------------------------------------------------------------------

def size_cost(geom: LayerGeom, gammas: dict, deltas: dict,
              pw: tuple[int, ...], px: tuple[int, ...],
              ctx: mps.SearchCtx) -> jax.Array:
    """Expected model size contribution of one layer, in *bytes*."""
    probs = mps.gamma_probs(gammas[geom.gamma], ctx)          # (C, |P|)
    exp_bits = probs @ jnp.asarray(pw, probs.dtype)           # (C,)
    total_bits = jnp.sum(exp_bits)
    if probs.shape[0] == 1:     # layer-wise gamma
        total_bits = total_bits * float(geom.cout)
    cin_eff = _cin_eff(geom, gammas, pw, ctx)
    k = float(geom.kx * geom.ky)
    cin_term = jnp.asarray(1.0) if geom.kind == "dwconv" else cin_eff
    return cin_term * k * total_bits / 8.0


# --------------------------------------------------------------------------
# bitops (hardware-agnostic latency proxy)
# --------------------------------------------------------------------------

def bitops_cost(geom: LayerGeom, gammas: dict, deltas: dict,
                pw: tuple[int, ...], px: tuple[int, ...],
                ctx: mps.SearchCtx) -> jax.Array:
    counts = _soft_channel_counts(geom, gammas, pw, ctx)      # (|P_W|,)
    aprobs = _act_probs(geom, deltas, px, ctx)                # (|P_X|,)
    cin_eff = _cin_eff(geom, gammas, pw, ctx)
    spatial = float(geom.out_h * geom.out_w * geom.kx * geom.ky)
    pw_b = jnp.asarray(pw, counts.dtype)
    px_b = jnp.asarray(px, counts.dtype)
    exp_pw_ch = jnp.sum(counts * pw_b)          # sum over channels of bits
    exp_px = jnp.sum(aprobs * px_b)
    return spatial * cin_eff * exp_pw_ch * exp_px


# --------------------------------------------------------------------------
# MPIC (Eq. 10-11): LUT of MACs/cycle per (p_x, p_w)
# --------------------------------------------------------------------------
# Reconstructed from the MPIC description (Ottavi et al. 2020): the SIMD
# dot-product unit packs 32 bits of operands -> 32/max(px,pw) MACs/cycle for
# homogeneous precisions; mixed-precision pairs gain ~20% from the reduced
# fetch count. Values are MACs/cycle.

def _mpic_lut() -> dict[tuple[int, int], float]:
    lut = {}
    for a in (2, 4, 8, 16):
        for w in (2, 4, 8, 16):
            base = 32.0 / max(a, w)
            lut[(a, w)] = base * (1.2 if a != w else 1.0)
    # homogeneous baselines measured in the paper are slightly below ideal
    lut[(8, 8)] = 4.0
    lut[(4, 4)] = 8.0
    lut[(2, 2)] = 16.0
    lut[(16, 16)] = 2.0
    return lut

MPIC_LUT = _mpic_lut()
MPIC_FREQ_HZ = 250e6          # paper Sec. 4.3.2
MPIC_POWER_W = 5.385e-3       # derived from paper Table 3 (energy/latency)


def mpic_cost(geom: LayerGeom, gammas: dict, deltas: dict,
              pw: tuple[int, ...], px: tuple[int, ...],
              ctx: mps.SearchCtx) -> jax.Array:
    """Expected cycles on MPIC (Eq. 10)."""
    counts = _soft_channel_counts(geom, gammas, pw, ctx)
    aprobs = _act_probs(geom, deltas, px, ctx)
    cin_eff = _cin_eff(geom, gammas, pw, ctx)
    spatial = float(geom.kx * geom.ky * geom.out_h * geom.out_w)
    total = jnp.asarray(0.0)
    for i, b_x in enumerate(px):
        for j, b_w in enumerate(pw):
            if b_w == 0:
                continue  # pruned channels execute no MACs
            macs = spatial * cin_eff * aprobs[i] * counts[j]
            total = total + macs / MPIC_LUT[(b_x, b_w)]
    return total


# --------------------------------------------------------------------------
# NE16 (Sec. 4.3.3): streamer + PE-matrix + store, 32-channel granularity
# --------------------------------------------------------------------------
NE16_STREAMER_BITS = 288.0    # weight-load bandwidth, bits/cycle
NE16_STORE_BITS = 64.0        # L1 store bandwidth, bits/cycle
NE16_PE_SPATIAL = 9.0         # 3x3 PEs, one output pixel each
NE16_PE_COUT = 32.0           # output channels per PE invocation
NE16_FREQ_HZ = 370e6          # GAP9 max frequency


def ne16_cost(geom: LayerGeom, gammas: dict, deltas: dict,
              pw: tuple[int, ...], px: tuple[int, ...],
              ctx: mps.SearchCtx) -> jax.Array:
    """Expected cycles on NE16.

    Three terms (paper Sec. 4.3.3): (i) weight streamer load, (ii) PE-matrix
    MAC time -- bit-serial in the weight precision, processing 3x3 output
    pixels x 32 output channels per invocation, (iii) L1 result store.
    The ceil() on channel groups is what makes <32-channel precision groups
    unprofitable (Fig. 8 discussion).
    """
    counts = _soft_channel_counts(geom, gammas, pw, ctx)      # (|P_W|,)
    cin_eff = _cin_eff(geom, gammas, pw, ctx)
    k = float(geom.kx * geom.ky)
    spatial_tiles = (math.ceil(geom.out_h / 3) * math.ceil(geom.out_w / 3))
    load = jnp.asarray(0.0)
    mac = jnp.asarray(0.0)
    kept = jnp.asarray(0.0)
    for j, b_w in enumerate(pw):
        if b_w == 0:
            continue
        groups = _group_count(counts[j], NE16_PE_COUT)  # 32-channel step
        cin_term = jnp.asarray(1.0) if geom.kind == "dwconv" else cin_eff
        # (i) weights streamed once per spatial tile row of invocations
        load = load + cin_term * k * groups * NE16_PE_COUT * b_w \
            / NE16_STREAMER_BITS
        # (ii) bit-serial MACs: cin*k^2*pw/8 cycles per 3x3x32 output tile
        mac = mac + spatial_tiles * groups * cin_term * k * b_w / 8.0
        kept = kept + counts[j]
    store = float(geom.out_h * geom.out_w) * kept * 8.0 / NE16_STORE_BITS
    return load + mac + store


def mpic_cycles_discrete(geom: LayerGeom, channel_bits, cin_eff: float,
                         act_bits: int = 8) -> float:
    """Discrete (post-search) MPIC cycle count for a concrete assignment."""
    import numpy as np
    channel_bits = np.asarray(channel_bits)
    spatial = float(geom.kx * geom.ky * geom.out_h * geom.out_w)
    cin_term = 1.0 if geom.kind == "dwconv" else float(cin_eff)
    total = 0.0
    for b_w in sorted(set(int(b) for b in channel_bits)):
        if b_w == 0:
            continue
        n = int(np.sum(channel_bits == b_w))
        total += spatial * cin_term * n / MPIC_LUT[(act_bits, b_w)]
    return total


def ne16_cycles_discrete(geom: LayerGeom, channel_bits, cin_eff: float
                         ) -> float:
    """Discrete (post-search) NE16 cycle count for a concrete assignment.

    ``channel_bits``: int array (C_out,) of assigned precisions. Used by the
    post-search refinement step and the deployment benchmarks.
    """
    import numpy as np
    channel_bits = np.asarray(channel_bits)
    k = float(geom.kx * geom.ky)
    cin_term = 1.0 if geom.kind == "dwconv" else float(cin_eff)
    spatial_tiles = math.ceil(geom.out_h / 3) * math.ceil(geom.out_w / 3)
    load = mac = 0.0
    kept = int(np.sum(channel_bits > 0))
    for b_w in sorted(set(int(b) for b in channel_bits)):
        if b_w == 0:
            continue
        n = int(np.sum(channel_bits == b_w))
        groups = math.ceil(n / NE16_PE_COUT)
        load += cin_term * k * groups * NE16_PE_COUT * b_w / NE16_STREAMER_BITS
        mac += spatial_tiles * groups * cin_term * k * b_w / 8.0
    store = float(geom.out_h * geom.out_w) * kept * 8.0 / NE16_STORE_BITS
    return load + mac + store


# --------------------------------------------------------------------------
# TPU v5e (ours, Sec. 3 of DESIGN.md): max(MXU, HBM) per layer
# --------------------------------------------------------------------------
TPU_BF16_FLOPS = 197e12
TPU_INT8_OPS = 394e12
TPU_HBM_BPS = 819e9
TPU_LANE = 128.0              # channel-group granularity (cf. NE16's 32)


def tpu_cost(geom: LayerGeom, gammas: dict, deltas: dict,
             pw: tuple[int, ...], px: tuple[int, ...],
             ctx: mps.SearchCtx) -> jax.Array:
    """Expected seconds on one TPU v5e core.

    Sub-8-bit precisions do NOT speed up the MXU (int8 is the floor) but DO
    shrink HBM traffic; only pruning (0-bit) removes FLOPs. Channel groups
    round to the 128-lane width (STE-ceil), mirroring the paper's NE16
    32-channel granularity argument at TPU scale.
    """
    counts = _soft_channel_counts(geom, gammas, pw, ctx)
    cin_eff = _cin_eff(geom, gammas, pw, ctx)
    k = float(geom.kx * geom.ky)
    cin_term = jnp.asarray(1.0) if geom.kind == "dwconv" else cin_eff
    spatial = float(geom.out_h * geom.out_w)
    compute_macs = jnp.asarray(0.0)
    weight_bits = jnp.asarray(0.0)
    for j, b_w in enumerate(pw):
        if b_w == 0:
            continue
        lanes = _group_count(counts[j], TPU_LANE) * TPU_LANE
        compute_macs = compute_macs + spatial * k * cin_term * lanes
        weight_bits = weight_bits + k * cin_term * lanes * b_w
    compute_s = 2.0 * compute_macs / TPU_INT8_OPS
    mem_s = (weight_bits / 8.0) / TPU_HBM_BPS
    return jnp.maximum(compute_s, mem_s)


# --------------------------------------------------------------------------
# discrete (post-search) counterparts for size / bitops / tpu
# --------------------------------------------------------------------------

def size_bytes_discrete(geom: LayerGeom, channel_bits, cin_eff: float,
                        act_bits: int = 8) -> float:
    """Discrete Eq. 9 bytes of one layer for a concrete assignment."""
    import numpy as np
    cin_term = 1.0 if geom.kind == "dwconv" else float(cin_eff)
    return cin_term * float(geom.kx * geom.ky) \
        * float(np.sum(np.asarray(channel_bits))) / 8.0


def bitops_discrete(geom: LayerGeom, channel_bits, cin_eff: float,
                    act_bits: int = 8) -> float:
    """Discrete MACs * px * pw of one layer for a concrete assignment."""
    import numpy as np
    spatial = float(geom.out_h * geom.out_w * geom.kx * geom.ky)
    cin_term = 1.0 if geom.kind == "dwconv" else float(cin_eff)
    return spatial * cin_term * float(np.sum(np.asarray(channel_bits))) \
        * float(act_bits)


def tpu_seconds_discrete(geom: LayerGeom, channel_bits, cin_eff: float,
                         act_bits: int = 8) -> float:
    """Discrete TPU-v5e roofline seconds for a concrete assignment."""
    import numpy as np
    channel_bits = np.asarray(channel_bits)
    k = float(geom.kx * geom.ky)
    cin_term = 1.0 if geom.kind == "dwconv" else float(cin_eff)
    spatial = float(geom.out_h * geom.out_w)
    compute_macs = weight_bits = 0.0
    for b_w in sorted(set(int(b) for b in channel_bits)):
        if b_w == 0:
            continue
        n = int(np.sum(channel_bits == b_w))
        lanes = math.ceil(n / TPU_LANE) * TPU_LANE
        compute_macs += spatial * k * cin_term * lanes
        weight_bits += k * cin_term * lanes * b_w
    return max(2.0 * compute_macs / TPU_INT8_OPS,
               (weight_bits / 8.0) / TPU_HBM_BPS)


# --------------------------------------------------------------------------
# dispatch (via the pluggable registry in repro.api.cost_models)
# --------------------------------------------------------------------------

def total_cost(geoms: Sequence[LayerGeom], gammas: dict, deltas: dict,
               pw: tuple[int, ...], px: tuple[int, ...],
               ctx: mps.SearchCtx, model: str = "size") -> jax.Array:
    """Sum of the per-layer regularizer over the whole network.

    ``model`` is a registry name (or a CostModel instance); custom hardware
    models registered via ``repro.api.register_cost_model`` resolve here
    without touching this module.
    """
    from repro.api.cost_models import get_cost_model
    cm = get_cost_model(model)
    total = jnp.asarray(0.0)
    for geom in geoms:
        total = total + cm.expected(geom, gammas, deltas, pw, px, ctx)
    return total
