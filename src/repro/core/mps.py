"""Mixed-precision search (MPS) effective tensors — paper Sec. 4.1/4.2.

Weights: per-output-channel selection over P_W (which includes 0-bit ==
structured pruning). Activations: per-tensor selection over P_X, PACT
quantized.

All functions here are pure; the "module" state lives in plain pytrees:

  mps_weight params   : {'w': (..., C_out on `channel_axis`), 'gamma': (C_out, |P_W|)}
  mps_act params      : {'delta': (|P_X|,), 'alpha': ()}

``SearchCtx`` carries the sampling method, temperature and (optional) rng so
a whole model can thread one context through every MPS site.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quantizers, sampling


@dataclasses.dataclass(frozen=True)
class SearchCtx:
    """Per-step search context threaded through all MPS sites."""
    method: str = sampling.SOFTMAX
    tau: jax.Array | float = 1.0
    rng: Optional[jax.Array] = None
    # when True use the fused Pallas path for the effective-weight combine
    use_kernel: bool = False

    def fold_rng(self, tag: int) -> Optional[jax.Array]:
        if self.rng is None:
            return None
        return jax.random.fold_in(self.rng, tag)


def gamma_probs(gamma: jax.Array, ctx: SearchCtx, tag: int = 0) -> jax.Array:
    """(C_out, |P_W|) probability rows for the weight selection params."""
    return sampling.sample(gamma, ctx.method, ctx.tau, ctx.fold_rng(tag))


def delta_probs(delta: jax.Array, ctx: SearchCtx, tag: int = 0) -> jax.Array:
    """(|P_X|,) probability vector for the activation selection params."""
    return sampling.sample(delta, ctx.method, ctx.tau, ctx.fold_rng(tag))


def effective_weight(w: jax.Array, gamma: jax.Array,
                     precisions: tuple[int, ...], ctx: SearchCtx,
                     channel_axis: int = 0, tag: int = 0) -> jax.Array:
    """Paper Eq. 5: W_hat = sum_p gamma_hat[:, p] * Q_p(W).

    ``gamma`` has shape (C_out, |P_W|); the probability of precision p for
    channel i multiplies the p-bit fake-quantized variant of channel i.
    """
    probs = gamma_probs(gamma, ctx, tag)  # (C, |P|)
    if probs.shape[0] == 1 and w.shape[channel_axis] != 1:
        # layer-wise MPS (EdMIPS-style): one selection row for the whole
        # layer, broadcast over channels (gradients sum over channels)
        probs = jnp.broadcast_to(probs,
                                 (w.shape[channel_axis], probs.shape[1]))
    if ctx.use_kernel and w.ndim == 2 and channel_axis == 0:
        from repro.kernels.mps_combine import ops as mps_ops
        return mps_ops.mps_combine(w, probs, precisions)
    qs = quantizers.quantize_weights_multi(w, precisions, channel_axis)
    # reshape probs so that the channel dim broadcasts on `channel_axis`
    shape = [len(precisions)] + [1] * w.ndim
    shape[1 + channel_axis] = w.shape[channel_axis]
    probs_b = jnp.moveaxis(probs, -1, 0).reshape(shape)
    return jnp.sum(probs_b * qs, axis=0)


def effective_activation(x: jax.Array, delta: jax.Array, alpha: jax.Array,
                         precisions: tuple[int, ...], ctx: SearchCtx,
                         tag: int = 0) -> jax.Array:
    """Paper Eq. 4: X_hat = sum_p delta_hat[p] * Q_p(X) (PACT variants)."""
    probs = delta_probs(delta, ctx, tag)  # (|Px|,)
    qs = quantizers.quantize_acts_multi(x, alpha, precisions)
    probs_b = probs.reshape((len(precisions),) + (1,) * x.ndim)
    return jnp.sum(probs_b * qs, axis=0)


def init_mps_weight(c_out: int, precisions: tuple[int, ...]) -> jax.Array:
    """Per-channel gamma logits, paper Eq. 13 init."""
    return sampling.init_selection_logits(precisions, (c_out,))

def init_mps_act(precisions: tuple[int, ...], alpha0: float = 6.0):
    """(delta logits, PACT alpha) initial values."""
    return sampling.init_selection_logits(precisions), jnp.asarray(alpha0)


def rescale_weights_for_search(w: jax.Array, gamma: jax.Array,
                               precisions: tuple[int, ...], ctx: SearchCtx,
                               channel_axis: int = 0) -> jax.Array:
    """Paper Eq. 12 weight rescaling at the start of the search phase.

    The 0-bit variant contributes a constant zero to the effective weight,
    systematically shrinking its magnitude vs. the post-warmup weights. We
    divide each channel by the total non-zero-bit probability mass so the
    effective tensor keeps the warmup magnitude.
    """
    probs = gamma_probs(gamma, ctx)  # (C, |P|)
    nonzero = jnp.asarray([p != 0 for p in precisions], w.dtype)
    mass = jnp.sum(probs * nonzero, axis=-1)  # (C,)
    mass = jnp.maximum(mass, 1e-3)
    if mass.shape[0] == 1:          # layer-wise gamma
        mass = jnp.broadcast_to(mass, (w.shape[channel_axis],))
    shape = [1] * w.ndim
    shape[channel_axis] = w.shape[channel_axis]
    return w / mass.reshape(shape)


def discretize_gamma(gamma: jax.Array, precisions: tuple[int, ...]
                     ) -> jax.Array:
    """Paper Eq. 8: per-channel argmax precision assignment (int array)."""
    idx = jnp.argmax(gamma, axis=-1)
    return jnp.asarray(precisions, jnp.int32)[idx]


def discretize_delta(delta: jax.Array, precisions: tuple[int, ...]) -> int:
    """Paper Eq. 7: per-tensor argmax precision assignment."""
    return int(jnp.asarray(precisions)[int(jnp.argmax(delta))])


def expected_bits(gamma: jax.Array, precisions: tuple[int, ...],
                  ctx: SearchCtx) -> jax.Array:
    """Per-channel expected bit-width <gamma_hat, P_W> (used by cost models)."""
    probs = gamma_probs(gamma, ctx)
    return probs @ jnp.asarray(precisions, probs.dtype)


def keep_probability(gamma: jax.Array, precisions: tuple[int, ...],
                     ctx: SearchCtx) -> jax.Array:
    """Per-channel probability of NOT being pruned (1 - gamma_hat[:, p0])."""
    probs = gamma_probs(gamma, ctx)
    nonzero = jnp.asarray([p != 0 for p in precisions], probs.dtype)
    return probs @ nonzero
