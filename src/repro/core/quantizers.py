"""Quantizers used by the joint pruning + mixed-precision search.

Faithful to the paper (Sec. 2.1 / 5.1):
  * weights  -> symmetric min-max, per-channel scale, signed integer grid
  * activations -> PACT (learnable clip value alpha), affine unsigned grid
  * 0-bit weight "quantization" == structured pruning (constant zero)

All quantizers are fake-quant (simulate integer grid in float) and use the
straight-through estimator (STE) for gradients.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Small epsilon to avoid division by zero scales on all-zero channels.
_EPS = 1e-8


def ste_round(x: jax.Array) -> jax.Array:
    """round() with identity gradient (straight-through estimator)."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def quantize_weights_symmetric(w: jax.Array, bits: int, channel_axis: int = 0
                               ) -> jax.Array:
    """Symmetric min-max per-channel fake quantization of weights.

    ``bits == 0`` returns zeros (structured pruning of the channel).
    The scale is computed per output channel (``channel_axis``) as
    ``max|w| / (2^(b-1) - 1)`` so that the integer grid is symmetric.
    """
    if bits == 0:
        return jnp.zeros_like(w)
    if bits >= 32:  # identity / float passthrough
        return w
    qmax = float(2 ** (bits - 1) - 1)
    reduce_axes = tuple(i for i in range(w.ndim) if i != channel_axis)
    absmax = jnp.max(jnp.abs(w), axis=reduce_axes, keepdims=True)
    scale = jnp.maximum(absmax, _EPS) / qmax
    # stop_gradient on scale: the paper trains the weights through the STE,
    # the min-max scale follows the weights (no learned scale for weights).
    scale = jax.lax.stop_gradient(scale)
    # clip BEFORE round: same forward values, but the STE gradient mask is
    # the standard raw-value convention 1{|w/s| < qmax} (clip-after-round
    # would zero-split the gradient of every element that rounds to the
    # extreme grid level -- most of the tensor at 2 bits)
    q = ste_round(jnp.clip(w / scale, -qmax, qmax))
    return q * scale


def quantize_weights_multi(w: jax.Array, precisions: tuple[int, ...],
                           channel_axis: int = 0) -> jax.Array:
    """Stack of fake-quantized variants of ``w``: shape (|P|, *w.shape)."""
    return jnp.stack(
        [quantize_weights_symmetric(w, b, channel_axis) for b in precisions])


def pact_quantize(x: jax.Array, alpha: jax.Array, bits: int) -> jax.Array:
    """PACT activation fake quantization.

    y = clip(x, 0, alpha), quantized to an unsigned ``bits``-bit grid with
    step alpha/(2^b - 1). Gradient flows to ``alpha`` through the clip
    boundary (as in the PACT paper) and to ``x`` via STE.
    """
    if bits >= 32:
        return jax.nn.relu(x)
    alpha = jnp.maximum(alpha, _EPS)
    levels = float(2 ** bits - 1)
    clipped = jnp.clip(x, 0.0, alpha)
    step = alpha / levels
    return ste_round(clipped / step) * step


def quantize_acts_multi(x: jax.Array, alpha: jax.Array,
                        precisions: tuple[int, ...]) -> jax.Array:
    """Stack of PACT-quantized variants of ``x``: shape (|Px|, *x.shape)."""
    return jnp.stack([pact_quantize(x, alpha, b) for b in precisions])


def integerize_weights(w: jax.Array, bits: int, channel_axis: int = 0):
    """Return (int_weights, per-channel scale) on the true integer grid.

    Used at deployment/export time (after discretization). ``bits == 0``
    channels should have been removed already; if present they map to 0.
    """
    if bits == 0:
        return jnp.zeros(w.shape, jnp.int8), jnp.zeros(
            tuple(1 if i != channel_axis else w.shape[i]
                  for i in range(w.ndim)), w.dtype)
    qmax = float(2 ** (bits - 1) - 1)
    reduce_axes = tuple(i for i in range(w.ndim) if i != channel_axis)
    absmax = jnp.max(jnp.abs(w), axis=reduce_axes, keepdims=True)
    scale = jnp.maximum(absmax, _EPS) / qmax
    q = jnp.clip(jnp.round(w / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale
