"""Bit-width selection parameter sampling (paper Eq. 3).

Three sampling methods over the selection logits:
  * SM   -- softmax with temperature tau
  * AM   -- argmax (the tau -> 0 limit); forward is a hard one-hot,
            backward uses the tau-softmax surrogate (straight-through)
  * HGSM -- hard Gumbel-softmax: Gumbel-perturbed argmax forward,
            soft Gumbel-softmax backward

``logits`` may be (|P|,) for a per-layer activation assignment (delta) or
(C_out, |P|) for per-channel weight assignment (gamma); sampling is applied
along the last axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

SOFTMAX = "softmax"
ARGMAX = "argmax"
GUMBEL = "gumbel"
SAMPLERS = (SOFTMAX, ARGMAX, GUMBEL)


def _hard_from_soft(soft: jax.Array) -> jax.Array:
    """One-hot of the soft distribution's argmax, with soft gradients."""
    hard = jax.nn.one_hot(jnp.argmax(soft, axis=-1), soft.shape[-1],
                          dtype=soft.dtype)
    return soft + jax.lax.stop_gradient(hard - soft)


def sample(logits: jax.Array, method: str, tau: jax.Array | float,
           rng: jax.Array | None = None) -> jax.Array:
    """Return a probability vector (rows sum to 1) over the precision set."""
    tau = jnp.maximum(jnp.asarray(tau, logits.dtype), 1e-4)
    if method == SOFTMAX:
        return jax.nn.softmax(logits / tau, axis=-1)
    if method == ARGMAX:
        return _hard_from_soft(jax.nn.softmax(logits / tau, axis=-1))
    if method == GUMBEL:
        if rng is None:
            raise ValueError("gumbel sampling requires an rng key")
        g = jax.random.gumbel(rng, logits.shape, logits.dtype)
        return _hard_from_soft(jax.nn.softmax((logits + g) / tau, axis=-1))
    raise ValueError(f"unknown sampling method {method!r}")


def temperature_schedule(initial: float, decay: float):
    """Per-epoch exponential temperature decay: tau_e = initial * decay**e.

    The paper uses decay = exp(-0.045) for CIFAR-10/GSC and 0.638 for
    Tiny ImageNet (fewer epochs, same final temperature).
    """
    def tau_at(epoch) -> jax.Array:
        return jnp.asarray(initial, jnp.float32) * jnp.power(
            jnp.asarray(decay, jnp.float32), epoch)
    return tau_at


def init_selection_logits(precisions: tuple[int, ...],
                          leading_shape: tuple[int, ...] = ()) -> jax.Array:
    """Paper Eq. 13: logits proportional to the precision, gamma_p = p/max(P).

    Higher precisions start more likely; 0-bit (pruning) starts least likely,
    which avoids early gradient-flow interruption.
    """
    pmax = float(max(precisions))
    base = jnp.asarray([p / pmax for p in precisions], jnp.float32)
    return jnp.broadcast_to(base, leading_shape + (len(precisions),)).copy()
