"""Post-search discretization, channel reordering and NE16 refinement.

Implements paper Eq. 7/8 (argmax assignment), Fig. 3 (offline reordering of
weight channels into per-precision groups so each layer splits into |P_W|
dense sub-layers), and the Sec. 4.3.3 post-search refinement (greedily bump
channel groups *up* in precision when that reduces the predicted NE16
cycles, e.g. 33 channels at 4b -> 32 at 4b + 1 at 8b is slower than 33 at
8b... the refinement checks and fixes such mismatches; it never decreases a
bit-width so task accuracy cannot degrade).
"""
from __future__ import annotations

import numpy as np

from repro.core import costs


def assign(mps_params, pw: tuple[int, ...], px: tuple[int, ...]):
    """argmax-discretize all selection parameters (paper Eq. 7/8).

    Returns {"gamma": {group: int array (C,)}, "delta": {name: int},
             "alpha": {name: float}}.
    """
    pw_arr = np.asarray(pw)
    px_arr = np.asarray(px)
    out_g = {k: pw_arr[np.argmax(np.asarray(v), axis=-1)]
             for k, v in mps_params["gamma"].items()}
    out_d = {k: int(px_arr[int(np.argmax(np.asarray(v)))])
             for k, v in mps_params["delta"].items()}
    out_a = {k: float(v) for k, v in mps_params["alpha"].items()}
    return {"gamma": out_g, "delta": out_d, "alpha": out_a}


def assignment_size_bytes(geoms, assignment) -> float:
    """Exact size (bytes) of the discretized model, with pruned channels
    removed and C_in shrunk by the producer's pruning (Eq. 9, discrete)."""
    total = 0.0
    kept = {g: int(np.sum(bits > 0))
            for g, bits in assignment["gamma"].items()}
    for geom in geoms:
        bits = assignment["gamma"][geom.gamma]
        cin_eff = (kept[geom.in_gamma] if geom.in_gamma in kept
                   else geom.cin) if geom.in_gamma else geom.cin
        cin_term = 1 if geom.kind == "dwconv" else cin_eff
        total += cin_term * geom.kx * geom.ky * float(np.sum(bits)) / 8.0
    return total


def prune_fraction(assignment) -> float:
    all_bits = np.concatenate([np.asarray(v).ravel()
                               for v in assignment["gamma"].values()])
    return float(np.mean(all_bits == 0))


def bits_histogram(assignment, pw: tuple[int, ...]):
    """Per-group share of channels at each precision (paper Fig. 7/8)."""
    hist = {}
    for grp, bits in assignment["gamma"].items():
        bits = np.asarray(bits)
        hist[grp] = {b: float(np.mean(bits == b)) for b in pw}
    return hist


# ---------------------------------------------------------------------------
# Fig. 3: channel reordering into per-precision groups
# ---------------------------------------------------------------------------

def reorder_permutations(assignment):
    """Stable per-group permutation sorting channels by assigned bit-width
    (pruned channels last, so dropping them is a slice)."""
    perms = {}
    for grp, bits in assignment["gamma"].items():
        bits = np.asarray(bits)
        order_key = np.where(bits == 0, 999, bits)   # pruned -> end
        perms[grp] = np.argsort(order_key, kind="stable")
    return perms


def sublayer_split(assignment, pw: tuple[int, ...]):
    """After reordering, each layer splits into contiguous per-precision
    sub-layers. Returns {group: [(bits, start, stop), ...]} (pruned channels
    excluded)."""
    perms = reorder_permutations(assignment)
    split = {}
    for grp, bits in assignment["gamma"].items():
        sorted_bits = np.asarray(bits)[perms[grp]]
        segs, start = [], 0
        for b in sorted(set(int(x) for x in sorted_bits if x > 0)):
            n = int(np.sum(sorted_bits == b))
            segs.append((b, start, start + n))
            start += n
        split[grp] = segs
    return split


# ---------------------------------------------------------------------------
# NE16 post-search refinement (Sec. 4.3.3)
# ---------------------------------------------------------------------------

def ne16_refine(geoms, assignment, group_size: int = 32):
    """Greedy, monotone-increase precision refinement.

    For every layer and every precision group whose channel count is not a
    multiple of ``group_size``, try promoting the spill (count % group_size
    channels) to the next higher precision; keep the change if the discrete
    NE16 cycle count decreases. Never decreases precision; runs in
    O(layers * |P_W|) and needs no retraining (paper: <1 s).
    """
    new_gamma = {k: np.asarray(v).copy()
                 for k, v in assignment["gamma"].items()}
    kept = {g: int(np.sum(b > 0)) for g, b in new_gamma.items()}

    def layer_cycles(geom, bits):
        cin_eff = (kept.get(geom.in_gamma, geom.cin)
                   if geom.in_gamma else geom.cin)
        return costs.ne16_cycles_discrete(geom, bits, cin_eff)

    changed = 0
    for geom in geoms:
        bits = new_gamma[geom.gamma]
        levels = sorted(set(int(b) for b in bits if b > 0))
        for li, b in enumerate(levels):
            spill = int(np.sum(bits == b)) % group_size
            if spill == 0 or b == 8:
                continue
            higher = ([lv for lv in levels[li + 1:]] + [8])[0]
            cand = bits.copy()
            idx = np.where(cand == b)[0][-spill:]
            cand[idx] = higher
            if layer_cycles(geom, cand) < layer_cycles(geom, bits):
                new_gamma[geom.gamma] = cand
                bits = cand
                changed += spill
    out = dict(assignment)
    out["gamma"] = new_gamma
    return out, changed
