"""Legacy entry point for the three-phase training recipe (paper Sec. 4.4).

The recipe itself now lives in the composable API:
``repro.api.Warmup`` / ``JointSearch`` / ``Finetune`` driven by
``repro.api.Compressor``. This module keeps the original surface --
:class:`SearchConfig` plus :func:`run_pipeline` -- as a thin, deprecated
shim over that API so old callers and scripts keep working.
"""
from __future__ import annotations

import dataclasses
import warnings

from repro.api.phases import (accuracy, cross_entropy, evaluate,  # noqa: F401
                              merge_bn_stats as _merge_bn,
                              phases_from_config)
from repro.core import sampling


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    pw: tuple[int, ...] = (0, 2, 4, 8)
    px: tuple[int, ...] = (8,)
    sampler: str = sampling.SOFTMAX
    cost_model: str = "size"
    lam: float = 1e-4
    warmup_steps: int = 300
    search_steps: int = 300
    finetune_steps: int = 150
    batch: int = 64
    lr_weights: float = 1e-3
    lr_theta: float = 1e-2          # selection params: SGD(0.9) @ 1e-2
    tau0: float = 1.0
    tau_end: float = 0.02           # annealed to by the end of the search
    cost_normalize: bool = True     # R / R(all-8-bit) -> lambda is O(1)
    ne16_refine: bool = False
    layerwise: bool = False         # EdMIPS-style per-layer assignment
    seed: int = 0

    def __post_init__(self):
        def err(msg: str):
            raise ValueError(f"SearchConfig: {msg}")

        if not self.pw:
            err("pw must be non-empty")
        if not any(p != 0 for p in self.pw):
            err(f"pw must contain at least one nonzero precision, "
                f"got {tuple(self.pw)} (an all-pruned search space cannot "
                f"represent a network)")
        if any(p < 0 for p in self.pw):
            err(f"pw precisions must be >= 0, got {tuple(self.pw)}")
        if not self.px or any(p <= 0 for p in self.px):
            err(f"px must be non-empty with positive precisions, "
                f"got {tuple(self.px)}")
        if self.warmup_steps < 0:
            err(f"warmup_steps must be >= 0, got {self.warmup_steps}")
        if self.search_steps < 1:
            err(f"search_steps must be >= 1, got {self.search_steps}")
        if self.finetune_steps < 0:
            err(f"finetune_steps must be >= 0, got {self.finetune_steps}")
        if self.batch < 1:
            err(f"batch must be >= 1, got {self.batch}")
        if self.lam < 0:
            err(f"lam must be >= 0, got {self.lam}")
        if self.lr_weights <= 0 or self.lr_theta <= 0:
            err(f"learning rates must be positive, got "
                f"lr_weights={self.lr_weights}, lr_theta={self.lr_theta}")
        if self.tau0 <= 0:
            err(f"tau0 must be positive, got {self.tau0}")
        if not (0 < self.tau_end < self.tau0):
            err(f"temperature must anneal: need 0 < tau_end < tau0, got "
                f"tau_end={self.tau_end}, tau0={self.tau0}")
        if self.sampler not in sampling.SAMPLERS:
            err(f"sampler must be one of {sampling.SAMPLERS}, "
                f"got {self.sampler!r}")


def run_pipeline(g, spec, cfg: SearchConfig, verbose: bool = False,
                 init_net_folded=None, gamma_init=None):
    """Deprecated: full warmup -> search -> finetune run (result dict).

    Use ``repro.api.Compressor`` with explicit phase objects instead::

        from repro import api
        comp = api.Compressor(g, spec, pw=cfg.pw, px=cfg.px,
                              batch=cfg.batch, seed=cfg.seed)
        res = comp.run(api.phases_from_config(cfg))

    init_net_folded: start the search from these already-BN-folded params
    (skips warmup; used by the sequential PIT->MixPrec baseline).
    gamma_init: override the Eq. 13 gamma initialization per group (used to
    pin channels pruned by a previous stage).
    """
    warnings.warn(
        "run_pipeline is deprecated; use repro.api.Compressor with phase "
        "objects (see repro.api.phases_from_config)",
        DeprecationWarning, stacklevel=2)
    from repro.api.compressor import Compressor

    comp = Compressor(g, spec, pw=cfg.pw, px=cfg.px, batch=cfg.batch,
                      seed=cfg.seed)
    phases = phases_from_config(cfg, gamma_init=gamma_init,
                                include_warmup=init_net_folded is None)
    hooks = []
    if verbose:
        from repro.api.phases import MetricsLog
        hooks.append(MetricsLog(every=100))
    res = comp.run(phases, hooks=hooks, init_folded=init_net_folded)
    return res.as_legacy_dict()
