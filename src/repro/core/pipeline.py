"""Three-phase training recipe (paper Sec. 4.4):

  warmup  -- float weights only, task loss
  search  -- joint (weights, gamma, delta, alpha) with L_task + lambda*R,
             after BN folding + Eq. 12 weight rescaling; temperature anneal
  finetune -- discretized model (Eq. 7/8), task loss only

Runs the paper's CNN track end-to-end on CPU with synthetic data.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costs, discretize, mps, sampling
from repro.data import synthetic
from repro.models import cnn
from repro.optim import optimizers, schedules


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    pw: tuple[int, ...] = (0, 2, 4, 8)
    px: tuple[int, ...] = (8,)
    sampler: str = sampling.SOFTMAX
    cost_model: str = "size"
    lam: float = 1e-4
    warmup_steps: int = 300
    search_steps: int = 300
    finetune_steps: int = 150
    batch: int = 64
    lr_weights: float = 1e-3
    lr_theta: float = 1e-2          # selection params: SGD(0.9) @ 1e-2
    tau0: float = 1.0
    tau_end: float = 0.02           # annealed to by the end of the search
    cost_normalize: bool = True     # R / R(all-8-bit) -> lambda is O(1)
    ne16_refine: bool = False
    layerwise: bool = False         # EdMIPS-style per-layer assignment
    seed: int = 0


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(logits, labels):
    return jnp.mean(jnp.argmax(logits, -1) == labels)


def _is_mps_leaf(path, _leaf):
    return "mps" if any(getattr(p, "key", None) == "mps" for p in path) \
        else "net"


def run_pipeline(g: cnn.GraphDef, spec: synthetic.ClassificationSpec,
                 cfg: SearchConfig, verbose: bool = False,
                 init_net_folded=None, gamma_init=None):
    """Full warmup -> search -> finetune run. Returns a result dict.

    init_net_folded: start the search from these already-BN-folded params
    (skips warmup; used by the sequential PIT->MixPrec baseline).
    gamma_init: override the Eq. 13 gamma initialization per group (used to
    pin channels pruned by a previous stage).
    """
    t_start = time.time()
    key = jax.random.key(cfg.seed)
    params = cnn.init_params(g, key)
    geoms = cnn.cost_geoms(g)
    timings = {}

    # ---------------- phase 1: warmup (float) ----------------
    opt_w = optimizers.adam(cfg.lr_weights, weight_decay=1e-4)
    opt_state = opt_w.init(params)

    @jax.jit
    def warmup_step(params, opt_state, step):
        x, y = synthetic.class_batch(spec, step, cfg.batch, cfg.seed)

        def loss_fn(p):
            logits, new_p = cnn.apply(g, p, x, mode="float", train=True)
            return cross_entropy(logits, y), new_p

        (loss, new_p), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params)
        new_params, opt_state = opt_w.update(grads, opt_state, params, step)
        # keep the BN running stats updated by the forward pass
        new_params = _merge_bn(new_params, new_p)
        return new_params, opt_state, loss

    t0 = time.time()
    if init_net_folded is None:
        for step in range(cfg.warmup_steps):
            params, opt_state, loss = warmup_step(params, opt_state, step)
        acc_float = evaluate(g, params, spec, mode="float")
        folded = cnn.fold_batchnorm(g, params)
    else:
        folded = init_net_folded
        acc_float = evaluate(g, folded, spec, mode="float", folded=True)
    timings["warmup_s"] = time.time() - t0

    # ---------------- MPS init + Eq.12 rescale ----------------
    mps_params = cnn.init_mps_params(g, cfg.pw, cfg.px,
                                     layerwise=cfg.layerwise)
    if gamma_init is not None:
        mps_params = {**mps_params,
                      "gamma": {**mps_params["gamma"], **gamma_init}}
    ctx0 = mps.SearchCtx(cfg.sampler, cfg.tau0,
                         jax.random.key(cfg.seed + 1))
    folded = {
        name: {**p, "w": mps.rescale_weights_for_search(
            p["w"], mps_params["gamma"][g.node(name).group()], cfg.pw,
            ctx0)}
        for name, p in folded.items()}

    # ---------------- phase 2: joint search ----------------
    # normalizer: the cost of the untouched all-8-bit network
    if cfg.cost_normalize:
        hard8 = {k: jnp.full_like(v, -40.0).at[..., len(cfg.pw) - 1]
                 .set(40.0) for k, v in mps_params["gamma"].items()}
        # normalizer is evaluated on hard one-hot logits: always use the
        # deterministic softmax sampler (gumbel would demand an rng here)
        r8 = float(costs.total_cost(geoms, hard8, mps_params["delta"],
                                    cfg.pw, cfg.px,
                                    mps.SearchCtx(sampling.SOFTMAX, 0.01),
                                    cfg.cost_model))
        cost_scale = 1.0 / max(r8, 1e-9)
    else:
        cost_scale = 1.0
    search_params = {"net": folded, "mps": mps_params}
    opt = optimizers.multi_optimizer(
        _is_mps_leaf,
        {"net": optimizers.adam(cfg.lr_weights, weight_decay=1e-4),
         "mps": optimizers.sgd(cfg.lr_theta, momentum=0.9)})
    opt_state = opt.init(search_params)

    @jax.jit
    def search_step(sp, opt_state, step, tau, rng):
        x, y = synthetic.class_batch(spec, 1_000_000 + step, cfg.batch,
                                     cfg.seed)
        ctx = mps.SearchCtx(cfg.sampler, tau, rng)

        def loss_fn(sp):
            logits, _ = cnn.apply(g, sp["net"], x, mode="search",
                                  mps_params=sp["mps"], ctx=ctx,
                                  pw=cfg.pw, px=cfg.px, folded=True)
            task = cross_entropy(logits, y)
            reg = costs.total_cost(geoms, sp["mps"]["gamma"],
                                   sp["mps"]["delta"], cfg.pw, cfg.px, ctx,
                                   cfg.cost_model) * cost_scale
            return task + cfg.lam * reg, (task, reg)

        (loss, (task, reg)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(sp)
        sp, opt_state = opt.update(grads, opt_state, sp, step)
        return sp, opt_state, task, reg

    t0 = time.time()
    rng = jax.random.key(cfg.seed + 2)
    tau_decay = (cfg.tau_end / cfg.tau0) ** (1.0 /
                                             max(cfg.search_steps - 1, 1))
    for step in range(cfg.search_steps):
        tau = cfg.tau0 * (tau_decay ** step)
        rng, sub = jax.random.split(rng)
        search_params, opt_state, task, reg = search_step(
            search_params, opt_state, step, tau, sub)
        if verbose and step % 100 == 0:
            print(f"  search {step}: task={float(task):.3f} "
                  f"reg={float(reg):.4g}")
    timings["search_s"] = time.time() - t0

    # ---------------- discretize (+ optional NE16 refinement) -------------
    if cfg.layerwise:
        # broadcast the per-layer decision to every channel of the group
        geoms_by_g = {gm.gamma: gm for gm in geoms}
        mp = search_params["mps"]
        mp = {**mp, "gamma": {
            k: jnp.broadcast_to(v, (geoms_by_g[k].cout, v.shape[-1]))
            for k, v in mp["gamma"].items()}}
        search_params = {**search_params, "mps": mp}
    assignment = discretize.assign(search_params["mps"], cfg.pw, cfg.px)
    if cfg.ne16_refine:
        assignment, n_promoted = discretize.ne16_refine(geoms, assignment)
        timings["ne16_promoted"] = n_promoted
    assignment = {
        "gamma": {k: jnp.asarray(v) for k, v in assignment["gamma"].items()},
        "delta": assignment["delta"],
        "alpha": {k: jnp.asarray(v) for k, v in assignment["alpha"].items()},
    }

    # ---------------- phase 3: fine-tune the discrete model ----------------
    net = search_params["net"]
    opt_ft = optimizers.adam(cfg.lr_weights * 0.5, weight_decay=1e-4)
    opt_state = opt_ft.init(net)

    @jax.jit
    def ft_step(net, opt_state, step):
        x, y = synthetic.class_batch(spec, 2_000_000 + step, cfg.batch,
                                     cfg.seed)

        def loss_fn(p):
            logits, _ = cnn.apply(g, p, x, mode="quant",
                                  assignment=assignment, folded=True,
                                  pw=cfg.pw, px=cfg.px)
            return cross_entropy(logits, y)

        loss, grads = jax.value_and_grad(loss_fn)(net)
        net, opt_state = opt_ft.update(grads, opt_state, net, step)
        return net, opt_state, loss

    t0 = time.time()
    for step in range(cfg.finetune_steps):
        net, opt_state, loss = ft_step(net, opt_state, step)
    timings["finetune_s"] = time.time() - t0

    acc_final = evaluate(g, net, spec, mode="quant", assignment=assignment,
                         pw=cfg.pw, px=cfg.px)
    np_assign = {"gamma": {k: np.asarray(v)
                           for k, v in assignment["gamma"].items()},
                 "delta": assignment["delta"],
                 "alpha": {k: float(v)
                           for k, v in assignment["alpha"].items()}}
    size_bytes = discretize.assignment_size_bytes(geoms, np_assign)
    return {
        "acc_float": float(acc_float),
        "acc_final": float(acc_final),
        "size_bytes": float(size_bytes),
        "prune_fraction": discretize.prune_fraction(np_assign),
        "bits_histogram": discretize.bits_histogram(np_assign, cfg.pw),
        "assignment": np_assign,
        "net": net,
        "timings": timings,
        "total_s": time.time() - t_start,
    }


def _merge_bn(opt_params, fwd_params):
    """Take optimizer-updated weights but forward-updated BN stats."""
    out = {}
    for k, p in opt_params.items():
        if "bn" in fwd_params.get(k, {}):
            q = dict(p)
            bn = dict(q["bn"])
            bn["mean"] = fwd_params[k]["bn"]["mean"]
            bn["var"] = fwd_params[k]["bn"]["var"]
            q["bn"] = bn
            out[k] = q
        else:
            out[k] = p
    return out


def evaluate(g, params, spec, mode="float", assignment=None,
             pw=(0, 2, 4, 8), px=(8,), n_batches: int = 8,
             batch: int = 128, folded: bool | None = None) -> float:
    if folded is None:
        folded = mode != "float"

    @jax.jit
    def eval_logits(params, x):
        logits, _ = cnn.apply(g, params, x, mode=mode, train=False,
                              assignment=assignment, pw=pw, px=px,
                              folded=folded)
        return logits

    accs = []
    for x, y in synthetic.eval_set(spec, n_batches, batch):
        accs.append(float(accuracy(eval_logits(params, x), y)))
    return float(np.mean(accs))
