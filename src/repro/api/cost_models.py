"""Pluggable cost-model registry (hardware backends for the search).

A cost model is one target hardware's complexity estimate and has two faces
(paper Sec. 4.3 / 5.6 -- "well-tailored cost models"):

  * ``expected(geom, gammas, deltas, pw, px, ctx)`` -- differentiable
    expected cost of ONE layer under the current soft selection parameters;
    summed over layers it is the search regularizer ``R``.
  * ``discrete(geom, channel_bits, cin_eff, act_bits=8)`` -- exact cost of
    one layer for a concrete per-channel bit assignment; used for
    deployment reporting (paper Table 3) and post-search refinement.

Models are registered by name and the search refers to them by name
(``JointSearch(cost_model="mygpu")``), so a new hardware target plugs in
without touching ``repro.core``:

    from repro import api

    class MyGpu:
        name = "mygpu"
        def expected(self, geom, gammas, deltas, pw, px, ctx): ...
        def discrete(self, geom, channel_bits, cin_eff, act_bits=8): ...

    api.register_cost_model(MyGpu())
    # ... JointSearch(cost_model="mygpu") now works everywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Protocol, runtime_checkable

from repro.core import costs as _costs


@runtime_checkable
class CostModel(Protocol):
    """Protocol every registered cost model implements."""

    name: str

    def expected(self, geom, gammas, deltas, pw, px, ctx):
        """Differentiable expected cost of one layer (search regularizer)."""
        ...

    def discrete(self, geom, channel_bits, cin_eff, act_bits: int = 8):
        """Exact cost of one layer for a concrete bit assignment."""
        ...


@dataclasses.dataclass(frozen=True)
class FunctionCostModel:
    """Adapter building a :class:`CostModel` from two plain functions."""

    name: str
    expected_fn: Callable
    discrete_fn: Callable

    def expected(self, geom, gammas, deltas, pw, px, ctx):
        return self.expected_fn(geom, gammas, deltas, pw, px, ctx)

    def discrete(self, geom, channel_bits, cin_eff, act_bits: int = 8):
        return self.discrete_fn(geom, channel_bits, cin_eff, act_bits)


_REGISTRY: dict[str, CostModel] = {}


def register_cost_model(model: CostModel, name: str | None = None,
                        overwrite: bool = False) -> CostModel:
    """Register ``model`` under ``name`` (defaults to ``model.name``)."""
    key = name if name is not None else getattr(model, "name", None)
    if not key:
        raise ValueError("cost model needs a non-empty name")
    if not overwrite and key in _REGISTRY and _REGISTRY[key] is not model:
        raise ValueError(f"cost model {key!r} is already registered "
                         f"(pass overwrite=True to replace it)")
    _REGISTRY[key] = model
    return model


def get_cost_model(name_or_model) -> CostModel:
    """Resolve a registry name (or pass a model instance through)."""
    if isinstance(name_or_model, str):
        try:
            return _REGISTRY[name_or_model]
        except KeyError:
            raise KeyError(
                f"unknown cost model {name_or_model!r}; available: "
                f"{', '.join(available_cost_models())}") from None
    return name_or_model


def available_cost_models() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# built-in hardware models (implementations live in repro.core.costs)
# ---------------------------------------------------------------------------

for _name, _expected, _discrete in (
    ("size", _costs.size_cost, _costs.size_bytes_discrete),
    ("bitops", _costs.bitops_cost, _costs.bitops_discrete),
    ("mpic", _costs.mpic_cost, _costs.mpic_cycles_discrete),
    ("ne16", _costs.ne16_cost,
     lambda geom, bits, cin_eff, act_bits=8:
         _costs.ne16_cycles_discrete(geom, bits, cin_eff)),
    ("tpu", _costs.tpu_cost, _costs.tpu_seconds_discrete),
):
    register_cost_model(FunctionCostModel(_name, _expected, _discrete))
