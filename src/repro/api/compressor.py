"""The phase-composition orchestrator.

``Compressor`` owns the model-space settings shared by every phase (graph,
data spec, precision sets, batch size, seed), runs an arbitrary phase list,
and returns a :class:`CompressionResult` whose centerpiece is the
serializable :class:`~repro.api.plan.CompressionPlan`.

Checkpoint/resume rides on :class:`repro.checkpoint.CheckpointManager`:
pass ``checkpoint=manager`` to ``run`` and the orchestrator saves the
in-flight train state every ``checkpoint_every`` steps plus a carry
snapshot at every phase boundary; a later ``run`` with the same manager
resumes from the newest readable checkpoint and -- because every phase
derives its per-step randomness by folding the step index into a seed-keyed
base -- replays the identical stream, so an interrupted and a resumed run
produce the same plan.

In-phase checkpoints are **incremental**: each phase start writes one
pinned full snapshot of the carry (folded net / final net / plan), and
periodic saves then store only the train state plus the carry leaves that
actually changed since that snapshot (usually none -- the carry moves at
phase boundaries).  Resume restores base + delta, bit-exact; at LM-track
scale this stops every periodic save from rewriting the full model carry.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.api import phases as phases_mod
from repro.api.plan import CompressionPlan
from repro.checkpoint import checkpoint as checkpoint_mod
from repro.models import cnn

_PHASE_STRIDE = 1_000_000    # checkpoint step tag = phase_index*stride+step


@dataclasses.dataclass
class CompressionResult:
    """Outcome of a full phase composition."""

    plan: Optional[CompressionPlan]
    net: Any
    acc_float: Optional[float]
    acc_final: Optional[float]
    size_bytes: Optional[float]
    prune_fraction: Optional[float]
    bits_histogram: Optional[dict]
    timings: dict
    metrics: dict
    total_s: float
    # warm-start handoff surface (repro.sweep): the post-search BN-folded
    # net and final selection parameters, so the next point of a Pareto
    # sweep can continue from this one's finished state
    folded: Any = None
    mps_params: Any = None

    def as_legacy_dict(self) -> dict:
        """The result dict shape of the deprecated ``run_pipeline``."""
        return {
            "acc_float": self.acc_float,
            "acc_final": self.acc_final,
            "size_bytes": self.size_bytes,
            "prune_fraction": self.prune_fraction,
            "bits_histogram": self.bits_histogram,
            "assignment": self.plan.to_assignment()
            if self.plan is not None else None,
            "net": self.net,
            "timings": self.timings,
            "total_s": self.total_s,
        }


class Compressor:
    """Drive a list of phase objects over one network + dataset."""

    def __init__(self, graph, spec, *, pw=(0, 2, 4, 8), px=(8,),
                 batch: int = 64, seed: int = 0):
        if not pw:
            raise ValueError("Compressor: pw must be non-empty")
        if not any(p != 0 for p in pw):
            raise ValueError(f"Compressor: pw must contain at least one "
                             f"nonzero precision, got {tuple(pw)}")
        if any(p < 0 for p in pw):
            raise ValueError(f"Compressor: pw precisions must be >= 0, "
                             f"got {tuple(pw)}")
        if not px or any(p <= 0 for p in px):
            raise ValueError(f"Compressor: px must be non-empty with "
                             f"positive precisions, got {tuple(px)}")
        if batch < 1:
            raise ValueError(f"Compressor: batch must be >= 1, got {batch}")
        self.graph = graph
        self.spec = spec
        self.pw = tuple(int(p) for p in pw)
        self.px = tuple(int(p) for p in px)
        self.batch = int(batch)
        self.seed = int(seed)

    # ------------------------------------------------------------------ run
    def run(self, phases, hooks=(), init_folded=None, checkpoint=None,
            checkpoint_every: int = 50,
            registry=None) -> CompressionResult:
        """``registry`` (a :class:`repro.obs.MetricsRegistry`) routes the
        phases' step metrics and timings into the shared observability
        namespace -- the same registry the serving stack writes into.
        Hook-logged step metrics become ``compress_step_value`` /
        ``compress_step_points_total{phase,metric}`` (idempotent under
        checkpoint resume when the same registry is reused), and each
        phase's wall time lands in ``compress_phase_seconds{phase}``."""
        t_start = time.time()
        state = phases_mod.CompressionState(
            graph=self.graph, spec=self.spec, pw=self.pw, px=self.px,
            batch=self.batch, seed=self.seed)
        state.folded = init_folded
        if registry is not None and registry.enabled:
            state.registry = registry
        phases = list(phases)
        hooks = list(hooks)

        start_phase, start_step, resumed_train = 0, 0, None
        if checkpoint is not None:
            resumed = self._try_resume(checkpoint, phases, state)
            if resumed is not None:
                start_phase, start_step, resumed_train = resumed

        for i, phase in enumerate(phases):
            if i < start_phase:
                continue
            phase_hooks = hooks
            if checkpoint is not None:
                phase_hooks = hooks + [_CheckpointSaver(
                    checkpoint, checkpoint_every, i,
                    is_last=(i == len(phases) - 1))]
            for h in phase_hooks:
                h.on_phase_start(phase, state)
            t0 = time.time()
            phase.run(state, hooks=phase_hooks,
                      start_step=start_step if i == start_phase else 0,
                      train_state=resumed_train if i == start_phase
                      else None)
            key = f"{phase.name}_s"
            state.timings[key] = state.timings.get(key, 0.0) \
                + time.time() - t0
            if state.registry is not None:
                state.registry.gauge(
                    "compress_phase_seconds",
                    "Cumulative wall time spent in a compression phase",
                    labels=("phase",)).set(state.timings[key],
                                           phase=phase.name)
            for h in phase_hooks:
                h.on_phase_end(phase, state)
        if checkpoint is not None:
            checkpoint.wait()
        return self._result(state, time.time() - t_start)

    def _result(self, state, total_s: float) -> CompressionResult:
        plan = state.plan
        size_bytes = prune_frac = hist = None
        if plan is not None:
            geoms = cnn.cost_geoms(self.graph)
            size_bytes = float(plan.size_bytes(geoms))
            prune_frac = plan.prune_fraction()
            hist = plan.bits_histogram()
        net = state.net if state.net is not None else (
            state.folded if state.folded is not None else state.params)
        return CompressionResult(
            plan=plan, net=net,
            acc_float=state.acc_float, acc_final=state.acc_final,
            size_bytes=size_bytes, prune_fraction=prune_frac,
            bits_histogram=hist, timings=dict(state.timings),
            metrics=dict(state.metrics), total_s=total_s,
            folded=state.folded, mps_params=state.mps_params)

    # -------------------------------------------------------------- resume
    def _try_resume(self, manager, phases, state):
        """Resume from the newest checkpoint that restores cleanly.

        Unreadable arrays or a template mismatch (e.g. the phase list was
        edited) fall back to the next-older checkpoint instead of failing
        the run, matching restore_latest()'s skip-corrupt behavior.
        Incremental in-phase checkpoints restore the carry from their
        pinned phase-start base snapshot plus the saved delta leaves.
        """
        for tag in reversed(manager.all_steps()):
            try:
                meta = manager.peek_meta(tag)
                i = int(meta.get("phase_index", 0))
                step = int(meta.get("phase_step", 0))
                if i >= len(phases):
                    continue
                carry = self._restore_carry(manager, tag, meta)
                self._apply_carry(state, carry, meta)
                if meta.get("boundary"):
                    return (i, 0, None)
                train_tmpl = phases[i].init_train_state(state)
                restored, _ = manager.restore(tag, {"train": train_tmpl})
                return (i, step, restored["train"])
            except Exception as e:  # corrupt/mismatched: try an older one
                print(f"[compressor] cannot resume from checkpoint {tag}: "
                      f"{e}")
        return None

    def _restore_carry(self, manager, tag, meta) -> dict:
        base_tag = meta.get("carry_base_tag")
        if base_tag is None:       # boundary / legacy full-carry save
            restored, _ = manager.restore(
                tag, {"carry": self._carry_template(meta)})
            return restored["carry"]
        base_meta = manager.peek_meta(base_tag)
        restored, _ = manager.restore(
            base_tag, {"carry": self._carry_template(base_meta)})
        carry = dict(restored["carry"])
        delta_keys = meta.get("carry_delta_keys") or []
        if delta_keys:
            full_tmpl = self._carry_template(meta)
            restored, _ = manager.restore(
                tag, {"carry_delta": {k: full_tmpl[k]
                                      for k in delta_keys}})
            carry.update(restored["carry_delta"])
        # keys the phase dropped since the base snapshot
        carry = {k: v for k, v in carry.items() if meta.get(f"has_{k}")}
        manager.pin(base_tag)      # a fresh manager must not GC the base
        return carry

    def _folded_template(self):
        params = cnn.init_params(self.graph, jax.random.key(self.seed))
        return cnn.fold_batchnorm(self.graph, params)

    def _plan_template(self):
        mps_params = cnn.init_mps_params(self.graph, self.pw, self.px)
        tree = {"bits": {}, "perm": {}}
        for grp, gamma in mps_params["gamma"].items():
            c = int(gamma.shape[0])
            tree["bits"][grp] = np.zeros((c,), np.int64)
            tree["perm"][grp] = np.zeros((c,), np.int64)
        return tree

    def _carry_template(self, meta) -> dict:
        carry = {}
        if meta.get("has_folded"):
            carry["folded"] = self._folded_template()
        if meta.get("has_net"):
            carry["net"] = self._folded_template()
        if meta.get("has_plan"):
            carry["plan"] = self._plan_template()
        if meta.get("has_mps"):
            carry["mps"] = cnn.init_mps_params(self.graph, self.pw,
                                               self.px)
        return carry

    def _apply_carry(self, state, carry, meta):
        # unconditional assignment: a failed resume attempt from a newer
        # checkpoint must not leak state into the fallback attempt
        state.folded = carry.get("folded")
        state.net = carry.get("net")
        state.mps_params = carry.get("mps")
        state.plan = CompressionPlan.from_tree(
            carry["plan"], meta["plan_scalars"]) if "plan" in carry else None
        state.acc_float = float(meta["acc_float"]) \
            if meta.get("acc_float") is not None else None
        for key, value in (meta.get("timings") or {}).items():
            state.timings.setdefault(key, value)


class _CheckpointSaver(phases_mod.Hook):
    """Internal hook: one pinned full carry snapshot at phase start, then
    periodic in-phase saves of the train state + only the carry leaves
    that changed vs. that snapshot (delta; empty in the common case), and
    a full carry snapshot at the phase boundary."""

    def __init__(self, manager, every: int, phase_index: int,
                 is_last: bool):
        self.manager = manager
        self.every = every
        self.phase_index = phase_index
        self.is_last = is_last
        self._base_flat: dict[str, dict] = {}
        # strong refs to the carry objects captured in the base: phases
        # REPLACE carry entries rather than mutating them, so object
        # identity proves a key unchanged without flattening it (the refs
        # keep `is` sound -- CPython reuses addresses of dead objects)
        self._base_objs: dict[str, object] = {}

    def _carry(self, state) -> dict:
        carry = {}
        if state.folded is not None:
            carry["folded"] = state.folded
        if state.net is not None:
            carry["net"] = state.net
        if state.plan is not None:
            carry["plan"] = state.plan.to_tree()
        if state.mps_params is not None:
            # the sweep's warm-start handoff rides on the final selection
            # parameters: carry them so a run resumed past JointSearch
            # still reports them in CompressionResult.mps_params
            carry["mps"] = state.mps_params
        return carry

    def _meta(self, state, phase_index: int, phase_step: int,
              boundary: bool) -> dict:
        return {
            "phase_index": phase_index,
            "phase_step": phase_step,
            "boundary": boundary,
            "has_folded": state.folded is not None,
            "has_net": state.net is not None,
            "has_plan": state.plan is not None,
            "has_mps": state.mps_params is not None,
            "plan_scalars": state.plan.scalars()
            if state.plan is not None else None,
            "acc_float": state.acc_float,
            "timings": {k: v for k, v in state.timings.items()
                        if isinstance(v, (int, float))},
        }

    @property
    def _base_tag(self) -> int:
        return self.phase_index * _PHASE_STRIDE

    def on_phase_start(self, phase, state):
        if self.every <= 0:
            return
        carry = self._carry(state)
        self._base_objs = dict(carry)
        existing = self._load_base_flat()
        if existing is not None:
            # a resumed run re-enters the phase: the pinned base snapshot
            # on disk is what older delta checkpoints reference -- reuse
            # it instead of rewriting the full carry (and deltas keep
            # comparing against the disk content, not the resumed carry)
            self._base_flat = existing
            self._base_objs = {}
            self.manager.pin(self._base_tag)
            return
        self._base_flat = {k: checkpoint_mod._flatten(v)
                           for k, v in carry.items()}
        self.manager.save(
            self._base_tag, {"carry": carry}, blocking=False,
            metadata=self._meta(state, self.phase_index, 0, boundary=True),
            pin=True)

    def _load_base_flat(self):
        """The base snapshot's carry as {key: {leaf_path: array}}, read
        straight from disk (None if absent/unreadable)."""
        self.manager.wait()            # join any in-flight boundary write
        try:
            with np.load(self.manager._fname(self._base_tag),
                         allow_pickle=False) as z:
                out: dict[str, dict] = {}
                for key in z.files:
                    if not key.startswith("carry/"):
                        continue
                    top, _, leaf = key[len("carry/"):].partition("/")
                    out.setdefault(top, {})[leaf] = z[key]
                return out or None
        except Exception:
            return None

    def _delta_keys(self, carry: dict) -> list[str]:
        changed = []
        for k, v in carry.items():
            if self._base_objs.get(k) is v:
                continue               # same object the base captured
            base = self._base_flat.get(k)
            if base is None:
                changed.append(k)
                continue
            flat = checkpoint_mod._flatten(v)
            if set(flat) != set(base) or any(
                    not np.array_equal(flat[p], base[p]) for p in flat):
                changed.append(k)
            else:
                self._base_objs[k] = v   # equal content: short-circuit
                #                          the compare on later saves
        return changed

    def on_step(self, phase, state, step, metrics, train_state):
        if self.every <= 0 or (step + 1) % self.every:
            return
        carry = self._carry(state)
        delta_keys = self._delta_keys(carry)
        meta = self._meta(state, self.phase_index, step + 1,
                          boundary=False)
        meta["carry_base_tag"] = self._base_tag
        meta["carry_delta_keys"] = delta_keys
        tag = self.phase_index * _PHASE_STRIDE + step + 1
        self.manager.save(
            tag,
            {"train": train_state,
             "carry_delta": {k: carry[k] for k in delta_keys}},
            blocking=False, metadata=meta)

    def on_phase_end(self, phase, state):
        if self.is_last or self.every <= 0:
            return
        tag = (self.phase_index + 1) * _PHASE_STRIDE
        self.manager.save(
            tag, {"carry": self._carry(state)}, blocking=False,
            metadata=self._meta(state, self.phase_index + 1, 0,
                                boundary=True), pin=True)
