"""Composable compression API (paper Sec. 4.4 + Fig. 3).

Three first-class abstractions:

  * phase objects (:class:`Warmup`, :class:`JointSearch`,
    :class:`Finetune`) composed by a :class:`Compressor`;
  * the serializable :class:`CompressionPlan` artifact every downstream
    consumer (discretize, serve, benchmarks) takes;
  * a pluggable cost-model registry
    (:func:`register_cost_model` / :func:`get_cost_model`).

Typical use::

    from repro import api
    comp = api.Compressor(graph, spec, pw=(0, 2, 4, 8), batch=32)
    res = comp.run([api.Warmup(steps=300),
                    api.JointSearch(steps=300, lam=10.0,
                                    cost_model="ne16"),
                    api.Finetune(steps=150)])
    res.plan.save("artifacts/plan")
"""
from repro.api.compressor import CompressionResult, Compressor
from repro.api.cost_models import (CostModel, FunctionCostModel,
                                   available_cost_models, get_cost_model,
                                   register_cost_model)
from repro.api.phases import (CompressionState, Finetune, Hook, JointSearch,
                              MetricsLog, PeriodicEval, Warmup, accuracy,
                              cross_entropy, evaluate, phases_from_config)
from repro.api.plan import CompressionPlan

__all__ = [
    "CompressionPlan", "CompressionResult", "CompressionState",
    "Compressor", "CostModel", "Finetune", "FunctionCostModel", "Hook",
    "JointSearch", "MetricsLog", "PeriodicEval", "Warmup", "accuracy",
    "available_cost_models", "cross_entropy", "evaluate", "get_cost_model",
    "phases_from_config", "register_cost_model",
]
