"""The serializable compression artifact (paper Eq. 7/8 + Fig. 3).

A :class:`CompressionPlan` is everything the search decides, frozen into one
self-describing object: per-group channel bit-widths (0 == pruned),
per-tensor activation precisions, trained PACT clip values, the Fig. 3
channel-reorder permutations, and provenance metadata (which cost model,
lambda, sampler, ... produced it).

It replaces the raw ``{"gamma": ..., "delta": ..., "alpha": ...}`` dicts
that used to be threaded through discretization, serving and the
benchmarks: every consumer now takes the plan, and the plan round-trips
through ``save``/``load`` (arrays in an ``.npz``, scalars + provenance in a
sidecar ``.json``) so a search run and its deployment can live on different
machines.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional

import numpy as np

from repro.core import discretize

FORMAT_VERSION = 1


def _stem(path: str) -> str:
    for suffix in (".npz", ".json"):
        if path.endswith(suffix):
            return path[: -len(suffix)]
    return path


@dataclasses.dataclass
class CompressionPlan:
    """Concrete per-channel precision assignment plus deployment layout."""

    pw: tuple[int, ...]                  # weight precision search space
    px: tuple[int, ...]                  # activation precision search space
    channel_bits: dict[str, np.ndarray]  # group -> (C,) int bits, 0 = pruned
    act_bits: dict[str, int]             # weight-node name -> act precision
    alphas: dict[str, float]             # weight-node name -> PACT clip
    permutations: dict[str, np.ndarray]  # group -> Fig. 3 reorder (C,) int
    meta: dict = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------ build
    @classmethod
    def from_assignment(cls, assignment: dict, pw, px,
                        meta: Optional[dict] = None) -> "CompressionPlan":
        """Wrap a raw ``{"gamma","delta","alpha"}`` assignment dict."""
        bits = {k: np.asarray(v, np.int64)
                for k, v in assignment["gamma"].items()}
        perms = discretize.reorder_permutations({"gamma": bits})
        return cls(
            pw=tuple(int(p) for p in pw),
            px=tuple(int(p) for p in px),
            channel_bits=bits,
            act_bits={k: int(v) for k, v in assignment["delta"].items()},
            alphas={k: float(v) for k, v in assignment["alpha"].items()},
            permutations={k: np.asarray(v, np.int64)
                          for k, v in perms.items()},
            meta=dict(meta or {}),
        )

    def to_assignment(self, as_jax: bool = False) -> dict:
        """Legacy assignment dict for ``cnn.apply`` / ``core.discretize``."""
        if as_jax:
            import jax.numpy as jnp
            gamma = {k: jnp.asarray(v) for k, v in self.channel_bits.items()}
            alpha = {k: jnp.asarray(v) for k, v in self.alphas.items()}
        else:
            gamma = {k: np.asarray(v) for k, v in self.channel_bits.items()}
            alpha = dict(self.alphas)
        return {"gamma": gamma, "delta": dict(self.act_bits), "alpha": alpha}

    # ------------------------------------------------------------ metrics
    def size_bytes(self, geoms) -> float:
        return discretize.assignment_size_bytes(geoms, self.to_assignment())

    def prune_fraction(self) -> float:
        return discretize.prune_fraction(self.to_assignment())

    def bits_histogram(self) -> dict:
        return discretize.bits_histogram(self.to_assignment(), self.pw)

    def sublayer_split(self) -> dict:
        """Per-precision contiguous sub-layers after the Fig. 3 reorder.

        Derived from the plan's STORED permutations (not recomputed), so
        the reported layout always matches what ``export_plan_layers``
        packs -- even if the reorder heuristic changes between the version
        that saved the plan and the one that loads it.
        """
        split = {}
        for grp, bits in self.channel_bits.items():
            sorted_bits = np.asarray(bits)[self.permutations[grp]]
            segs, start = [], 0
            for b in sorted(set(int(x) for x in sorted_bits if x > 0)):
                n = int(np.sum(sorted_bits == b))
                segs.append((b, start, start + n))
                start += n
            split[grp] = segs
        return split

    @property
    def groups(self) -> tuple[str, ...]:
        return tuple(sorted(self.channel_bits))

    # ------------------------------------------------------------ serving
    def bind(self, weights: dict) -> dict:
        """Pack ``weights`` (group name -> (C_out, C_in) float matrix) for
        serving with this plan's channel bits + stored Fig. 3
        permutations.  Returns ``{group: (packed_layers, perm, kept)}`` --
        the per-layer half of what ``serve.engine.apply_plan`` binds into
        a full LM tree."""
        from repro.serve import engine
        return engine.export_plan_layers(self, weights)

    # ------------------------------------------------------------ save/load
    def scalars(self) -> dict:
        """The JSON-able (non-array) half of the plan."""
        return {
            "format_version": FORMAT_VERSION,
            "pw": list(self.pw),
            "px": list(self.px),
            "act_bits": {k: int(v) for k, v in self.act_bits.items()},
            "alphas": {k: float(v) for k, v in self.alphas.items()},
            "groups": sorted(self.channel_bits),
            "meta": self.meta,
        }

    def save(self, path: str) -> str:
        """Write ``<stem>.npz`` (arrays) + ``<stem>.json`` (scalars).

        ``path`` may be a bare stem or end in ``.npz``/``.json``. Returns
        the ``.npz`` path.
        """
        stem = _stem(path)
        arrays = {}
        for grp, bits in self.channel_bits.items():
            arrays[f"bits::{grp}"] = np.asarray(bits, np.int64)
            arrays[f"perm::{grp}"] = np.asarray(self.permutations[grp],
                                                np.int64)
        npz_path, json_path = stem + ".npz", stem + ".json"
        with open(npz_path, "wb") as f:
            np.savez(f, **arrays)
        with open(json_path, "w") as f:
            json.dump(self.scalars(), f, indent=2, sort_keys=True)
        return npz_path

    @classmethod
    def load(cls, path: str) -> "CompressionPlan":
        stem = _stem(path)
        with open(stem + ".json") as f:
            sc = json.load(f)
        if sc.get("format_version") != FORMAT_VERSION:
            raise ValueError(f"unsupported plan format version "
                             f"{sc.get('format_version')!r} in {stem}.json")
        bits, perms = {}, {}
        with np.load(stem + ".npz", allow_pickle=False) as z:
            for grp in sc["groups"]:
                bits[grp] = np.asarray(z[f"bits::{grp}"], np.int64)
                perms[grp] = np.asarray(z[f"perm::{grp}"], np.int64)
        return cls(pw=tuple(sc["pw"]), px=tuple(sc["px"]),
                   channel_bits=bits, act_bits=dict(sc["act_bits"]),
                   alphas=dict(sc["alphas"]), permutations=perms,
                   meta=dict(sc.get("meta", {})))

    # ------------------------------------------------------- (de)tree-ify
    def to_tree(self) -> dict:
        """Array-only pytree (checkpointing); pairs with :meth:`scalars`."""
        return {"bits": {k: np.asarray(v, np.int64)
                         for k, v in self.channel_bits.items()},
                "perm": {k: np.asarray(v, np.int64)
                         for k, v in self.permutations.items()}}

    @classmethod
    def from_tree(cls, tree: dict, scalars: dict) -> "CompressionPlan":
        return cls(pw=tuple(scalars["pw"]), px=tuple(scalars["px"]),
                   channel_bits={k: np.asarray(v, np.int64)
                                 for k, v in tree["bits"].items()},
                   act_bits=dict(scalars["act_bits"]),
                   alphas=dict(scalars["alphas"]),
                   permutations={k: np.asarray(v, np.int64)
                                 for k, v in tree["perm"].items()},
                   meta=dict(scalars.get("meta", {})))

    # ------------------------------------------------------------- equality
    def equals(self, other: "CompressionPlan") -> bool:
        """Exact equality of everything that affects deployment."""
        if not isinstance(other, CompressionPlan):
            return False
        if (self.pw != other.pw or self.px != other.px
                or set(self.channel_bits) != set(other.channel_bits)
                or self.act_bits != other.act_bits):
            return False
        for k, v in self.alphas.items():
            if k not in other.alphas or float(v) != float(other.alphas[k]):
                return False
        if set(self.alphas) != set(other.alphas):
            return False
        for grp, bits in self.channel_bits.items():
            if not np.array_equal(bits, other.channel_bits[grp]):
                return False
            if not np.array_equal(self.permutations[grp],
                                  other.permutations[grp]):
                return False
        return True

    def summary(self) -> str:
        n = sum(int(np.asarray(b).size) for b in self.channel_bits.values())
        pruned = self.prune_fraction()
        return (f"CompressionPlan({len(self.channel_bits)} groups, "
                f"{n} channels, {100 * pruned:.1f}% pruned, "
                f"pw={self.pw}, px={self.px})")
