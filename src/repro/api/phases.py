"""Composable compression phases (paper Sec. 4.4).

The paper's recipe -- warmup -> joint search -> finetune -- is expressed as
three first-class phase objects. Each phase is a validated config dataclass
with a ``run(state, hooks=...)`` method that advances a shared
:class:`CompressionState`; the :class:`~repro.api.compressor.Compressor`
chains an arbitrary phase list, so the sequential PIT->MixPrec baseline,
EdMIPS-style layerwise search, fixed-precision references and Pareto sweeps
are phase compositions rather than keyword flags on a monolithic pipeline.

Hooks observe every phase: ``on_phase_start`` / ``on_step`` /
``on_phase_end``. Built-ins cover metrics logging, periodic evaluation and
(via the Compressor) checkpoint/resume.
"""
from __future__ import annotations

import dataclasses
import hashlib
import inspect
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import cost_models
from repro.api.plan import CompressionPlan
from repro.core import costs, discretize, mps, sampling
from repro.data import synthetic
from repro.models import cnn
from repro.optim import optimizers


# ---------------------------------------------------------------------------
# shared training helpers (canonical home; core.pipeline re-exports them)
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(logits, labels):
    return jnp.mean(jnp.argmax(logits, -1) == labels)


def merge_bn_stats(opt_params, fwd_params):
    """Take optimizer-updated weights but forward-updated BN stats."""
    out = {}
    for k, p in opt_params.items():
        if "bn" in fwd_params.get(k, {}):
            q = dict(p)
            bn = dict(q["bn"])
            bn["mean"] = fwd_params[k]["bn"]["mean"]
            bn["var"] = fwd_params[k]["bn"]["var"]
            q["bn"] = bn
            out[k] = q
        else:
            out[k] = p
    return out


def evaluate(g, params, spec, mode="float", assignment=None,
             pw=(0, 2, 4, 8), px=(8,), n_batches: int = 8,
             batch: int = 128, folded: bool | None = None) -> float:
    if folded is None:
        folded = mode != "float"

    @jax.jit
    def eval_logits(params, x):
        logits, _ = cnn.apply(g, params, x, mode=mode, train=False,
                              assignment=assignment, pw=pw, px=px,
                              folded=folded)
        return logits

    accs = []
    for x, y in synthetic.eval_set(spec, n_batches, batch):
        accs.append(float(accuracy(eval_logits(params, x), y)))
    return float(np.mean(accs))


def _is_mps_leaf(path, _leaf):
    return "mps" if any(getattr(p, "key", None) == "mps" for p in path) \
        else "net"


def _check(cond: bool, msg: str):
    if not cond:
        raise ValueError(msg)


# ---------------------------------------------------------------------------
# state threaded through the phases
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompressionState:
    """Everything a phase may consume or produce."""

    graph: Any
    spec: Any
    pw: tuple[int, ...]
    px: tuple[int, ...]
    batch: int
    seed: int
    params: Any = None          # float params with live BN (warmup output)
    folded: Any = None          # BN-folded net (search input/output)
    mps_params: Any = None      # selection parameters after the search
    plan: Optional[CompressionPlan] = None
    net: Any = None             # final (fine-tuned) network
    acc_float: Optional[float] = None
    acc_final: Optional[float] = None
    timings: dict = dataclasses.field(default_factory=dict)
    metrics: dict = dataclasses.field(default_factory=dict)
    registry: Any = None        # optional repro.obs.MetricsRegistry

    def log_metric(self, phase_name: str, step: int, **values):
        self.metrics.setdefault(phase_name, []).append(
            {"step": int(step), **values})
        if self.registry is not None:
            # the registry's per-(phase, metric) step high-water mark
            # makes this idempotent under checkpoint resume: replayed
            # steps re-log into self.metrics (rebuilt from scratch) but
            # are not double-counted in the registry
            self.registry.emit_phase_point(phase_name, int(step), values)


# ---------------------------------------------------------------------------
# hooks
# ---------------------------------------------------------------------------

class Hook:
    """Per-phase observer; override any subset of the callbacks."""

    def on_phase_start(self, phase, state: CompressionState):
        pass

    def on_step(self, phase, state: CompressionState, step: int,
                metrics: dict, train_state):
        pass

    def on_phase_end(self, phase, state: CompressionState):
        pass


class MetricsLog(Hook):
    """Print (and record) step metrics every ``every`` steps."""

    def __init__(self, every: int = 100, printer=print):
        _check(every >= 1, f"MetricsLog.every must be >= 1, got {every}")
        self.every = every
        self.printer = printer

    def on_step(self, phase, state, step, metrics, train_state):
        if step % self.every:
            return
        vals = {k: float(v) for k, v in metrics.items()}
        state.log_metric(phase.name, step, **vals)
        shown = " ".join(f"{k}={v:.4g}" for k, v in vals.items())
        self.printer(f"  {phase.name} {step}: {shown}")


class PeriodicEval(Hook):
    """Run the phase's quick evaluation every ``every`` steps.

    Keeps one per-phase cache dict that cache-aware ``quick_eval``
    implementations (JointSearch, Finetune) use to skip re-discretizing an
    assignment when the selection parameters haven't changed since the
    last eval -- dense eval cadences stop paying the argmax + dict rebuild
    for identical gammas.
    """

    def __init__(self, every: int = 100, n_batches: int = 2):
        _check(every >= 1, f"PeriodicEval.every must be >= 1, got {every}")
        self.every = every
        self.n_batches = n_batches
        self._caches: dict = {}

    def on_step(self, phase, state, step, metrics, train_state):
        if (step + 1) % self.every:
            return
        quick = getattr(phase, "quick_eval", None)
        if quick is None:
            return
        kwargs = {}
        if "cache" in inspect.signature(quick).parameters:
            kwargs["cache"] = self._caches.setdefault(
                (phase.name, id(phase)), {})
        result = quick(state, train_state, n_batches=self.n_batches,
                       **kwargs)
        if result:
            state.log_metric(phase.name, step + 1, **result)


def _emit(hooks, phase, state, step, metrics, train_state):
    for h in hooks:
        h.on_step(phase, state, step, metrics, train_state)


def _plan_fingerprint(plan) -> str:
    """Content hash of the plan pieces that determine its assignment
    (object identity is not a safe cache key: CPython reuses addresses)."""
    h = hashlib.blake2b(digest_size=16)
    for grp in sorted(plan.channel_bits):
        h.update(grp.encode())
        h.update(np.asarray(plan.channel_bits[grp]).tobytes())
    for name in sorted(plan.act_bits):
        h.update(f"{name}={plan.act_bits[name]}".encode())
    for name in sorted(plan.alphas):
        h.update(f"{name}={plan.alphas[name]!r}".encode())
    return h.hexdigest()


def _mps_fingerprint(mps_params) -> str:
    """Content hash of the selection parameters that determine the
    discretized assignment (gamma + delta; alpha passes through assign
    unchanged but is hashed too for safety)."""
    h = hashlib.blake2b(digest_size=16)
    for field in ("gamma", "delta", "alpha"):
        for name in sorted(mps_params.get(field, {})):
            h.update(name.encode())
            h.update(np.asarray(mps_params[field][name]).tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# phase 1: float warmup
# ---------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)
class Warmup:
    """Float training of the full network, then BN folding (phase 1)."""

    steps: int = 300
    lr: float = 1e-3
    weight_decay: float = 1e-4
    name: str = "warmup"

    def __post_init__(self):
        _check(self.steps >= 0, f"Warmup.steps must be >= 0, "
                                f"got {self.steps}")
        _check(self.lr > 0, f"Warmup.lr must be positive, got {self.lr}")
        _check(self.weight_decay >= 0,
               f"Warmup.weight_decay must be >= 0, got {self.weight_decay}")

    def _opt(self):
        return optimizers.adam(self.lr, weight_decay=self.weight_decay)

    def init_train_state(self, state: CompressionState):
        params = state.params if state.params is not None else \
            cnn.init_params(state.graph, jax.random.key(state.seed))
        return {"params": params, "opt": self._opt().init(params)}

    def quick_eval(self, state, train_state, n_batches: int = 2):
        acc = evaluate(state.graph, train_state["params"], state.spec,
                       mode="float", n_batches=n_batches)
        return {"acc_float": acc}

    def run(self, state: CompressionState, hooks=(), start_step: int = 0,
            train_state=None):
        g, spec = state.graph, state.spec
        ts = train_state if train_state is not None \
            else self.init_train_state(state)
        opt_w = self._opt()

        @jax.jit
        def step_fn(params, opt_state, step):
            x, y = synthetic.class_batch(spec, step, state.batch, state.seed)

            def loss_fn(p):
                logits, new_p = cnn.apply(g, p, x, mode="float", train=True)
                return cross_entropy(logits, y), new_p

            (loss, new_p), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            new_params, opt_state = opt_w.update(grads, opt_state, params,
                                                 step)
            # keep the BN running stats updated by the forward pass
            new_params = merge_bn_stats(new_params, new_p)
            return new_params, opt_state, loss

        for step in range(start_step, self.steps):
            params, opt_state, loss = step_fn(ts["params"], ts["opt"], step)
            ts = {"params": params, "opt": opt_state}
            _emit(hooks, self, state, step, {"loss": loss}, ts)

        state.params = ts["params"]
        state.acc_float = evaluate(g, state.params, spec, mode="float")
        state.folded = cnn.fold_batchnorm(g, state.params)
        return state


# ---------------------------------------------------------------------------
# phase 2: joint pruning + mixed-precision search
# ---------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)
class JointSearch:
    """Joint (weights, gamma, delta, alpha) optimization of
    ``L_task + lambda * R`` on the BN-folded network, then Eq. 7/8
    discretization into a :class:`CompressionPlan` (phase 2)."""

    steps: int = 300
    lam: float = 1e-4
    cost_model: Any = "size"        # registry name or CostModel instance
    sampler: str = sampling.SOFTMAX
    lr_weights: float = 1e-3
    lr_theta: float = 1e-2          # selection params: SGD(0.9)
    weight_decay: float = 1e-4
    tau0: float = 1.0
    tau_end: float = 0.02           # annealed to by the end of the search
    cost_normalize: bool = True     # R / R(all-max-bit) -> lambda is O(1)
    layerwise: bool = False         # EdMIPS-style per-layer assignment
    ne16_refine: bool = False
    gamma_init: Optional[dict] = None
    name: str = "search"

    def __post_init__(self):
        _check(self.steps >= 1,
               f"JointSearch.steps must be >= 1, got {self.steps}")
        _check(self.lam >= 0, f"JointSearch.lam must be >= 0, "
                              f"got {self.lam}")
        _check(self.lr_weights > 0 and self.lr_theta > 0,
               f"JointSearch learning rates must be positive, got "
               f"lr_weights={self.lr_weights}, lr_theta={self.lr_theta}")
        _check(self.tau0 > 0,
               f"JointSearch.tau0 must be positive, got {self.tau0}")
        _check(0 < self.tau_end < self.tau0,
               f"JointSearch temperature must anneal: need "
               f"0 < tau_end < tau0, got tau_end={self.tau_end}, "
               f"tau0={self.tau0}")
        _check(self.sampler in sampling.SAMPLERS,
               f"JointSearch.sampler must be one of {sampling.SAMPLERS}, "
               f"got {self.sampler!r}")

    def _opt(self):
        return optimizers.multi_optimizer(
            _is_mps_leaf,
            {"net": optimizers.adam(self.lr_weights,
                                    weight_decay=self.weight_decay),
             "mps": optimizers.sgd(self.lr_theta, momentum=0.9)})

    def _init_mps(self, state: CompressionState):
        """Initial selection parameters (deterministic; also used to
        recompute the cost normalizer identically on resume)."""
        mps_params = cnn.init_mps_params(state.graph, state.pw, state.px,
                                         layerwise=self.layerwise)
        if self.gamma_init is not None:
            mps_params = {**mps_params,
                          "gamma": {**mps_params["gamma"],
                                    **self.gamma_init}}
        return mps_params

    def init_train_state(self, state: CompressionState):
        if state.folded is None:
            raise RuntimeError(
                "JointSearch needs a BN-folded network: run a Warmup phase "
                "first or pass init_folded= to Compressor.run()")
        mps_params = self._init_mps(state)
        # Eq. 12 rescale so the effective tensor keeps the warmup magnitude
        ctx0 = mps.SearchCtx(self.sampler, self.tau0,
                             jax.random.key(state.seed + 1))
        folded = {
            name: {**p, "w": mps.rescale_weights_for_search(
                p["w"],
                mps_params["gamma"][state.graph.node(name).group()],
                state.pw, ctx0)}
            for name, p in state.folded.items()}
        sp = {"net": folded, "mps": mps_params}
        return {"sp": sp, "opt": self._opt().init(sp)}

    def _cost_scale(self, geoms, cm, state) -> float:
        """1 / R(all-max-bit): normalizes lambda to O(1).

        Evaluated on the INITIAL selection parameters (rebuilt from the
        seed, not read from the train state) so a resumed run computes the
        same normalizer as the run it continues.
        """
        if not self.cost_normalize:
            return 1.0
        mps_init = self._init_mps(state)
        hard = {k: jnp.full_like(v, -40.0).at[..., len(state.pw) - 1]
                .set(40.0) for k, v in mps_init["gamma"].items()}
        # evaluated on hard one-hot logits: always use the deterministic
        # softmax sampler (gumbel would demand an rng here)
        ctx = mps.SearchCtx(sampling.SOFTMAX, 0.01)
        r_max = float(costs.total_cost(geoms, hard, mps_init["delta"],
                                       state.pw, state.px, ctx, model=cm))
        return 1.0 / max(r_max, 1e-9)

    def quick_eval(self, state, train_state, n_batches: int = 2,
                   cache: Optional[dict] = None):
        sp = train_state["sp"]
        assignment = None
        if cache is not None:
            fp = _mps_fingerprint(sp["mps"])
            if cache.get("fp") == fp:
                assignment = cache["assignment"]
        if assignment is None:
            assignment = discretize.assign(sp["mps"], state.pw, state.px)
            if cache is not None:
                cache["fp"] = fp
                cache["assignment"] = assignment
        acc = evaluate(state.graph, sp["net"], state.spec, mode="quant",
                       assignment=assignment, pw=state.pw, px=state.px,
                       n_batches=n_batches)
        return {"acc_quant": acc}

    def run(self, state: CompressionState, hooks=(), start_step: int = 0,
            train_state=None):
        g, spec = state.graph, state.spec
        if state.acc_float is None and state.folded is not None:
            state.acc_float = evaluate(g, state.folded, spec, mode="float",
                                       folded=True)
        ts = train_state if train_state is not None \
            else self.init_train_state(state)
        geoms = cnn.cost_geoms(g)
        cm = cost_models.get_cost_model(self.cost_model)
        cost_scale = self._cost_scale(geoms, cm, state)
        opt = self._opt()

        @jax.jit
        def step_fn(sp, opt_state, step, tau, rng):
            x, y = synthetic.class_batch(spec, 1_000_000 + step, state.batch,
                                         state.seed)
            ctx = mps.SearchCtx(self.sampler, tau, rng)

            def loss_fn(sp):
                logits, _ = cnn.apply(g, sp["net"], x, mode="search",
                                      mps_params=sp["mps"], ctx=ctx,
                                      pw=state.pw, px=state.px, folded=True)
                task = cross_entropy(logits, y)
                reg = costs.total_cost(geoms, sp["mps"]["gamma"],
                                       sp["mps"]["delta"], state.pw,
                                       state.px, ctx,
                                       model=cm) * cost_scale
                return task + self.lam * reg, (task, reg)

            (loss, (task, reg)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(sp)
            sp, opt_state = opt.update(grads, opt_state, sp, step)
            return sp, opt_state, task, reg

        base_rng = jax.random.key(state.seed + 2)
        tau_decay = (self.tau_end / self.tau0) ** (
            1.0 / max(self.steps - 1, 1))
        for step in range(start_step, self.steps):
            tau = self.tau0 * (tau_decay ** step)
            # fold_in (not sequential split) so resume-from-checkpoint
            # replays the identical stream
            sub = jax.random.fold_in(base_rng, step)
            sp, opt_state, task, reg = step_fn(ts["sp"], ts["opt"], step,
                                               tau, sub)
            ts = {"sp": sp, "opt": opt_state}
            _emit(hooks, self, state, step,
                  {"task": task, "reg": reg, "tau": tau}, ts)

        # ---- discretize (+ optional NE16 refinement) into the plan
        sp = ts["sp"]
        mps_final = sp["mps"]
        if self.layerwise:
            # broadcast the per-layer decision to every channel of the group
            geoms_by_g = {gm.gamma: gm for gm in geoms}
            mps_final = {**mps_final, "gamma": {
                k: jnp.broadcast_to(v, (geoms_by_g[k].cout, v.shape[-1]))
                for k, v in mps_final["gamma"].items()}}
        assignment = discretize.assign(mps_final, state.pw, state.px)
        if self.ne16_refine:
            assignment, n_promoted = discretize.ne16_refine(geoms,
                                                            assignment)
            state.timings["ne16_promoted"] = n_promoted
        state.plan = CompressionPlan.from_assignment(
            assignment, state.pw, state.px,
            meta={"cost_model": getattr(cm, "name", str(self.cost_model)),
                  "lam": self.lam, "sampler": self.sampler,
                  "steps": self.steps, "seed": state.seed,
                  "layerwise": self.layerwise,
                  "ne16_refine": self.ne16_refine,
                  "cost_normalize": self.cost_normalize,
                  "acc_float": state.acc_float})
        state.folded = sp["net"]
        state.mps_params = mps_final
        return state


# ---------------------------------------------------------------------------
# phase 3: fine-tune the discretized model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)
class Finetune:
    """Task-loss-only training of the discretized network (phase 3)."""

    steps: int = 150
    lr: float = 5e-4
    weight_decay: float = 1e-4
    name: str = "finetune"

    def __post_init__(self):
        _check(self.steps >= 0, f"Finetune.steps must be >= 0, "
                                f"got {self.steps}")
        _check(self.lr > 0, f"Finetune.lr must be positive, got {self.lr}")
        _check(self.weight_decay >= 0,
               f"Finetune.weight_decay must be >= 0, "
               f"got {self.weight_decay}")

    def _opt(self):
        return optimizers.adam(self.lr, weight_decay=self.weight_decay)

    def init_train_state(self, state: CompressionState):
        if state.folded is None or state.plan is None:
            raise RuntimeError("Finetune needs a searched network and a "
                               "CompressionPlan: run JointSearch first")
        return {"net": state.folded, "opt": self._opt().init(state.folded)}

    def quick_eval(self, state, train_state, n_batches: int = 2,
                   cache: Optional[dict] = None):
        # the plan is fixed for the whole phase: build the jax-side
        # assignment once per plan content
        assignment = None
        if cache is not None:
            fp = _plan_fingerprint(state.plan)
            if cache.get("plan_fp") == fp:
                assignment = cache["assignment"]
        if assignment is None:
            assignment = state.plan.to_assignment(as_jax=True)
            if cache is not None:
                cache["plan_fp"] = fp
                cache["assignment"] = assignment
        acc = evaluate(state.graph, train_state["net"], state.spec,
                       mode="quant", assignment=assignment,
                       pw=state.pw, px=state.px, n_batches=n_batches)
        return {"acc_quant": acc}

    def run(self, state: CompressionState, hooks=(), start_step: int = 0,
            train_state=None):
        g, spec = state.graph, state.spec
        ts = train_state if train_state is not None \
            else self.init_train_state(state)
        assignment = state.plan.to_assignment(as_jax=True)
        opt_ft = self._opt()

        @jax.jit
        def step_fn(net, opt_state, step):
            x, y = synthetic.class_batch(spec, 2_000_000 + step, state.batch,
                                         state.seed)

            def loss_fn(p):
                logits, _ = cnn.apply(g, p, x, mode="quant",
                                      assignment=assignment, folded=True,
                                      pw=state.pw, px=state.px)
                return cross_entropy(logits, y)

            loss, grads = jax.value_and_grad(loss_fn)(net)
            net, opt_state = opt_ft.update(grads, opt_state, net, step)
            return net, opt_state, loss

        for step in range(start_step, self.steps):
            net, opt_state, loss = step_fn(ts["net"], ts["opt"], step)
            ts = {"net": net, "opt": opt_state}
            _emit(hooks, self, state, step, {"loss": loss}, ts)

        state.net = ts["net"]
        state.acc_final = evaluate(g, state.net, spec, mode="quant",
                                   assignment=assignment, pw=state.pw,
                                   px=state.px)
        return state


# ---------------------------------------------------------------------------
# recipe helpers
# ---------------------------------------------------------------------------

def phases_from_config(cfg, gamma_init=None, include_warmup: bool = True):
    """Build the paper's 3-phase recipe from a legacy ``SearchConfig``."""
    phases = []
    if include_warmup:
        phases.append(Warmup(steps=cfg.warmup_steps, lr=cfg.lr_weights))
    phases.append(JointSearch(
        steps=cfg.search_steps, lam=cfg.lam, cost_model=cfg.cost_model,
        sampler=cfg.sampler, lr_weights=cfg.lr_weights,
        lr_theta=cfg.lr_theta, tau0=cfg.tau0, tau_end=cfg.tau_end,
        cost_normalize=cfg.cost_normalize, layerwise=cfg.layerwise,
        ne16_refine=cfg.ne16_refine, gamma_init=gamma_init))
    phases.append(Finetune(steps=cfg.finetune_steps,
                           lr=cfg.lr_weights * 0.5))
    return phases
