"""Production mesh construction (single pod: 16x16 = 256 chips of TPU v5e;
multi-pod: 2 pods = 512 chips with a leading pure-DP 'pod' axis)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have "
            f"{len(devices)}; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            f"(launch/dryrun.py sets this automatically)")
    return jax.make_mesh(shape, axes, devices=devices)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests)."""
    devices = jax.devices()[: data * model]
    return jax.make_mesh((data, model), ("data", "model"), devices=devices)
