"""Distributed training launcher.

On real hardware this runs under the production mesh (16x16 per pod); on
this CPU container it runs reduced configs on a debug mesh — same code
path, same step functions as the dry-run.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b-smoke \
        --steps 50 [--search] [--ckpt-dir /tmp/ckpt]

Fault tolerance: atomic step-tagged checkpoints + auto-resume; SIGTERM
triggers a final checkpoint before exit (preemption-safe). Straggler
mitigation on real pods: fixed-shape steps (no data-dependent shapes
anywhere) + the XLA latency-hiding scheduler flag below.
"""
from __future__ import annotations

import argparse
import os
import signal
import sys
import time

# overlap compute with collectives on TPU (no-op on CPU)
os.environ.setdefault(
    "LIBTPU_INIT_ARGS",
    "--xla_tpu_enable_latency_hiding_scheduler=true")

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402

from repro.checkpoint.checkpoint import CheckpointManager  # noqa: E402
from repro.configs import registry                      # noqa: E402
from repro.data import synthetic                        # noqa: E402
from repro.distributed import sharding                  # noqa: E402
from repro.launch import mesh as meshlib                # noqa: E402
from repro.launch import steps as steps_lib             # noqa: E402
from repro.models import lm                             # noqa: E402
from repro.optim import optimizers                      # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b-smoke")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--search", action="store_true",
                    help="joint MPS+pruning objective (paper Sec. 4)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (needs 256 devices)")
    args = ap.parse_args()

    cfg = registry.get(args.arch)
    n_dev = len(jax.devices())
    if args.production_mesh:
        mesh = meshlib.make_production_mesh()
    else:
        mesh = meshlib.make_debug_mesh(data=1, model=1)
    rules = dict(registry.RULE_OVERRIDES.get(cfg.name.replace("-smoke", ""),
                                             {}))
    rules.update(steps_lib.shape_rules(
        type("S", (), {"kind": "train", "global_batch": args.batch})()))

    with sharding.use_mesh(mesh, rules):
        params = lm.init_params(cfg, jax.random.key(0), mps_on=args.search)
        opt = optimizers.make_optimizer(cfg.optimizer, 3e-4)
        opt_state = opt.init(params)
        step_fn = jax.jit(steps_lib.make_train_step(cfg, opt,
                                                    search=args.search))

        mgr = None
        state = {"params": params, "opt": opt_state}
        start = 0
        if args.ckpt_dir:
            mgr = CheckpointManager(args.ckpt_dir, keep=2)
            restored, meta = mgr.restore_latest(state)
            if restored is not None:
                state, start = restored, meta["step"] + 1
                print(f"[train] resumed from step {meta['step']}")

        stop = {"flag": False}
        signal.signal(signal.SIGTERM, lambda *_: stop.update(flag=True))

        t0 = time.time()
        loss = float("nan")
        for step in range(start, args.steps):
            batch = synthetic.lm_batch(cfg.vocab, args.seq + 1, args.batch,
                                       step)
            new_p, new_o, loss = step_fn(state["params"], state["opt"],
                                         batch, jnp.asarray(step))
            state = {"params": new_p, "opt": new_o}
            if step % 10 == 0:
                print(f"[train] step {step} loss {float(loss):.4f} "
                      f"({time.time()-t0:.1f}s, {n_dev} devices)")
            if mgr and (step % args.ckpt_every == 0 and step > start
                        or stop["flag"]):
                mgr.save(step, state, blocking=stop["flag"])
            if stop["flag"]:
                print("[train] SIGTERM: checkpointed, exiting")
                sys.exit(0)
        if mgr:
            mgr.wait()
            mgr.save(args.steps - 1, state)
        print(f"[train] done: final loss {float(loss):.4f}")


if __name__ == "__main__":
    main()
