import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and extract the roofline terms.

MUST be run as its own process (the XLA_FLAGS line above has to execute
before jax initializes):

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape train_4k [--multi-pod] [--search]
    PYTHONPATH=src python -m repro.launch.dryrun --all

Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>[__search].json
"""
import argparse        # noqa: E402
import json            # noqa: E402
import sys             # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

import jax             # noqa: E402

from repro.configs import registry                      # noqa: E402
from repro.configs.base import SHAPES, cell_applicable  # noqa: E402
from repro.distributed import hlo_analysis, sharding    # noqa: E402
from repro.launch import mesh as meshlib                # noqa: E402
from repro.launch import steps as steps_lib             # noqa: E402
from repro.models import lm                             # noqa: E402


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             search: bool = False, verbose: bool = True) -> dict:
    cfg = registry.get(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "search": search}
    if not ok:
        rec["skipped"] = reason
        return rec
    t0 = time.time()
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    rules = dict(registry.RULE_OVERRIDES.get(arch, {}))
    rules.update(steps_lib.shape_rules(shape))
    try:
        with sharding.use_mesh(mesh, rules):
            step, args, in_sh, out_sh, donate = steps_lib.cell_artifacts(
                cfg, shape, mesh, search=search)
            jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        cost = compiled.cost_analysis() or {}
        try:
            mem = compiled.memory_analysis()
            mem_rec = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
                "peak_bytes_est":
                    getattr(mem, "argument_size_in_bytes", 0)
                    + getattr(mem, "output_size_in_bytes", 0)
                    - getattr(mem, "alias_size_in_bytes", 0)
                    + getattr(mem, "temp_size_in_bytes", 0),
            }
        except Exception as e:  # backend without memory analysis
            mem_rec = {"error": str(e)}
        hlo = compiled.as_text()
        _save_hlo(arch, shape_name, multi_pod, search, hlo)
        totals = hlo_analysis.analyze(hlo)
        n_dev = mesh.devices.size
        roof = hlo_analysis.Roofline(
            flops_per_device=totals.flops,
            bytes_per_device=totals.bytes,
            collective_bytes=totals.collective_traffic_bytes,
            n_devices=n_dev,
            dot_bytes_per_device=totals.dot_bytes)
        rec.update({
            "ok": True,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "xla_cost_analysis_flops": float(cost.get("flops", 0.0)),
            "memory_analysis": mem_rec,
            "collectives": {
                "bytes_by_kind": totals.coll_bytes,
                "count_by_kind": totals.coll_counts,
                "traffic_bytes": totals.collective_traffic_bytes,
            },
            "roofline": roof.as_dict(),
            "hlo_lines": len(hlo.splitlines()),
        })
        if verbose:
            r = rec["roofline"]
            print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}"
                  f"{' [search]' if search else ''}: compile ok in "
                  f"{t_compile:.1f}s | compute {r['compute_s']:.4f}s "
                  f"memory {r['memory_s']:.4f}s collective "
                  f"{r['collective_s']:.4f}s -> {r['dominant']}-bound")
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']} FAILED: "
                  f"{rec['error']}")
    return rec


def artifact_path(out_dir, arch, shape_name, multi_pod, search):
    tag = "2x16x16" if multi_pod else "16x16"
    sfx = "__search" if search else ""
    return os.path.join(out_dir, f"{arch}__{shape_name}__{tag}{sfx}.json")


HLO_DIR = "artifacts/hlo"


def _hlo_path(arch, shape_name, multi_pod, search):
    tag = "2x16x16" if multi_pod else "16x16"
    sfx = "__search" if search else ""
    return os.path.join(HLO_DIR, f"{arch}__{shape_name}__{tag}{sfx}.hlo.zst")


def _save_hlo(arch, shape_name, multi_pod, search, text: str):
    """Persist the compiled per-device HLO (zstd) so the roofline can be
    re-analyzed without recompiling."""
    import zstandard
    os.makedirs(HLO_DIR, exist_ok=True)
    with open(_hlo_path(arch, shape_name, multi_pod, search), "wb") as f:
        f.write(zstandard.ZstdCompressor(level=9).compress(
            text.encode()))


def load_hlo(arch, shape_name, multi_pod, search=False) -> str:
    import zstandard
    with open(_hlo_path(arch, shape_name, multi_pod, search), "rb") as f:
        return zstandard.ZstdDecompressor().decompress(f.read()).decode()


def reanalyze(out_dir: str):
    """Recompute analyzer-derived fields of every artifact from stored
    HLO (no recompilation)."""
    for fname in sorted(os.listdir(out_dir)):
        if not fname.endswith(".json"):
            continue
        path = os.path.join(out_dir, fname)
        rec = json.load(open(path))
        if not rec.get("ok"):
            continue
        mp = rec["mesh"] == "2x16x16"
        try:
            hlo = load_hlo(rec["arch"], rec["shape"], mp,
                           rec.get("search", False))
        except FileNotFoundError:
            print(f"[reanalyze] no HLO for {fname}")
            continue
        totals = hlo_analysis.analyze(hlo)
        n_dev = 512 if mp else 256
        roof = hlo_analysis.Roofline(
            flops_per_device=totals.flops,
            bytes_per_device=totals.bytes,
            collective_bytes=totals.collective_traffic_bytes,
            n_devices=n_dev,
            dot_bytes_per_device=totals.dot_bytes)
        rec["collectives"] = {
            "bytes_by_kind": totals.coll_bytes,
            "count_by_kind": totals.coll_counts,
            "traffic_bytes": totals.collective_traffic_bytes}
        rec["roofline"] = roof.as_dict()
        with open(path, "w") as f:
            json.dump(rec, f, indent=2, default=str)
        print(f"[reanalyze] {fname}: {roof.dominant}-bound")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--search", action="store_true",
                    help="lower the paper's joint MPS+pruning search step")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute analyses from stored HLO, no compile")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    if args.reanalyze:
        reanalyze(args.out)
        return

    cells = []
    if args.all:
        for arch in registry.ARCHS:
            for shape_name in SHAPES:
                cells.append((arch, shape_name, False, False))
                cells.append((arch, shape_name, True, False))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape, args.multi_pod, args.search))

    n_fail = 0
    for arch, shape_name, mp, search in cells:
        path = artifact_path(args.out, arch, shape_name, mp, search)
        if args.skip_existing and os.path.exists(path):
            continue
        rec = run_cell(arch, shape_name, multi_pod=mp, search=search)
        with open(path, "w") as f:
            json.dump(rec, f, indent=2, default=str)
        if rec.get("ok") is False:
            n_fail += 1
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
