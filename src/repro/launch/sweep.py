"""Sweep launcher: trace a Pareto front into a durable plan store.

    # lm track: plans the serving fleet can bind directly
    PYTHONPATH=src python -m repro.launch.sweep --track lm \
        --bench llama3.2-1b-smoke --lams 0.5,4 --search-steps 8 \
        --store sweep_store --workdir sweep_work

    # cnn track (the paper's reference networks) with adaptive bisection
    # and fixed-precision baselines for the iso-accuracy report:
    PYTHONPATH=src python -m repro.launch.sweep --track cnn --bench gsc \
        --lams 2,20 --adaptive 2 --baselines --store sweep_store

Kill/resume: re-running the same command against the same
``--store``/``--workdir`` loads finished points from the store and
resumes the in-flight point from its checkpoint; ``--max-points N``
bounds how many points one invocation executes (a deliberate
"interrupt after N" lever, used by the CI smoke).  The resulting store
serves directly: ``python -m repro.launch.fleet --tiers
store:<store-dir>``.
"""
from __future__ import annotations

import argparse
import json

from repro import obs as obs_mod
from repro import sweep as sweep_mod


def build_spec(args) -> sweep_mod.SweepSpec:
    kw = dict(
        name=args.name, track=args.track, bench=args.bench,
        cost_model=args.cost_model,
        lams=tuple(float(x) for x in args.lams.split(",") if x),
        adaptive_points=args.adaptive,
        warm_start=not args.cold,
        warmup_steps=args.warmup_steps, search_steps=args.search_steps,
        warm_search_steps=args.warm_search_steps,
        finetune_steps=args.finetune_steps, batch=args.batch,
        seed=args.seed, width=args.width, seq=args.seq,
        eval_batches=args.eval_batches,
        checkpoint_every=args.checkpoint_every)
    if args.lm_lr is not None:
        kw["lm_lr"] = args.lm_lr
    return sweep_mod.SweepSpec(**kw)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--name", default="sweep")
    ap.add_argument("--track", default="lm", choices=["cnn", "lm"])
    ap.add_argument("--bench", default="llama3.2-1b-smoke",
                    help="cnn: bench name (gsc/cifar10); lm: arch name")
    ap.add_argument("--cost-model", default="size")
    ap.add_argument("--lams", default="0.5,4",
                    help="comma-separated regularization strengths")
    ap.add_argument("--adaptive", type=int, default=0,
                    help="extra bisection points inserted into the "
                         "largest front gaps after the grid")
    ap.add_argument("--cold", action="store_true",
                    help="disable warm-start continuation")
    ap.add_argument("--warmup-steps", type=int, default=60)
    ap.add_argument("--search-steps", type=int, default=60)
    ap.add_argument("--warm-search-steps", type=int, default=None)
    ap.add_argument("--finetune-steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--width", type=int, default=8)
    ap.add_argument("--lm-lr", type=float, default=None)
    ap.add_argument("--eval-batches", type=int, default=4)
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--store", default="sweep_store")
    ap.add_argument("--workdir", default="sweep_work")
    ap.add_argument("--max-points", type=int, default=None,
                    help="execute at most N points this invocation "
                         "(store hits are free); rerun to continue")
    ap.add_argument("--baselines", action="store_true",
                    help="also train fixed w8/w2 references and print "
                         "the iso-accuracy report (cnn track)")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write sweep metrics in Prometheus text format")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the point lifecycle trace as JSON lines")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="write the sweep summary as JSON")
    args = ap.parse_args(argv)

    spec = build_spec(args)
    obs = obs_mod.Observability() if (args.metrics or args.trace) \
        else None
    store = sweep_mod.PlanStore(args.store)
    runner = sweep_mod.SweepRunner(
        spec, store, args.workdir,
        registry=obs.registry if obs else None,
        tracer=obs.tracer if obs else None)
    summary = runner.run(max_points=args.max_points)

    print(f"[sweep] {summary['executed']} executed, "
          f"{summary['loaded']} loaded from store, "
          f"{summary['steps_executed']} steps run, "
          f"{summary['steps_saved']} steps saved by warm starts")
    front = store.front(store.query(kind="point", sweep=spec.name),
                        cost_key=spec.cost_model)
    for e in front:
        lin = e["lineage"]
        print(f"[sweep] front: {e['name']} lam={lin['lam']:g} "
              f"score={e['metrics']['score']:.4f} "
              f"cost={e['costs'][spec.cost_model]:.1f} "
              f"plan={e['plan'][:12]}")

    if args.baselines:
        for bits in (8, 2):
            runner.baseline(bits)
        iso = runner.iso_report()
        for label, row in iso.items():
            print(f"[sweep] iso-accuracy vs {label}: "
                  f"reduction={row['reduction_pct']}% "
                  f"(baseline score={row['baseline_score']:.4f})")
        summary["iso_report"] = iso

    if obs is not None and args.metrics:
        obs_mod.write_prometheus(obs.registry, args.metrics)
        print(f"[sweep] wrote {args.metrics}")
    if obs is not None and args.trace:
        obs_mod.write_trace(obs.tracer, args.trace)
        print(f"[sweep] wrote {args.trace}")
    if args.report:
        with open(args.report, "w") as f:
            json.dump(summary, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"[sweep] wrote {args.report}")


if __name__ == "__main__":
    main()
