"""Serving launcher: batched greedy decoding for any --arch (reduced
configs on CPU; the same prefill/decode step functions lower on the
production mesh in the dry-run).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b-smoke \
        --requests 4 --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import lm
from repro.serve import engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b-smoke")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    cfg = registry.get(args.arch)
    params = lm.init_params(cfg, jax.random.key(0))
    eng = engine.ServeEngine(cfg, params, max_len=args.max_len)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           size=(args.requests, args.prompt_len)
                           ).astype(np.int32)
    t0 = time.time()
    out = eng.generate(prompts, n_tokens=args.tokens)
    dt = time.time() - t0
    total = args.requests * args.tokens
    print(f"[serve] {args.requests} requests x {args.tokens} tokens "
          f"in {dt:.2f}s ({total/dt:.1f} tok/s batched)")
    for i in range(min(args.requests, 4)):
        print(f"  req{i}: prompt={list(prompts[i][:6])}... "
              f"completion={list(out[i][:8])}")


if __name__ == "__main__":
    main()
