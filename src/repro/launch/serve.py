"""Serving launcher: plan-driven continuous-batching decode for any --arch
(reduced configs on CPU; the same prefill/decode step functions lower on
the production mesh in the dry-run).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b-smoke \
        --requests 4 --tokens 16

    # quantized decode from a saved CompressionPlan (or the built-in demo
    # plan), sampled at temperature 0.8, requests arriving over time:
    PYTHONPATH=src python -m repro.launch.serve --plan demo \
        --temperature 0.8 --top-k 40 --stream --arrival-gap 3

    # paged KV cache (vLLM-style page pool + block tables): cache memory
    # scales with live tokens; admission is memory-aware, the pool
    # preempts to the queue on exhaustion:
    PYTHONPATH=src python -m repro.launch.serve --plan demo \
        --cache paged --page-size 8 --pages 24 --stream
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import lm
from repro.serve import engine
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import Request


def _load_plan(spec: str, cfg, params):
    if spec == "demo":
        return engine.synthetic_plan(cfg, params, bits=None, seed=0)
    from repro.api.plan import CompressionPlan
    return CompressionPlan.load(spec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b-smoke")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=4,
                    help="decode slots (requests beyond this queue)")
    ap.add_argument("--plan", default=None,
                    help="CompressionPlan stem/path for quantized decode, "
                         "or 'demo' for a synthetic mixed-precision plan")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stream", action="store_true",
                    help="streaming-arrivals mode: requests join the "
                         "queue over time instead of all at step 0")
    ap.add_argument("--arrival-gap", type=int, default=2,
                    help="decode steps between arrivals with --stream")
    ap.add_argument("--cache", default="dense",
                    choices=["dense", "paged"],
                    help="cache backend: dense slot buffers or a paged "
                         "pool with block tables + memory-aware admission")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per page (must divide --max-len)")
    ap.add_argument("--pages", type=int, default=None,
                    help="page-pool size (default: dense-equivalent "
                         "max_batch*max_len/page_size)")
    ap.add_argument("--host-sampling", action="store_true",
                    help="sample on the host per token instead of the "
                         "on-device batched gumbel top-k path")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="enable observability and write the metrics "
                         "registry in Prometheus text format to PATH")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable observability and write the per-request "
                         "lifecycle trace as JSON lines to PATH")
    args = ap.parse_args()

    cfg = registry.get(args.arch)
    params = lm.init_params(cfg, jax.random.key(0))
    plan = None
    if args.plan is not None:
        plan = _load_plan(args.plan, cfg, params)
        print(f"[serve] quantized decode: {plan.summary()}")
    obs = None
    if args.metrics or args.trace:
        from repro.obs import Observability
        obs = Observability()
    server = engine.InferenceServer(cfg, params, plan=plan,
                                    max_len=args.max_len,
                                    max_batch=args.max_batch,
                                    cache=args.cache,
                                    page_size=args.page_size,
                                    pages=args.pages,
                                    sample_on_device=not args.host_sampling,
                                    obs=obs)

    rng = np.random.default_rng(0)
    sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                        max_tokens=args.tokens, seed=args.seed)
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab,
                              size=args.prompt_len).astype(np.int32)
        arrival = i * args.arrival_gap if args.stream else 0
        reqs.append(Request(uid=i, prompt=prompt, sampling=sp,
                            arrival=arrival))

    t0 = time.time()
    out = server.serve(reqs)
    dt = time.time() - t0
    total = sum(len(v) for v in out.values())
    mode = "stream" if args.stream else "batch"
    quant = "quantized" if plan is not None else "float"
    print(f"[serve] {args.requests} requests x {args.tokens} tokens "
          f"({mode}, {quant}, {args.cache} cache) in {dt:.2f}s "
          f"({total/dt:.1f} tok/s, {server.stats['decode_steps']} decode "
          f"steps, {server.stats['preemptions']} preemptions)")
    mem = server.stats["memory"]
    if mem["backend"] == "paged":
        print(f"[serve] memory: peak {mem['peak_cache_bytes']} B "
              f"({mem['peak_pages_in_use']}/{mem['n_pages']} pages of "
              f"{mem['bytes_per_page']} B) vs dense-equivalent "
              f"{mem['dense_equivalent_bytes']} B")
    else:
        print(f"[serve] memory: dense cache {mem['cache_bytes']} B "
              f"(pinned for the full serve)")
    for i in range(min(args.requests, 4)):
        print(f"  req{i}: prompt={[int(t) for t in reqs[i].prompt[:6]]}... "
              f"completion={[int(t) for t in out[i][:8]]}")

    if obs is not None:
        from repro.obs import write_prometheus, write_trace
        summary = server.metrics_snapshot().get("summary", {})
        if summary:
            ttft = summary["ttft_s"]
            tok = summary["token_latency_s"]
            fmt = lambda v: "n/a" if v is None else f"{v * 1e3:.1f}ms"
            print(f"[obs] ttft p50={fmt(ttft['p50'])} "
                  f"p95={fmt(ttft['p95'])} p99={fmt(ttft['p99'])} | "
                  f"token p50={fmt(tok['p50'])} p95={fmt(tok['p95'])} "
                  f"p99={fmt(tok['p99'])} | "
                  f"preemptions={summary['preemptions']} "
                  f"pages_hwm={summary['pages_held_hwm']}")
            widths = summary.get("decode_compiles_per_width")
            if widths:
                print(f"[obs] decode compiles per width: {widths}")
        if args.metrics:
            write_prometheus(obs.registry, args.metrics)
            print(f"[obs] metrics -> {args.metrics}")
        if args.trace:
            write_trace(obs.tracer, args.trace)
            print(f"[obs] trace -> {args.trace} "
                  f"({len(obs.tracer.events)} events)")


if __name__ == "__main__":
    main()
