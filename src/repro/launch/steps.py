"""train_step / serve_step factories shared by the real launcher and the
multi-pod dry-run. Also builds the ShapeDtypeStruct input specs and the
NamedShardings for every (arch x shape) cell."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import mps
from repro.distributed import sharding
from repro.models import lm
from repro.optim import grad as gradlib
from repro.optim import optimizers


_IS_AXES = lambda x: isinstance(x, tuple)  # logical-axes leaves  # noqa: E731


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, opt: optimizers.Optimizer,
                    search: bool = False, lam: float = 1e-9,
                    clip_norm: float = 1.0):
    """(params, opt_state, batch, step) -> (params, opt_state, loss).

    search=True runs the paper's joint MPS+pruning objective: effective
    weights from the per-channel selection parameters + lambda * size cost.
    """

    def loss_of(params, batch):
        ctx = mps.SearchCtx(tau=1.0) if search else None
        return lm.loss_fn(cfg, params, batch, ctx=ctx,
                          lam=lam if search else 0.0)

    k = max(cfg.train_microbatches, 1)

    def step_fn(params, opt_state, batch, step):
        if k == 1:
            loss_val, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            # gradient accumulation over k microbatches (lax.scan keeps one
            # microbatch's activations live at a time -> peak memory / k,
            # at the cost of k weight-gather passes; Perf iteration 5)
            micro = jax.tree.map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]),
                batch)

            def body(acc, mb):
                l, g = jax.value_and_grad(loss_of)(params, mb)
                acc_g, acc_l = acc
                g = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                 acc_g, g)
                return (g, acc_l + l), None

            # accumulate in the parameter dtype: bf16-master models keep
            # bf16 accumulators (halves the carried gradient memory)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                                params)
            (grads, loss_sum), _ = jax.lax.scan(body, (zero, 0.0), micro)
            grads = jax.tree.map(lambda g: g / k, grads)
            loss_val = loss_sum / k
        grads, _ = gradlib.clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = opt.update(grads, opt_state, params, step)
        return new_params, new_opt, loss_val

    return step_fn


def make_prefill_step(cfg: ArchConfig):
    def step_fn(params, batch):
        logits, caches = lm.forward(cfg, params, batch, mode="prefill",
                                    logits_mode="last")
        return logits, caches
    return step_fn


def make_paged_prefill_step(cfg: ArchConfig):
    """Prefill straight into a :class:`~repro.serve.cache.PagedCache`
    page pool: ``kv_caches`` is the pool subtree (donated by the engine
    so the page writes are in place), ``tables`` the slot's block tables
    sliced to the live width, ``lens`` the (B,) REAL prompt lengths.
    ``tokens`` may be padded up to a q-chunk boundary -- the attention
    kernel masks rows at or beyond ``lens`` and the pool scatter drops
    them, so one compile per (padded length, table width) serves every
    prompt length in the chunk (last_pos is traced, not baked in).  Only
    valid for attention-only stacks -- an SSM mixer's recurrent state
    would be polluted by the trailing padding; pure/hybrid-SSM archs
    prefill at exact length instead."""
    def step_fn(params, batch, kv_caches, tables, lens):
        logits, caches = lm.forward(cfg, params, batch, mode="prefill",
                                    logits_mode="last",
                                    last_pos=lens[0] - 1,
                                    caches=kv_caches, pos=lens,
                                    tables=tables)
        return logits, caches
    return step_fn


def make_decode_step(cfg: ArchConfig):
    """``tables`` is the paged-serving block-table array (None for dense
    caches); it rides outside the cache tree so the engine can donate the
    caches while the device-resident tables survive across steps."""
    def step_fn(params, token_batch, caches, pos, tables=None):
        return lm.decode_step(cfg, params, token_batch, caches, pos,
                              tables=tables)
    return step_fn


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, shardable, no allocation)
# ---------------------------------------------------------------------------

def shape_rules(shape: ShapeConfig) -> dict:
    """Per-shape sharding rule overrides (see DESIGN.md Sec. 5)."""
    if shape.kind == "train":
        return {"act_seq": "model"}
    if shape.kind == "prefill":
        return {"act_seq": "model", "kv_seq": "model"}
    # decode
    if shape.global_batch == 1:      # long-context: shard the KV sequence
        return {"batch": None, "act_seq": None,
                "kv_seq": ("pod", "data", "model")}
    return {"act_seq": None, "kv_seq": "model"}


def batch_struct(cfg: ArchConfig, shape: ShapeConfig):
    """Abstract model inputs for one step of the given kind."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        tok = {"tokens": sds((b, 1), jnp.int32)}
        if cfg.frontend != "none":
            tok = {"embeddings": sds((b, 1, cfg.d_model), jnp.bfloat16)}
        return tok
    batch = {}
    if cfg.frontend == "none":
        batch["tokens"] = sds((b, s), jnp.int32)
    else:  # precomputed patch/frame embeddings (stub frontend)
        batch["embeddings"] = sds((b, s, cfg.d_model), jnp.bfloat16)
    if cfg.is_encdec:
        batch["enc_embeddings"] = sds((b, s, cfg.d_model), jnp.bfloat16)
    if shape.kind == "train":
        batch["targets"] = sds((b, s), jnp.int32)
    return batch


def batch_logical(cfg: ArchConfig, shape: ShapeConfig):
    out = {}
    if shape.kind == "decode":
        key = "tokens" if cfg.frontend == "none" else "embeddings"
        out[key] = ("batch", None) if key == "tokens" else \
            ("batch", None, None)
        return out
    if cfg.frontend == "none":
        out["tokens"] = ("batch", None)
    else:
        out["embeddings"] = ("batch", None, None)
    if cfg.is_encdec:
        out["enc_embeddings"] = ("batch", None, None)
    if shape.kind == "train":
        out["targets"] = ("batch", None)
    return out


def resolve_shardings(mesh, logical_tree):
    """logical tree (tuple leaves) -> NamedSharding tree."""
    return jax.tree.map(
        lambda l: NamedSharding(mesh, sharding.spec(*l)),
        logical_tree, is_leaf=_IS_AXES)


def cell_artifacts(cfg: ArchConfig, shape: ShapeConfig, mesh,
                   search: bool = False):
    """Everything needed to lower one (arch x shape) cell under `mesh`
    (call inside sharding.use_mesh): abstract args, shardings, step fn."""
    params_abs = lm.abstract_params(cfg, mps_on=search)
    params_log = lm.logical_axes(cfg, mps_on=search)
    params_sh = resolve_shardings(mesh, params_log)
    b_abs = batch_struct(cfg, shape)
    b_sh = resolve_shardings(mesh, batch_logical(cfg, shape))

    if shape.kind == "train":
        opt = optimizers.make_optimizer(cfg.optimizer, 1e-4)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        opt_log = optimizers.state_logical_axes(cfg.optimizer, params_log)
        opt_sh = resolve_shardings(mesh, opt_log)
        step = make_train_step(cfg, opt, search=search)
        args = (params_abs, opt_abs, b_abs,
                jax.ShapeDtypeStruct((), jnp.int32))
        in_sh = (params_sh, opt_sh, b_sh, NamedSharding(mesh, P()))
        out_sh = (params_sh, opt_sh, NamedSharding(mesh, P()))
        donate = (0, 1)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg)
        args = (params_abs, b_abs)
        in_sh = (params_sh, b_sh)
        logits_sh = NamedSharding(mesh, sharding.spec("batch", None,
                                                      "vocab"))
        cache_sh = resolve_shardings(mesh, lm.cache_logical_axes(cfg))
        out_sh = (logits_sh, cache_sh)
        donate = ()
    else:  # decode
        step = make_decode_step(cfg)
        caches_abs = lm.init_caches(cfg, shape.global_batch, shape.seq_len,
                                    enc_len=shape.seq_len, abstract=True)
        cache_sh = resolve_shardings(mesh, lm.cache_logical_axes(cfg))
        args = (params_abs, b_abs, caches_abs,
                jax.ShapeDtypeStruct((), jnp.int32))
        in_sh = (params_sh, b_sh, cache_sh, NamedSharding(mesh, P()))
        logits_sh = NamedSharding(mesh, sharding.spec("batch", None,
                                                      "vocab"))
        out_sh = (logits_sh, cache_sh)
        donate = (2,)
    return step, args, in_sh, out_sh, donate
