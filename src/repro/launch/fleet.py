"""Fleet launcher: multi-replica serving across plan tiers with a
Pareto-aware router, deadline admission and an open-loop load trace.

    PYTHONPATH=src python -m repro.launch.fleet \
        --arch llama3.2-1b-smoke --tiers float,demo \
        --requests 12 --rate 40 --deadline-ms 400

    # Pareto-degrade routing over four tiers, burst arrivals, obs
    # artifacts for repro.obs.validate:
    PYTHONPATH=src python -m repro.launch.fleet \
        --tiers float,w8,mixed,w2 --policy pareto_degrade \
        --trace-kind burst --metrics fleet.prom --trace fleet.jsonl

    # chaos: deterministic crash + slow faults with failover and
    # health-gated recovery (CI's chaos smoke stage):
    PYTHONPATH=src python -m repro.launch.fleet \
        --tiers float,w8 --chaos crash+slow --chaos-seed 7 \
        --metrics fleet.prom --trace fleet.jsonl --report fleet.json

Tier specs (comma-separated), in plan-source order:

* ``store:<dir>`` -- every Pareto-front entry of a ``repro.sweep``
  PlanStore becomes one tier (named after its entry);
* ``store:<dir>/<name>`` -- one named store entry;
* a CompressionPlan stem/path (``plan`` / ``plan.npz`` / ``plan.json``);
* ``float`` (no plan), ``w<bits>`` (uniform synthetic plan), and
  ``demo`` / ``mixed`` (seeded random synthetic plan) -- the fallback
  grammar for demos without a real search behind them.

Every replica runs the same arch/params; latency is the fleet's
deterministic virtual clock (see ``repro.fleet.fleet``), token content
is real.  Store tiers must hold lm-track plans for the served arch
(``engine.apply_plan`` raises on group mismatch).
"""
from __future__ import annotations

import argparse
import json
import os

import jax

from repro.configs import registry
from repro.models import lm
from repro.serve import engine
from repro import fleet as fleet_mod


def _store_tiers(ref: str, base_step_ms: float):
    """``store:`` tier source: ``ref`` is a PlanStore root (-> one tier
    per Pareto-front entry) or ``<root>/<entry-name>`` (-> one tier)."""
    from repro.sweep import PlanStore, StoreError

    def is_store(path: str) -> bool:
        return os.path.isdir(os.path.join(path, "entries"))

    if is_store(ref):
        store, name = PlanStore(ref), None
    elif "/" in ref and is_store(ref.rsplit("/", 1)[0]):
        root, name = ref.rsplit("/", 1)
        store = PlanStore(root)
    else:
        raise StoreError(f"store:{ref}: {ref!r} is not a PlanStore root "
                         f"(no entries/ directory) or <root>/<name>")
    entries = [store.entry(name)] if name is not None else \
        store.front(store.query(kind="point") or None)
    if not entries:
        raise StoreError(f"store:{ref}: the store has no entries")
    return [fleet_mod.tier_from_plan(e["name"], store.get(e["plan"]),
                                     base_step_ms=base_step_ms)
            for e in entries]


def build_tiers(spec: str, cfg, params, base_step_ms: float):
    """Tier spec -> list of TierSpec (``store:<dir>`` may expand to
    several; every other form yields exactly one)."""
    if spec.startswith("store:"):
        return _store_tiers(spec[len("store:"):], base_step_ms)
    if spec == "float":
        plan = None
    elif spec in ("demo", "mixed"):
        plan = engine.synthetic_plan(cfg, params, bits=None, seed=0)
    elif spec.startswith("w") and spec[1:].isdigit():
        plan = engine.synthetic_plan(cfg, params, bits=int(spec[1:]))
    else:
        from repro.api.plan import CompressionPlan
        plan = CompressionPlan.load(spec)
    return [fleet_mod.tier_from_plan(spec, plan,
                                     base_step_ms=base_step_ms)]


def build_tier(spec: str, cfg, params, base_step_ms: float):
    """Tier spec -> one TierSpec (see module docstring for the grammar;
    rejects ``store:<dir>`` specs that expand to several tiers)."""
    tiers = build_tiers(spec, cfg, params, base_step_ms)
    if len(tiers) != 1:
        raise ValueError(f"tier spec {spec!r} expands to {len(tiers)} "
                         f"tiers; use build_tiers()")
    return tiers[0]


def build_fleet(cfg, params, tier_specs, *, policy: str,
                max_len: int, max_batch: int, cache: str,
                page_size: int, pages, base_step_ms: float,
                metrics: bool = True, chaos=None,
                failover: bool = True) -> fleet_mod.Fleet:
    pairs = []
    for spec in tier_specs:
        for tier in build_tiers(spec, cfg, params, base_step_ms):
            server = engine.InferenceServer(
                cfg, params, plan=tier.plan, max_len=max_len,
                max_batch=max_batch, cache=cache, page_size=page_size,
                pages=pages)
            pairs.append((tier, server))
    return fleet_mod.Fleet(pairs, policy=policy, metrics=metrics,
                           chaos=chaos, failover=failover)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b-smoke")
    ap.add_argument("--tiers", default="float,demo",
                    help="comma-separated tier specs: store:<dir> (whole "
                         "front) or store:<dir>/<name>, a CompressionPlan "
                         "stem/path, float, w<bits>, demo/mixed")
    ap.add_argument("--policy", default="pareto_degrade",
                    help="round_robin | least_loaded | pareto_degrade | "
                         "static:<tier>")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=40.0,
                    help="open-loop Poisson arrival rate, requests per "
                         "virtual second")
    ap.add_argument("--trace-kind", default="poisson",
                    choices=["poisson", "burst"])
    ap.add_argument("--burst-size", type=int, default=4)
    ap.add_argument("--burst-every-ms", type=float, default=150.0)
    ap.add_argument("--deadline-ms", type=float, default=400.0,
                    help="per-request deadline on the virtual clock "
                         "(<=0 disables deadlines)")
    ap.add_argument("--retry-budget", type=int, default=1)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache", default="paged",
                    choices=["dense", "paged"])
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--pages", type=int, default=None)
    ap.add_argument("--base-step-ms", type=float, default=8.0,
                    help="modeled decode-step cost of the float tier")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="deterministic fault schedule, e.g. "
                         "'crash+slow' or 'crash@40:w8+slow@30-200:x6' "
                         "(see repro.chaos.parse_chaos); targets "
                         "default to seeded draws over the fleet's "
                         "tiers")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the unpinned fields of --chaos")
    ap.add_argument("--no-failover", action="store_true",
                    help="disable crash recovery (struck replicas' "
                         "requests die with the fault terminal) -- the "
                         "ablation arm")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write the shared registry in Prometheus text "
                         "format to PATH")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the merged per-replica lifecycle trace "
                         "as JSON lines to PATH")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="write the SLO report as JSON to PATH")
    args = ap.parse_args()

    cfg = registry.get(args.arch)
    params = lm.init_params(cfg, jax.random.key(0))
    tier_specs = [s for s in args.tiers.split(",") if s]
    flt = build_fleet(cfg, params, tier_specs, policy=args.policy,
                      max_len=args.max_len, max_batch=args.max_batch,
                      cache=args.cache, page_size=args.page_size,
                      pages=args.pages, base_step_ms=args.base_step_ms,
                      failover=not args.no_failover)
    for rep in flt.replicas:
        print(f"[fleet] replica {rep.tier.name}: "
              f"quality={rep.tier.quality:.2f} bits, "
              f"step={rep.tier.step_ms:.2f} ms")

    deadline = args.deadline_ms if args.deadline_ms > 0 else None
    common = dict(vocab=cfg.vocab, prompt_len=args.prompt_len,
                  max_tokens=args.tokens, deadline_ms=deadline,
                  retry_budget=args.retry_budget,
                  temperature=args.temperature, top_k=args.top_k,
                  seed=args.seed)
    if args.trace_kind == "poisson":
        trace = fleet_mod.poisson_trace(args.requests,
                                        rate_rps=args.rate, **common)
    else:
        n_bursts = -(-args.requests // args.burst_size)
        trace = fleet_mod.burst_trace(
            n_bursts, args.burst_size,
            burst_every_ms=args.burst_every_ms, **common)[:args.requests]

    if args.chaos:
        from repro.chaos import ChaosInjector, parse_chaos
        horizon = (trace[-1].arrival_ms if trace else 0.0) + 1000.0
        sched = parse_chaos(args.chaos,
                            targets=[r.tier.name for r in flt.replicas],
                            seed=args.chaos_seed, horizon_ms=horizon)
        for spec in sched:
            print(f"[chaos] {spec.describe()}")
        flt.chaos = ChaosInjector(sched)

    records = flt.run(trace)
    report = fleet_mod.slo_report(flt, records)
    st = report["status"]
    att = report["deadline_attainment"]
    print(f"[fleet] {len(records)} requests via {args.policy}: "
          f"{st['finished']} finished, {st['timeout']} timeout, "
          f"{st['shed']} shed, {report['degraded']} degraded, "
          f"{report['retries']} retries"
          + (f", attainment={att:.2%}" if att is not None else ""))
    fmt = lambda v: "n/a" if v is None else f"{v:.1f}ms"
    for name, t in report["per_tier"].items():
        print(f"[fleet]   {name}: {t['requests']} served, ttft "
              f"p50={fmt(t['ttft_ms']['p50'])} "
              f"p99={fmt(t['ttft_ms']['p99'])}, token "
              f"p50={fmt(t['token_latency_ms']['p50'])}")
    if args.chaos:
        n_rec = sum(1 for r in records.values()
                    for a in r.attempts
                    if a.cause.startswith("recovered:"))
        print(f"[chaos] {len(flt.chaos.delivered)} fault events "
              f"delivered, {n_rec} requests recovered; "
              f"health: {flt.health.states()}")

    if args.metrics:
        from repro.obs import write_prometheus
        write_prometheus(flt.registry, args.metrics)
        print(f"[obs] metrics -> {args.metrics}")
    if args.trace:
        flt.write_trace(args.trace)
        print(f"[obs] trace -> {args.trace} "
              f"({len(flt.trace_events())} events)")
    if args.report:
        with open(args.report, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"[fleet] report -> {args.report}")


if __name__ == "__main__":
    main()
