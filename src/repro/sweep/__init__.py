"""Pareto-front search orchestration (see README.md in this package).

The paper's headline artifact -- an accuracy-vs-cost front of jointly
pruned + channel-wise mixed-precision networks -- as a first-class,
resumable campaign: :class:`SweepSpec` / :class:`SweepRunner` execute the
points (explicit lambda grid + adaptive bisection, warm-start
continuation between points), :class:`PlanStore` persists every finished
plan with its metrics and lineage, and :mod:`repro.sweep.front` maintains
the front and produces the paper-style iso-accuracy reports.
"""
from repro.sweep.front import (dominates, iso_accuracy_reduction,
                               iso_accuracy_report, largest_gap,
                               next_lambda, pareto_front, plan_cost,
                               uniform_cost)
from repro.sweep.runner import (SweepRunner, SweepSpec, available_benches,
                                register_bench)
from repro.sweep.store import (PlanStore, StoreCorruptError,
                               StoreError, plan_hash)

__all__ = [
    "PlanStore", "StoreCorruptError", "StoreError", "SweepRunner",
    "SweepSpec",
    "available_benches", "dominates", "iso_accuracy_reduction",
    "iso_accuracy_report", "largest_gap", "next_lambda", "pareto_front",
    "plan_cost", "plan_hash", "register_bench", "uniform_cost",
]
