"""Sweep orchestration: a resumable, observable Pareto-front campaign.

One :class:`SweepSpec` describes a whole front: the benchmark, the cost
model, an explicit lambda grid plus an adaptive-bisection budget, and the
per-point step recipe.  :class:`SweepRunner` executes the points in lambda
order through the existing search machinery and lands every finished point
in a :class:`~repro.sweep.store.PlanStore`:

* **cnn track** -- phase compositions through ``api.Compressor`` (the
  paper's warmup -> joint search -> finetune recipe on the reference
  CNNs);
* **lm track** -- the transformer search loop
  (``launch.steps.make_train_step(search=True)`` + ``lm.extract_plan``),
  producing plans the serving fleet can bind directly.

**Warm-start continuation**: point ``i+1`` initializes its weights and
selection parameters from point ``i``'s finished state (persisted per
point through :class:`~repro.checkpoint.CheckpointManager`, so the chain
survives process death) and runs a reduced search budget
(``warm_search_steps``) -- the paper's "greatly reduced search time"
mechanism.  Each point still derives its per-step randomness by
``fold_in``-ing the step index into a seed-keyed base, so a point is
bit-exactly resumable from its own incremental checkpoint regardless of
how it was initialized.

**Kill/resume**: finished points are recognized by name in the store
(guarded by the spec hash) and loaded instead of re-run; the in-flight
point resumes from its checkpoint directory.  Because loaded metrics are
bit-identical to freshly computed ones, a killed-and-resumed sweep
reproduces the uninterrupted sweep's store byte-for-byte -- adaptive
lambdas included, since they are pure functions of the front so far.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Optional

import jax
import numpy as np

from repro import api
from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import registry as configs_registry
from repro.core import mps, sampling
from repro.data import synthetic
from repro.launch import steps as steps_lib
from repro.models import cnn, lm
from repro.optim import optimizers
from repro.sweep import front as front_mod
from repro.sweep.store import (PlanStore, StoreCorruptError, StoreError,
                               plan_hash)

# ---------------------------------------------------------------------------
# cnn-track benchmark registry
# ---------------------------------------------------------------------------

_BENCHES = {}


def register_bench(name: str, builder):
    """Register a cnn-track benchmark: ``builder(width) -> (graph,
    data_spec)``."""
    _BENCHES[name] = builder


def available_benches():
    return tuple(sorted(_BENCHES))


register_bench("gsc", lambda width: (cnn.dscnn(width=width),
                                     synthetic.GSC_LIKE))
register_bench("cifar10", lambda width: (cnn.resnet9(width=width),
                                         synthetic.CIFAR10_LIKE))


def _check(cond: bool, msg: str):
    if not cond:
        raise ValueError(msg)


# ---------------------------------------------------------------------------
# the sweep contract
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SweepSpec:
    """Everything that determines a sweep's points (hashed into the store
    lineage, so a store can never silently mix two different specs under
    the same entry names)."""

    name: str = "sweep"
    track: str = "cnn"                  # "cnn" | "lm"
    bench: str = "gsc"                  # cnn: bench name; lm: arch name
    cost_model: str = "size"
    lams: tuple = (2.0, 8.0)
    adaptive_points: int = 0            # extra bisection points after grid
    warm_start: bool = True
    warmup_steps: int = 60              # cnn cold points only
    search_steps: int = 60
    warm_search_steps: Optional[int] = None   # default: search_steps // 2
    finetune_steps: int = 30            # cnn track only
    pw: tuple = (0, 2, 4, 8)
    px: tuple = (8,)
    batch: int = 32
    seed: int = 0
    width: int = 8                      # cnn model width
    seq: int = 32                       # lm batch sequence length
    lm_lr: float = 0.05
    eval_batches: int = 4
    checkpoint_every: int = 20

    def __post_init__(self):
        self.lams = tuple(float(l) for l in self.lams)
        self.pw = tuple(int(p) for p in self.pw)
        self.px = tuple(int(p) for p in self.px)
        _check(self.track in ("cnn", "lm"),
               f"SweepSpec.track must be 'cnn' or 'lm', got {self.track!r}")
        _check(len(self.lams) >= 1, "SweepSpec.lams must be non-empty")
        _check(all(l >= 0 for l in self.lams),
               f"SweepSpec.lams must be >= 0, got {self.lams}")
        _check(self.adaptive_points >= 0,
               f"SweepSpec.adaptive_points must be >= 0, "
               f"got {self.adaptive_points}")
        _check(self.search_steps >= 1,
               f"SweepSpec.search_steps must be >= 1, "
               f"got {self.search_steps}")
        _check(self.warmup_steps >= 1,
               f"SweepSpec.warmup_steps must be >= 1, "
               f"got {self.warmup_steps}")
        _check(self.finetune_steps >= 0,
               f"SweepSpec.finetune_steps must be >= 0, "
               f"got {self.finetune_steps}")
        if self.warm_search_steps is not None:
            _check(1 <= self.warm_search_steps,
                   f"SweepSpec.warm_search_steps must be >= 1, "
                   f"got {self.warm_search_steps}")
        _check(self.batch >= 1 and self.eval_batches >= 1,
               f"SweepSpec batch sizes must be >= 1, got "
               f"batch={self.batch}, eval_batches={self.eval_batches}")
        _check(self.checkpoint_every >= 0,
               f"SweepSpec.checkpoint_every must be >= 0, "
               f"got {self.checkpoint_every}")
        if self.track == "lm":
            _check(self.cost_model == "size",
                   f"the lm track optimizes the differentiable size cost; "
                   f"cost_model must be 'size', got {self.cost_model!r}")

    def warm_search(self) -> int:
        if self.warm_search_steps is not None:
            return self.warm_search_steps
        return max(self.search_steps // 2, 1)

    # -------------------------------------------------------- identity
    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "SweepSpec":
        return cls(**json.loads(text))

    def spec_hash(self) -> str:
        return hashlib.blake2b(self.to_json().encode(),
                               digest_size=8).hexdigest()


# phase-like shim so api.Hook observers (and their kill-injection test
# doubles) work on the lm track's flat train loop too
class _LMSearchPhase:
    name = "lm_search"


_LM_PHASE = _LMSearchPhase()


# ---------------------------------------------------------------------------
# the runner
# ---------------------------------------------------------------------------

class SweepRunner:
    """Execute a :class:`SweepSpec` into a :class:`PlanStore`.

    ``workdir`` holds the per-point checkpoint and warm-start handoff
    directories (``<workdir>/pt<i>/{ckpt,handoff}``); keep it alongside
    the store to make a killed sweep resumable.  ``registry`` / ``tracer``
    are optional ``repro.obs`` sinks (``sweep_*`` metrics, ``point_*``
    lifecycle events).
    """

    def __init__(self, spec: SweepSpec, store: PlanStore, workdir: str,
                 *, registry=None, tracer=None, verbose: bool = True):
        self.spec = spec
        self.store = store
        self.workdir = workdir
        self.registry = (registry if registry is not None
                         and registry.enabled else None)
        self.tracer = tracer
        self.verbose = verbose
        if spec.track == "cnn" and spec.bench not in _BENCHES:
            raise ValueError(f"unknown cnn bench {spec.bench!r}; "
                             f"available: {available_benches()}")
        self._graph = None
        self._dspec = None

    # ------------------------------------------------------------ helpers
    def _say(self, msg: str):
        if self.verbose:
            print(f"[sweep] {msg}")

    def _count(self, name: str, help_: str, n=1, **labels):
        if self.registry is not None:
            self.registry.counter(name, help_,
                                  labels=tuple(labels)).inc(n, **labels)

    def _trace(self, uid: int, kind: str, **extra):
        if self.tracer is not None:
            self.tracer.event(uid, kind, **extra)

    def point_name(self, index: int) -> str:
        return f"{self.spec.name}.pt{index:02d}"

    def _ptdir(self, index: int, sub: str) -> str:
        return os.path.join(self.workdir, f"pt{index:02d}", sub)

    def _bench(self):
        if self._graph is None:
            self._graph, self._dspec = _BENCHES[self.spec.bench](
                self.spec.width)
        return self._graph, self._dspec

    # ------------------------------------------------- warm-start handoff
    def _save_handoff(self, index: int, tree: dict):
        mgr = CheckpointManager(self._ptdir(index, "handoff"), keep=1)
        mgr.save(0, tree, blocking=True)

    def _load_handoff(self, index: int, template: dict) -> dict:
        mgr = CheckpointManager(self._ptdir(index, "handoff"), keep=1)
        if not mgr.all_steps():
            raise StoreError(
                f"warm start needs the finished state of point {index}, "
                f"but {self._ptdir(index, 'handoff')} is empty -- resume "
                f"with the original workdir, or rerun with "
                f"warm_start=False")
        tree, _ = mgr.restore(0, template)
        return tree

    # --------------------------------------------------------------- run
    def run(self, max_points: Optional[int] = None, hooks=()) -> dict:
        """Run the sweep: the explicit lambda grid in ascending order,
        then up to ``adaptive_points`` bisection points.  ``max_points``
        bounds the number of points *executed* this call (store hits are
        free) -- the kill/resume lever.  Returns the sweep summary."""
        spec = self.spec
        points: list[dict] = []
        executed = loaded = 0
        budget_hit = False
        schedule = [float(l) for l in sorted(spec.lams)]
        index = 0
        while index < len(schedule) + spec.adaptive_points:
            if index >= len(schedule):
                lam = front_mod.next_lambda(self._front(points))
                if lam is None:
                    self._say("adaptive bisection converged")
                    break
                schedule.append(lam)
            lam = schedule[index]
            name = self.point_name(index)
            self._trace(index, "point_enqueued", lam=float(lam))
            point = None
            if self.store.has(name):
                try:
                    point = self._load_point(index, name, lam)
                    loaded += 1
                except StoreCorruptError as e:
                    # a corrupt entry must not kill the whole campaign:
                    # move it aside and recompute the point instead
                    qpath = self.store.quarantine(name)
                    self._say(f"{name}: corrupt store entry ({e}); "
                              f"quarantined to {qpath}, recomputing")
            if point is None:
                if max_points is not None and executed >= max_points:
                    budget_hit = True
                    self._say(f"stopping before {name}: max_points="
                              f"{max_points} executions reached")
                    break
                point = self._execute_point(index, name, lam,
                                            points, hooks)
                executed += 1
            points.append(point)
            fr = self._front(points)
            if self.registry is not None:
                self.registry.gauge(
                    "sweep_front_size",
                    "Points currently on the sweep's Pareto front"
                ).set(len(fr))
            index += 1

        fr = self._front(points)
        return {
            "spec": spec.spec_hash(),
            "points": [p["name"] for p in points],
            "front": [p["name"] for p in fr],
            "executed": executed,
            "loaded": loaded,
            "complete": not budget_hit,
            "steps_executed": sum(p["steps"] for p in points
                                  if not p["from_store"]),
            "steps_saved": sum(p["saved"] for p in points),
        }

    def _front(self, points) -> list[dict]:
        return front_mod.pareto_front(points)

    # -------------------------------------------------------- store hits
    def _load_point(self, index: int, name: str, lam: float) -> dict:
        entry = self.store.entry(name)
        lin = entry["lineage"]
        if lin.get("spec") != self.spec.spec_hash():
            raise StoreError(
                f"store entry {name!r} was produced by a different "
                f"SweepSpec (spec hash {lin.get('spec')} != "
                f"{self.spec.spec_hash()}): use a fresh store or sweep "
                f"name")
        self._count("sweep_points_completed_total",
                    "Sweep points completed, by origin", source="store")
        self._trace(index, "point_loaded", plan=entry["plan"])
        self._say(f"{name}: loaded from store (lam={lam:g}, "
                  f"score={entry['metrics']['score']:.4f})")
        return self._point_record(entry, from_store=True)

    def _point_record(self, entry: dict, from_store: bool) -> dict:
        lin = entry["lineage"]
        return {
            "name": entry["name"],
            "lam": float(lin["lam"]),
            "score": float(entry["metrics"]["score"]),
            "cost": float(entry["costs"][self.spec.cost_model]),
            "plan": entry["plan"],
            "warm": bool(lin["warm"]),
            "steps": int(lin["steps"]),
            "saved": int(lin["saved"]),
            "from_store": from_store,
        }

    # -------------------------------------------------------- executions
    def _execute_point(self, index: int, name: str, lam: float,
                       points, hooks) -> dict:
        spec = self.spec
        warm = bool(spec.warm_start and index > 0)
        parent = points[-1]["plan"] if warm else None
        self._trace(index, "point_started", lam=float(lam), warm=warm)
        self._count("sweep_points_completed_total",
                    "Sweep points completed, by origin", source="run")
        if warm:
            self._count("sweep_warm_starts_total",
                        "Sweep points initialized from the previous "
                        "point's finished state")
        if spec.track == "cnn":
            plan, metrics, costs, steps, saved = self._run_cnn(
                index, lam, warm, hooks)
        else:
            plan, metrics, costs, steps, saved = self._run_lm(
                index, lam, warm, hooks)
        lineage = {
            "kind": "point", "sweep": spec.name,
            "spec": spec.spec_hash(), "index": index, "lam": float(lam),
            "warm": warm, "parent": parent, "track": spec.track,
            "bench": spec.bench, "cost_model": spec.cost_model,
            "steps": steps, "saved": saved,
        }
        entry = self.store.put(plan, name, metrics=metrics, costs=costs,
                               lineage=lineage)
        self._count("sweep_steps_saved_total",
                    "Search/warmup steps avoided by warm-start "
                    "continuation", n=saved)
        self._trace(index, "point_finished", steps=steps,
                    plan=entry["plan"])
        self._say(f"{name}: lam={lam:g} warm={warm} "
                  f"score={metrics['score']:.4f} "
                  f"cost={costs[spec.cost_model]:.1f} steps={steps}")
        return self._point_record(entry, from_store=False)

    # -------------------------------------------------------- cnn track
    def _cnn_handoff_template(self, g):
        folded = cnn.fold_batchnorm(
            g, cnn.init_params(g, jax.random.key(self.spec.seed)))
        gamma = cnn.init_mps_params(g, self.spec.pw,
                                    self.spec.px)["gamma"]
        return {"folded": folded, "gamma": gamma}

    def _run_cnn(self, index: int, lam: float, warm: bool, hooks,
                 gamma_override: Optional[int] = None):
        spec = self.spec
        g, dspec = self._bench()
        comp = api.Compressor(g, dspec, pw=spec.pw, px=spec.px,
                              batch=spec.batch, seed=spec.seed)
        mgr = CheckpointManager(self._ptdir(index, "ckpt"), keep=3)
        gamma_init = None
        if gamma_override is not None:
            # fixed uniform-precision reference: one-hot every group at
            # the requested bits (the paper's w<bits> baselines)
            j = spec.pw.index(gamma_override)
            gamma_init = {
                grp: np.full(gm.shape, -40.0, np.float32)
                for grp, gm in cnn.init_mps_params(
                    g, spec.pw, spec.px)["gamma"].items()}
            for grp in gamma_init:
                gamma_init[grp][..., j] = 40.0
        search_kw = dict(lam=lam, cost_model=spec.cost_model)
        if warm:
            # continuation: theta from the previous point's post-search
            # net (init_folded), gamma from its selection logits, at a
            # reduced search budget -- no warmup phase at all
            handoff = self._load_handoff(index - 1,
                                         self._cnn_handoff_template(g))
            phases = [api.JointSearch(steps=spec.warm_search(),
                                      gamma_init=handoff["gamma"],
                                      **search_kw),
                      api.Finetune(steps=spec.finetune_steps)]
            res = comp.run(phases, hooks=hooks,
                           init_folded=handoff["folded"], checkpoint=mgr,
                           checkpoint_every=spec.checkpoint_every,
                           registry=self.registry)
            phase_steps = {"search": spec.warm_search(),
                           "finetune": spec.finetune_steps}
            saved = spec.warmup_steps + (spec.search_steps
                                         - spec.warm_search())
        else:
            phases = [api.Warmup(steps=spec.warmup_steps),
                      api.JointSearch(steps=spec.search_steps,
                                      gamma_init=gamma_init, **search_kw),
                      api.Finetune(steps=spec.finetune_steps)]
            res = comp.run(phases, hooks=hooks, checkpoint=mgr,
                           checkpoint_every=spec.checkpoint_every,
                           registry=self.registry)
            phase_steps = {"warmup": spec.warmup_steps,
                           "search": spec.search_steps,
                           "finetune": spec.finetune_steps}
            saved = 0
        for phase, n in phase_steps.items():
            if n:
                self._count("sweep_search_steps_total",
                            "Training steps executed by sweep points, "
                            "per phase", n=n, phase=phase)
        self._save_handoff(index, {"folded": res.folded,
                                   "gamma": res.mps_params["gamma"]})
        geoms = cnn.cost_geoms(g)
        costs = {"size": front_mod.plan_cost(geoms, res.plan, "size")}
        if spec.cost_model != "size":
            costs[spec.cost_model] = front_mod.plan_cost(
                geoms, res.plan, spec.cost_model)
        metrics = {
            "score": float(res.acc_final),
            "acc_final": float(res.acc_final),
            "acc_float": float(res.acc_float),
            "size_bytes": float(res.size_bytes),
            "prune_fraction": float(res.prune_fraction),
        }
        return (res.plan, metrics, costs,
                sum(phase_steps.values()), saved)

    # --------------------------------------------------------- lm track
    def _run_lm(self, index: int, lam: float, warm: bool, hooks):
        spec = self.spec
        cfg = configs_registry.get(spec.bench)
        fresh = lm.init_params(cfg, jax.random.key(spec.seed),
                               mps_on=True)
        params = fresh
        if warm:
            params = self._load_handoff(index - 1,
                                        {"params": fresh})["params"]
        opt = optimizers.make_optimizer(cfg.optimizer, spec.lm_lr)
        state = {"params": params, "opt": opt.init(params)}
        # normalize lambda by the expected size at the (deterministic)
        # fresh init so sweep lambdas are O(1) on both tracks; evaluated
        # on near-hard logits like JointSearch._cost_scale
        r_max = float(lm.mps_size_cost(
            cfg, fresh, mps.SearchCtx(sampling.SOFTMAX, 0.01)))
        step_fn = jax.jit(steps_lib.make_train_step(
            cfg, opt, search=True, lam=lam / max(r_max, 1e-9)))
        steps = spec.warm_search() if warm else spec.search_steps
        saved = spec.search_steps - steps if warm else 0

        mgr = CheckpointManager(self._ptdir(index, "ckpt"), keep=2)
        start = 0
        restored, meta = mgr.restore_latest(state)
        if restored is not None:
            state, start = restored, int(meta["step"]) + 1
            self._say(f"{self.point_name(index)}: resumed from "
                      f"step {meta['step']}")
        for step in range(start, steps):
            # fold_in stream: lm_batch folds the step index into the
            # seed, so resume replays the identical batches
            batch = synthetic.lm_batch(cfg.vocab, spec.seq + 1,
                                       spec.batch, step, seed=spec.seed)
            p, o, loss = step_fn(state["params"], state["opt"], batch,
                                 np.int64(step))
            state = {"params": p, "opt": o}
            for h in hooks:
                h.on_step(_LM_PHASE, None, step,
                          {"loss": float(loss)}, state)
            if self.registry is not None:
                self.registry.emit_phase_point(
                    "lm_search", step, {"loss": float(loss)})
            if spec.checkpoint_every and (step + 1) \
                    % spec.checkpoint_every == 0 and step + 1 < steps:
                mgr.save(step, state, blocking=True,
                         metadata={"step": step})
        self._count("sweep_search_steps_total",
                    "Training steps executed by sweep points, per phase",
                    n=max(steps - start, 0), phase="lm_search")
        self._save_handoff(index, {"params": state["params"]})

        # score = -eval loss with near-hard selections on held-out
        # deterministic batches (disjoint step ids from training)
        eval_ctx = mps.SearchCtx(sampling.SOFTMAX, 0.02)

        @jax.jit
        def eval_fn(p, b):
            return lm.loss_fn(cfg, p, b, ctx=eval_ctx, lam=0.0)

        losses = []
        for j in range(spec.eval_batches):
            batch = synthetic.lm_batch(cfg.vocab, spec.seq + 1,
                                       spec.batch, 10_000_000 + j,
                                       seed=spec.seed)
            losses.append(float(eval_fn(state["params"], batch)))
        eval_loss = float(np.mean(losses))

        plan = lm.extract_plan(cfg, state["params"], px=spec.px,
                               meta={"lam": float(lam),
                                     "sweep": spec.name,
                                     "steps": steps})
        size = self._lm_plan_size(cfg, state["params"], plan)
        metrics = {"score": -eval_loss, "eval_loss": eval_loss}
        return plan, metrics, {"size": size}, steps, saved

    @staticmethod
    def _lm_plan_size(cfg, params, plan) -> float:
        """Discrete size (bytes) of an LM plan: sum over groups of
        ``sum(bits) * C_in / 8`` (the discrete face of
        ``lm.mps_size_cost``)."""
        groups = lm.serve_weight_groups(cfg, params)
        total = 0.0
        for grp, bits in plan.channel_bits.items():
            total += float(np.sum(np.asarray(bits))) \
                * groups[grp].shape[1] / 8.0
        return total

    # ---------------------------------------------------------- baselines
    def baseline(self, bits: int, hooks=()) -> dict:
        """Train and store a fixed uniform-``bits`` reference (cnn track):
        the denominator of the paper's iso-accuracy size reductions."""
        spec = self.spec
        if spec.track != "cnn":
            raise ValueError("uniform-precision baselines are cnn-track "
                             "only")
        if bits not in spec.pw:
            raise ValueError(f"baseline bits {bits} not in pw={spec.pw}")
        name = f"{spec.name}.w{bits}ref"
        if self.store.has(name):
            entry = self.store.entry(name)
            if entry["lineage"].get("spec") == spec.spec_hash():
                self._say(f"{name}: loaded from store")
                return entry
        # baselines run cold with lam=0 and a pinned one-hot gamma; use
        # an index far past the sweep points so workdirs never collide
        index = 1000 + spec.pw.index(bits)
        plan, metrics, costs, steps, _ = self._run_cnn(
            index, 0.0, warm=False, hooks=hooks, gamma_override=bits)
        lineage = {"kind": "baseline", "sweep": spec.name,
                   "spec": spec.spec_hash(), "index": index, "lam": 0.0,
                   "warm": False, "parent": None, "track": spec.track,
                   "bench": spec.bench, "cost_model": spec.cost_model,
                   "bits": int(bits), "steps": steps, "saved": 0}
        return self.store.put(plan, name, metrics=metrics, costs=costs,
                              lineage=lineage)

    def iso_report(self, baseline_bits=(8, 2)) -> dict:
        """Iso-accuracy cost-reduction report of the stored front against
        the stored ``w<bits>ref`` baselines (run :meth:`baseline`
        first)."""
        spec = self.spec
        pts = self.store.query(kind="point", sweep=spec.name)
        fr = self.store.front(pts, cost_key=spec.cost_model)
        baselines = {}
        for bits in baseline_bits:
            entry = self.store.entry(f"{spec.name}.w{bits}ref")
            baselines[f"w{bits}"] = (entry["metrics"]["score"],
                                     entry["costs"][spec.cost_model])
        return front_mod.iso_accuracy_report(
            fr, baselines,
            score=lambda e: e["metrics"]["score"],
            cost=lambda e: e["costs"][spec.cost_model])
