"""Content-addressed on-disk store of compression plans + eval metadata.

A :class:`PlanStore` is the durable half of a Pareto sweep: every finished
search point lands here as (a) the :class:`~repro.api.plan.CompressionPlan`
itself, written once under its content hash, and (b) a small named *entry*
JSON carrying the point's evaluation metrics, its discrete cost per
registered cost model, and its sweep lineage (which spec, which lambda,
warm-started from which parent).  Layout::

    <root>/plans/<hash>.npz     # CompressionPlan arrays (written once)
    <root>/plans/<hash>.json    # CompressionPlan scalars + provenance
    <root>/entries/<name>.json  # metrics + costs + lineage -> plan hash

Plans are deduplicated by :func:`plan_hash` -- a blake2b digest over
everything that affects deployment (pw/px, per-group channel bits +
permutations, act bits, alphas) and nothing that doesn't (``meta`` is
excluded, so two lambdas that converge to the same assignment share one
plan file).  Entry JSONs are written atomically (tmp + rename) with sorted
keys and no timestamps, so a killed-and-resumed sweep that reproduces the
same points produces byte-identical entries.

Every read path raises :class:`StoreError` with a message naming the file
and the failure mode (missing ``.npz`` beside its ``.json``, truncated
arrays, content-hash mismatch) instead of leaking ``KeyError`` /
``zipfile.BadZipFile`` internals.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile

import numpy as np

from repro.api.plan import CompressionPlan

ENTRY_VERSION = 1


class StoreError(RuntimeError):
    """A PlanStore read/write failed in a way the caller should see."""


class StoreCorruptError(StoreError):
    """A stored file exists but cannot be trusted: unparsable entry
    JSON, missing/truncated plan arrays, or a content-hash mismatch.
    Distinct from plain :class:`StoreError` (missing entries, invalid
    names, spec mismatches) so resume paths can quarantine-and-recompute
    corruption without masking real usage errors."""


def plan_hash(plan: CompressionPlan) -> str:
    """Content hash of everything that affects a plan's deployment.

    Matches :meth:`CompressionPlan.equals`: pw/px, per-group channel bits
    and Fig. 3 permutations, activation bits and PACT alphas.  ``meta``
    (provenance) is deliberately excluded so identical assignments found
    by different sweep points share one stored plan.
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(f"pw={tuple(plan.pw)};px={tuple(plan.px)}".encode())
    for grp in sorted(plan.channel_bits):
        h.update(grp.encode())
        h.update(np.asarray(plan.channel_bits[grp], np.int64).tobytes())
        h.update(np.asarray(plan.permutations[grp], np.int64).tobytes())
    for name in sorted(plan.act_bits):
        h.update(f"{name}={int(plan.act_bits[name])}".encode())
    for name in sorted(plan.alphas):
        h.update(f"{name}={float(plan.alphas[name])!r}".encode())
    return h.hexdigest()


def _atomic_write_text(path: str, text: str):
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


class PlanStore:
    """List/query/load API over the on-disk layout above."""

    def __init__(self, root: str):
        self.root = root
        self.plans_dir = os.path.join(root, "plans")
        self.entries_dir = os.path.join(root, "entries")

    # ----------------------------------------------------------- writing
    def put(self, plan: CompressionPlan, name: str, *, metrics=None,
            costs=None, lineage=None) -> dict:
        """Store ``plan`` under its content hash and write/overwrite the
        named entry pointing at it.  Returns the entry dict."""
        if "/" in name or not name:
            raise StoreError(f"invalid entry name {name!r}")
        os.makedirs(self.plans_dir, exist_ok=True)
        os.makedirs(self.entries_dir, exist_ok=True)
        h = plan_hash(plan)
        stem = os.path.join(self.plans_dir, h)
        # content-addressed: an already-stored plan is never rewritten
        if not (os.path.exists(stem + ".npz")
                and os.path.exists(stem + ".json")):
            plan.save(stem)
        entry = {
            "entry_version": ENTRY_VERSION,
            "name": name,
            "plan": h,
            "metrics": dict(metrics or {}),
            "costs": dict(costs or {}),
            "lineage": dict(lineage or {}),
        }
        _atomic_write_text(self._entry_path(name),
                           json.dumps(entry, indent=2, sort_keys=True)
                           + "\n")
        return entry

    # ----------------------------------------------------------- reading
    def _entry_path(self, name: str) -> str:
        return os.path.join(self.entries_dir, f"{name}.json")

    def names(self) -> list[str]:
        if not os.path.isdir(self.entries_dir):
            return []
        return sorted(f[:-5] for f in os.listdir(self.entries_dir)
                      if f.endswith(".json")
                      and not f.endswith(".quarantined.json"))

    def has(self, name: str) -> bool:
        return os.path.exists(self._entry_path(name))

    def entry(self, name: str) -> dict:
        path = self._entry_path(name)
        if not os.path.exists(path):
            raise StoreError(f"no entry {name!r} in store {self.root}")
        try:
            with open(path) as f:
                entry = json.load(f)
        except (json.JSONDecodeError, OSError) as e:
            raise StoreCorruptError(
                f"entry {name!r} is corrupt ({path}): {e}") from e
        if not isinstance(entry, dict):
            raise StoreCorruptError(
                f"entry {name!r} is corrupt ({path}): not a JSON object")
        for key in ("name", "plan", "metrics", "costs", "lineage"):
            if key not in entry:
                raise StoreCorruptError(
                    f"entry {name!r} is corrupt ({path}): "
                    f"missing field {key!r}")
        return entry

    def entries(self) -> list[dict]:
        return [self.entry(n) for n in self.names()]

    def get(self, h: str) -> CompressionPlan:
        """Load a plan by content hash, verifying integrity."""
        stem = os.path.join(self.plans_dir, h)
        if not os.path.exists(stem + ".json"):
            raise StoreError(f"no plan {h} in store {self.root}")
        if not os.path.exists(stem + ".npz"):
            raise StoreCorruptError(
                f"plan {h} is missing its .npz array file beside "
                f"{stem}.json (partial copy or interrupted write?)")
        try:
            plan = CompressionPlan.load(stem)
        except Exception as e:
            raise StoreCorruptError(
                f"plan {h} is corrupt or truncated ({stem}.npz): "
                f"{e}") from e
        actual = plan_hash(plan)
        if actual != h:
            raise StoreCorruptError(
                f"plan {h} failed its content-hash check (stored arrays "
                f"hash to {actual}): store was modified or truncated")
        return plan

    def load(self, name: str) -> CompressionPlan:
        """Load the plan a named entry points at."""
        return self.get(self.entry(name)["plan"])

    # ----------------------------------------------------------- queries
    def query(self, **filters) -> list[dict]:
        """Entries whose top-level or ``lineage`` fields equal every
        filter value, e.g. ``query(sweep="pareto", warm=True)``."""
        out = []
        for entry in self.entries():
            ok = True
            for key, want in filters.items():
                have = entry.get(key, entry["lineage"].get(key))
                if have != want:
                    ok = False
                    break
            if ok:
                out.append(entry)
        return out

    def front(self, entries=None, *, score_key: str = "score",
              cost_key: str = "size") -> list[dict]:
        """Pareto front (max score, min cost) over ``entries`` (default:
        all entries carrying both keys), sorted by cost."""
        from repro.sweep import front as front_mod
        if entries is None:
            entries = self.entries()
        pts = [e for e in entries
               if score_key in e["metrics"] and cost_key in e["costs"]]
        return front_mod.pareto_front(
            pts, score=lambda e: e["metrics"][score_key],
            cost=lambda e: e["costs"][cost_key])

    def quarantine(self, name: str) -> str:
        """Move a named entry aside as ``<name>.quarantined.json`` (an
        existing quarantine file for the name is overwritten).  The name
        disappears from :meth:`names`/:meth:`has`, so a resuming sweep
        recomputes the point; the bad bytes stay on disk for forensics.
        Returns the quarantine path."""
        path = self._entry_path(name)
        if not os.path.exists(path):
            raise StoreError(f"no entry {name!r} in store {self.root}")
        qpath = os.path.join(self.entries_dir,
                             f"{name}.quarantined.json")
        os.replace(path, qpath)
        return qpath

    def verify(self, repair: bool = False) -> list[str]:
        """Integrity sweep: every entry parses and its plan loads with a
        matching content hash.  Returns problem strings (empty = clean).
        ``repair=True`` additionally quarantines each corrupt entry
        (:meth:`quarantine`) so subsequent reads see a clean store."""
        problems = []
        for name in self.names():
            try:
                self.load(name)
            except StoreCorruptError as e:
                msg = str(e)
                if repair:
                    msg += f" [quarantined -> {self.quarantine(name)}]"
                problems.append(msg)
            except StoreError as e:
                problems.append(str(e))
        return problems
