"""Pareto-front maintenance for accuracy-vs-cost sweeps.

Points are arbitrary objects read through ``score``/``cost`` accessor
callables (dicts with ``"score"``/``"cost"`` keys by default).  Score is
maximized, cost minimized.  Besides dominance filtering this module holds
the two sweep-side decision rules:

* :func:`next_lambda` -- adaptive bisection: insert the next
  regularization strength into the largest normalized accuracy-vs-cost gap
  between adjacent front points (geometric mean of the bounding lambdas,
  matching the log-scale at which lambda acts);
* :func:`iso_accuracy_report` -- the paper's headline framing: the size
  reduction the front achieves at no accuracy loss relative to fixed
  uniform-precision baselines (abstract: 47.50% over 8-bit, 69.54% over
  2-bit).
"""
from __future__ import annotations

import math

import numpy as np


def _score(p):
    return p["score"]


def _cost(p):
    return p["cost"]


def _lam(p):
    return p["lam"]


def dominates(a, b, *, score=_score, cost=_cost) -> bool:
    """True if ``a`` is at least as good as ``b`` on both axes and
    strictly better on one."""
    sa, sb = score(a), score(b)
    ca, cb = cost(a), cost(b)
    return sa >= sb and ca <= cb and (sa > sb or ca < cb)


def pareto_front(points, *, score=_score, cost=_cost) -> list:
    """Non-dominated subset, sorted by cost ascending.

    Duplicate (score, cost) pairs keep only the first point in input
    order, so the front is deterministic for deterministic sweeps.
    """
    order = sorted(range(len(points)),
                   key=lambda i: (cost(points[i]), -score(points[i]), i))
    front, best_score, seen = [], -math.inf, set()
    for i in order:
        p = points[i]
        key = (float(cost(p)), float(score(p)))
        if score(p) > best_score and key not in seen:
            front.append(p)
            best_score = score(p)
            seen.add(key)
    return front


def largest_gap(front, *, score=_score, cost=_cost):
    """(index, gap) of the widest normalized Euclidean gap between
    adjacent points of a cost-sorted front; ``(None, 0.0)`` for fronts
    with fewer than two points."""
    if len(front) < 2:
        return None, 0.0
    scores = np.asarray([float(score(p)) for p in front])
    costs = np.asarray([float(cost(p)) for p in front])
    s_range = max(float(scores.max() - scores.min()), 1e-12)
    c_range = max(float(costs.max() - costs.min()), 1e-12)
    best_i, best_gap = None, 0.0
    for i in range(len(front) - 1):
        ds = (scores[i + 1] - scores[i]) / s_range
        dc = (costs[i + 1] - costs[i]) / c_range
        gap = math.hypot(ds, dc)
        if gap > best_gap:
            best_i, best_gap = i, gap
    return best_i, best_gap


def next_lambda(front, *, lam=_lam, score=_score, cost=_cost,
                rel_tol: float = 1e-6):
    """Lambda to try next: the geometric mean of the lambdas bounding the
    front's largest accuracy-vs-cost gap (lambda acts on a log scale).

    Returns None when the front has fewer than two points or the
    bisected lambda collapses onto an existing one (within ``rel_tol``
    relative distance) -- the sweep's convergence signal.
    """
    i, _ = largest_gap(front, score=score, cost=cost)
    if i is None:
        return None
    la, lb = float(lam(front[i])), float(lam(front[i + 1]))
    if la <= 0.0 or lb <= 0.0:
        new = 0.5 * (la + lb)
    else:
        new = math.sqrt(la * lb)
    for p in front:
        ref = max(abs(float(lam(p))), 1e-12)
        if abs(new - float(lam(p))) <= rel_tol * ref:
            return None
    return new


# ---------------------------------------------------------------------------
# paper-style iso-accuracy reporting
# ---------------------------------------------------------------------------

def iso_accuracy_reduction(front, baseline_score, baseline_cost, *,
                           score=_score, cost=_cost):
    """Largest relative cost reduction any front point achieves while
    matching or beating ``baseline_score`` (paper Sec. 5 framing, e.g.
    '47.50% size reduction over the 8-bit model at iso-accuracy').

    Returns a fraction in [0, 1] (negative if even the qualifying points
    cost more), or None when no front point reaches the baseline score.
    """
    if baseline_cost <= 0:
        raise ValueError(f"baseline_cost must be positive, "
                         f"got {baseline_cost}")
    qualifying = [cost(p) for p in front if score(p) >= baseline_score]
    if not qualifying:
        return None
    return 1.0 - min(qualifying) / float(baseline_cost)


def iso_accuracy_report(front, baselines: dict, *, score=_score,
                        cost=_cost) -> dict:
    """Per-baseline iso-accuracy summary.

    ``baselines`` maps a label (e.g. ``"w8"``) to ``(score, cost)`` of a
    fixed uniform-precision reference.  Each row reports the baseline
    point, the best qualifying front cost, and the reduction fraction.
    """
    report = {}
    for label, (b_score, b_cost) in baselines.items():
        red = iso_accuracy_reduction(front, b_score, b_cost,
                                     score=score, cost=cost)
        report[label] = {
            "baseline_score": float(b_score),
            "baseline_cost": float(b_cost),
            "reduction": None if red is None else float(red),
            "reduction_pct": None if red is None else
            round(100.0 * red, 2),
        }
    return report


# ---------------------------------------------------------------------------
# discrete plan costs (the store's per-cost-model numbers)
# ---------------------------------------------------------------------------

def plan_cost(geoms, plan, model) -> float:
    """Discrete deployment cost of a CNN-track plan under a registered
    cost model, with C_in shrunk by the producer group's pruning (the
    same accounting ``discretize.assignment_size_bytes`` uses)."""
    from repro.api import cost_models
    cm = cost_models.get_cost_model(model)
    kept = {grp: int(np.sum(np.asarray(b) > 0))
            for grp, b in plan.channel_bits.items()}
    total = 0.0
    for gm in geoms:
        bits = np.asarray(plan.channel_bits[gm.gamma])
        cin_eff = kept.get(gm.in_gamma, gm.cin) if gm.in_gamma else gm.cin
        total += float(cm.discrete(gm, bits, cin_eff))
    return total


def uniform_cost(geoms, bits: int, model="size") -> float:
    """Discrete cost of a uniform fixed-precision assignment (no pruning)
    -- the denominator of the paper's iso-accuracy reductions."""
    from repro.api import cost_models
    cm = cost_models.get_cost_model(model)
    total = 0.0
    for gm in geoms:
        full = np.full((gm.cout,), int(bits), np.int64)
        total += float(cm.discrete(gm, full, gm.cin))
    return total
