"""Multi-replica serving: the Pareto front, operationalized.

A :class:`Fleet` owns N :class:`~repro.serve.engine.InferenceServer`
replicas, each bound to one *plan tier* -- points on the accuracy/cost
Pareto front the compression search produces (float / 8-bit / mixed /
2-bit from the same run).  A pluggable router (see
:mod:`repro.fleet.router`) picks the replica per request; the
``pareto_degrade`` policy routes to the highest-quality tier whose
predicted completion keeps the request inside its deadline, degrading
to lower-bit replicas only under pressure and recovering when load
drops.

**Virtual time.**  The fleet advances a modeled clock in milliseconds:
each tier declares a per-decode-step cost ``step_ms`` (derived from its
plan's mean channel bits -- fewer bits, cheaper steps, the paper's cost
axis), and one engine ``step()`` advances that replica by ``step_ms``.
Token *content* is real -- every replica runs its actual jitted decode,
so a request's stream is byte-identical to a solo server with that
replica's plan -- while *latency* is modeled, which makes deadline
behavior deterministic and machine-independent (on an interpret-mode
CPU host the wall-clock cost ordering of quantized plans is meaningless
anyway).  Deadline admission, timeout cancellation (freeing cache
pages, ``timeout`` lifecycle event), bounded retry and preemption
budgets, and the SLO report in :mod:`repro.fleet.loadgen` all work in
this virtual clock.

**Faults and failover.**  The fleet accepts a
:class:`~repro.chaos.ChaosInjector` whose schedule it replays on the
same virtual clock (``--chaos`` in ``launch.fleet``): replica crashes
and NaN-plan quarantines *strike* a replica -- every in-flight request
is cancelled with a ``crashed``/``quarantined`` terminal, the session
closed, and (with ``failover=True``) recovered recompute-style onto
survivors, front-of-queue so FCFS seniority holds.  Because a request's
sampling stream is a pure function of ``(seed, uid, token_index)``, the
recovered stream is byte-identical to the fault-free run.  A
:class:`~repro.fleet.health.HealthMonitor` detects failures
observationally (dead heartbeat, watchdog step spacing, pool
starvation) and gates struck replicas behind a warm-up probe before
routers see them again.  Timeout/preemption retries back off
exponentially (bounded, virtual clock) before re-dispatch.

Observability: replicas share one :class:`MetricsRegistry` (fleet
counters + per-replica queue series keyed by the ``replica`` label) and
each carries its own :class:`RequestTracer`; :meth:`Fleet.trace_events`
merges the per-replica traces into one globally-ordered stream with a
``replica`` field per event.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import time
from typing import Optional

import numpy as np

from repro.obs import MetricsRegistry, Observability
from repro.chaos.inject import poison_params
from repro.fleet.health import HealthMonitor
from repro.fleet.router import make_router
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import Request

# warm-up probe uids live far above any realistic request uid so they
# never collide with routed traffic in a replica's session
PROBE_UID_BASE = 1_000_000_000


# ---------------------------------------------------------------------------
# tiers: plan -> (cost, quality) point
# ---------------------------------------------------------------------------

def plan_mean_bits(plan) -> float:
    """Mean per-channel bit-width across every group of a plan
    (pruned channels count as 0); float serving (``plan=None``) is 16."""
    if plan is None:
        return 16.0
    total = n = 0.0
    for bits in plan.channel_bits.values():
        b = np.asarray(bits, np.float64)
        total += float(b.sum())
        n += b.size
    return total / n if n else 16.0


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One Pareto-front point the fleet serves.

    ``step_ms`` is the modeled cost of one batched decode step on this
    tier's replica; ``quality`` orders tiers for the degrade policy
    (higher = better, mean channel bits by default)."""

    name: str
    plan: object                   # CompressionPlan or None (float)
    step_ms: float
    quality: float


def tier_from_plan(name: str, plan, base_step_ms: float = 8.0) -> TierSpec:
    """Model a tier's decode-step cost from its plan's mean bits.

    ``step_ms = base * (0.25 + 0.75 * bits/16)``: a float replica costs
    ``base`` per step, a fully 2-bit one ~0.34x of it -- a fixed
    scheduling/launch floor plus a weight-traffic term linear in bits,
    the same shape as the paper's size-proportional cost model."""
    bits = plan_mean_bits(plan)
    return TierSpec(name=name, plan=plan,
                    step_ms=base_step_ms * (0.25 + 0.75 * bits / 16.0),
                    quality=bits)


# ---------------------------------------------------------------------------
# requests + per-request accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FleetRequest:
    """One fleet-level request: an engine Request plus arrival time,
    deadline and retry budgets (all in virtual milliseconds)."""

    request: object                   # repro.serve.scheduler.Request
    arrival_ms: float = 0.0
    deadline_ms: Optional[float] = None   # relative; None = no SLO
    retry_budget: int = 1             # re-dispatches after timeout/evict
    preempt_budget: int = 3           # preemptions tolerated per attempt
    retries_used: int = 0

    @property
    def uid(self) -> int:
        return self.request.uid


@dataclasses.dataclass
class Attempt:
    """One dispatch of a request onto a replica."""

    tier: str
    t_start: float
    cause: str = "arrival"    # arrival | retry:timeout | retry:preempt
    #                           | recovered:crashed | recovered:quarantined
    degraded: bool = False
    preempt_base: int = 0         # replica's preempt count at dispatch


@dataclasses.dataclass
class RequestRecord:
    """Everything the fleet knows about one request's journey."""

    fr: FleetRequest
    # per-attempt cancellation deadline: refreshed on retry so the
    # retry is not cancelled at birth...
    deadline_abs: Optional[float] = None
    # ...but the SLO is judged against the ORIGINAL promise (arrival +
    # deadline_ms): a timeout-retry that lands late is still a miss
    sla_deadline_abs: Optional[float] = None
    attempts: list = dataclasses.field(default_factory=list)
    # queued|running|retrying|finished|timeout|cancelled|shed, plus the
    # fault terminals crashed|quarantined when failover is off
    status: str = "queued"
    replica: Optional[str] = None    # current / final replica
    first_token_ms: Optional[float] = None
    finish_ms: Optional[float] = None
    tokens: Optional[np.ndarray] = None
    degraded: bool = False           # ever routed below the top tier

    @property
    def deadline_met(self) -> bool:
        """Finished, and inside the deadline (vacuously true without
        one).  Shed / timed-out / cancelled requests miss by definition
        when they carry a deadline."""
        if self.status != "finished":
            return False
        return (self.sla_deadline_abs is None
                or self.finish_ms <= self.sla_deadline_abs + 1e-9)


@dataclasses.dataclass
class Replica:
    """A tier-bound engine plus its virtual-clock + fault state."""

    tier: TierSpec
    server: object                 # InferenceServer
    busy_until: float = 0.0        # virtual ms when its current step ends
    down: bool = False             # session dead (crash / quarantine)
    down_cause: str = ""           # "crashed" | "quarantined"
    slow_factor: float = 1.0       # active slow-fault step multiplier
    nan_undo: object = None        # undo closure of an active nan_plan
    probe_uid: Optional[int] = None   # in-flight warm-up probe

    def heartbeat(self) -> Optional[dict]:
        """Host-side liveness signal the health monitor polls: the
        engine's ``load_report()``, or None when the session is dead.
        The monitor infers ``down`` from this -- faults are never
        reported to it directly."""
        if self.down:
            return None
        return self.server.load_report()


# ---------------------------------------------------------------------------
# the fleet
# ---------------------------------------------------------------------------

class Fleet:
    """N tier-bound replicas behind one router.

    ``replicas`` is a list of ``(TierSpec, InferenceServer)`` pairs; the
    fleet attaches a shared-registry Observability bundle to each (one
    metric namespace, per-replica tracers).  ``policy`` is a router name
    (``round_robin`` / ``least_loaded`` / ``pareto_degrade`` /
    ``static:<tier>``); :meth:`set_policy` swaps it between runs --
    replicas and their compiled decode paths are reused, which is how
    the bench compares policies on identical fleets.

    ``chaos`` is an optional :class:`~repro.chaos.ChaosInjector` whose
    schedule the run loop replays (one injector per run -- its events
    deliver once).  ``health`` is the :class:`HealthMonitor` routers
    consult (a default is built on the shared registry).
    ``failover=False`` turns crash recovery off: a struck replica's
    requests die with the fault terminal -- the bench's ablation arm.
    ``retry_backoff_ms``/``retry_backoff_cap_ms`` bound the exponential
    backoff applied to timeout/preemption retries (virtual clock).
    """

    def __init__(self, replicas, *, policy: str = "round_robin",
                 metrics: bool = True, chaos=None, health=None,
                 failover: bool = True, retry_backoff_ms: float = 25.0,
                 retry_backoff_cap_ms: float = 400.0):
        if not replicas:
            raise ValueError("a fleet needs at least one replica")
        self.registry = MetricsRegistry(enabled=metrics)
        self.replicas: list[Replica] = []
        for tier, server in replicas:
            server.attach_obs(Observability(registry=self.registry,
                                            replica=tier.name))
            self.replicas.append(Replica(tier=tier, server=server))
        names = [r.tier.name for r in self.replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names: {names}")
        self.chaos = chaos
        self.health = (health if health is not None
                       else HealthMonitor(registry=self.registry))
        self.failover = bool(failover)
        self.retry_backoff_ms = float(retry_backoff_ms)
        self.retry_backoff_cap_ms = float(retry_backoff_cap_ms)
        self._retries: list = []    # (due_ms, RequestRecord, why, delay)
        self._probe_seq = 0
        self.records: dict[int, RequestRecord] = {}
        self.now = 0.0
        self.set_policy(policy)

    def set_policy(self, policy: str):
        self.policy = policy
        self.router = make_router(policy, self)

    def replica_by_name(self, name: str) -> Replica:
        for rep in self.replicas:
            if rep.tier.name == name:
                return rep
        raise KeyError(f"no replica {name!r} "
                       f"(have {[r.tier.name for r in self.replicas]})")

    # ------------------------------------------------------------- metrics
    def _count(self, name: str, help_: str, n: int = 1, **labels):
        if self.registry.enabled:
            self.registry.counter(
                name, help_,
                labels=tuple(labels) if labels else ()).inc(n, **labels)

    # ------------------------------------------------------------ the run
    def run(self, trace) -> dict:
        """Drive an arrival trace (iterable of :class:`FleetRequest`)
        to completion; returns ``{uid: RequestRecord}``.

        Virtual-time event loop: apply due chaos events, deliver
        arrivals due at ``now``, re-dispatch retries whose backoff
        expired, scan deadlines (timeout-cancel + backoff retry),
        observe replica health (issuing warm-up probes to recovering
        replicas), step every live replica whose previous step has
        finished, then jump ``now`` to the next event (arrival, step
        completion, retry due, or chaos event -- the clock lands *on*
        fault times, never over them).
        """
        for rep in self.replicas:
            rep.server.begin()
            rep.busy_until = 0.0
            rep.down = False
            rep.down_cause = ""
            rep.slow_factor = 1.0
            rep.nan_undo = None
            rep.probe_uid = None
        self._retries = []
        self._probe_seq = 0
        self.health.start([r.tier.name for r in self.replicas])
        t0 = time.perf_counter()
        for rep in self.replicas:       # one time origin -> merged trace
            tracer = rep.server.obs.tracer
            if tracer is not None:
                tracer.rebase(t0)

        pending = collections.deque(
            sorted(trace, key=lambda fr: (fr.arrival_ms, fr.uid)))
        records: dict[int, RequestRecord] = {}
        now = 0.0
        if pending:
            now = pending[0].arrival_ms
        while (pending or self._retries
               or any(rep.server.has_work for rep in self.replicas)):
            self._apply_chaos(now, records)
            while pending and pending[0].arrival_ms <= now + 1e-9:
                fr = pending.popleft()
                if fr.uid in records:
                    raise ValueError(f"duplicate fleet uid {fr.uid}")
                self._count("fleet_requests_total",
                            "Requests offered to the fleet")
                self._dispatch(fr, now, records, cause="arrival")
            self._retries.sort(key=lambda r: (r[0], r[1].fr.uid))
            while self._retries and self._retries[0][0] <= now + 1e-9:
                _, rec, why, delay = self._retries.pop(0)
                self._dispatch(rec.fr, now, records,
                               cause=f"retry:{why}",
                               trace_extra={"retry_delay_ms": delay})
            self._scan_deadlines(now, records)
            for rep in self.replicas:
                self.health.observe(rep, now)
                if (rep.probe_uid is None and not rep.down
                        and self.health.state(rep.tier.name)
                        == "warming"):
                    self._submit_probe(rep, now)
            for rep in self.replicas:
                if rep.down:
                    continue
                if rep.server.has_work and rep.busy_until <= now + 1e-9:
                    res = rep.server.step()
                    rep.busy_until = (now + rep.tier.step_ms
                                      * rep.slow_factor)
                    if res.nan:
                        # poisoned logits at the sampling boundary: the
                        # step's tokens were discarded; quarantine
                        self._strike(rep, now, records, "quarantined")
                    else:
                        self._after_step(rep, res, rep.busy_until,
                                         records, now)
            times = [pending[0].arrival_ms] if pending else []
            times.extend(due for due, *_ in self._retries)
            for rep in self.replicas:
                if not rep.down and rep.server.has_work:
                    times.append(rep.busy_until)
            if self.chaos is not None:
                # chaos alone does not keep the run alive, but while
                # work remains the clock must land ON fault times
                nt = self.chaos.next_time()
                if nt is not None and times:
                    times.append(nt)
            if not times:
                break
            now = max(now, min(times))

        self.now = now
        for rep in self.replicas:
            if rep.server._sched is not None:
                rep.server.end()
        self.records = records
        return records

    # -------------------------------------------------------- dispatching
    def _dispatch(self, fr: FleetRequest, now: float, records: dict,
                  cause: str, *, front: bool = False,
                  trace_extra: Optional[dict] = None):
        rec = records.get(fr.uid)
        if rec is None:
            rec = records[fr.uid] = RequestRecord(fr=fr)
        rep, degraded = self.router.route(fr, now)
        if rep is None:
            rec.status = "shed"
            rec.finish_ms = now
            self._count("fleet_shed_total",
                        "Requests rejected at routing (no tier could "
                        "meet the deadline)")
            return
        rep.server.submit(fr.request, front=front,
                          trace_extra=trace_extra)
        rec.status = "running"
        rec.replica = rep.tier.name
        rec.first_token_ms = None          # per-attempt: retries restart
        rec.deadline_abs = (None if fr.deadline_ms is None
                            else now + fr.deadline_ms)
        if rec.sla_deadline_abs is None and fr.deadline_ms is not None:
            rec.sla_deadline_abs = fr.arrival_ms + fr.deadline_ms
        rec.degraded = rec.degraded or degraded
        rec.attempts.append(Attempt(
            tier=rep.tier.name, t_start=now, cause=cause,
            degraded=degraded,
            preempt_base=rep.server.preemption_counts.get(fr.uid, 0)))
        self._count("fleet_routed_total",
                    "Requests dispatched to a replica, by tier",
                    tier=rep.tier.name)
        if degraded:
            self._count("fleet_degraded_total",
                        "Dispatches below the top-quality tier under "
                        "deadline pressure")

    # ----------------------------------------------------------- deadlines
    def _scan_deadlines(self, now: float, records: dict):
        for uid, rec in records.items():
            if rec.status != "running" or rec.deadline_abs is None:
                continue
            if now <= rec.deadline_abs + 1e-9:
                continue
            rep = self.replica_by_name(rec.replica)
            toks = rep.server.cancel(uid, reason="timeout")
            if toks is None:       # finished in the same instant
                continue
            self._count("fleet_timeouts_total",
                        "Deadline-exceeded cancellations, by tier",
                        tier=rep.tier.name)
            self._retry_or_fail(rec, now, records, "timeout")

    def _retry_or_fail(self, rec: RequestRecord, now: float,
                       records: dict, why: str):
        """Queue a bounded-exponential-backoff re-dispatch (virtual
        clock: ``min(base * 2**(retries_used-1), cap)``) or fail the
        request for good.  The delay rides on the re-dispatch's
        ``enqueued`` trace event as ``retry_delay_ms``."""
        fr = rec.fr
        if fr.retries_used < fr.retry_budget:
            fr.retries_used += 1
            delay = min(self.retry_backoff_ms
                        * (2.0 ** (fr.retries_used - 1)),
                        self.retry_backoff_cap_ms)
            self._count("fleet_retries_total",
                        "Re-dispatches after timeout or preemption-"
                        "budget eviction", cause=why)
            rec.status = "retrying"
            self._retries.append((now + delay, rec, why, delay))
        else:
            rec.status = "timeout" if why == "timeout" else "cancelled"
            rec.finish_ms = now
            if rec.deadline_abs is not None:
                self._count("fleet_deadline_missed_total",
                            "Requests that missed their deadline, by "
                            "tier", tier=rec.replica or "")

    # ------------------------------------------------------------- faults
    def _apply_chaos(self, now: float, records: dict):
        """Deliver every chaos event due at ``now`` to its host
        boundary: crash/quarantine strike the session, slow scales the
        modeled step cost, pool pressure withholds cache pages, and
        nan_plan poisons the bound params (the engine's NaN guard does
        the rest).  Restores undo the matching injection."""
        if self.chaos is None:
            return
        for phase, spec in self.chaos.due(now):
            if spec.kind == "store_corrupt":
                raise ValueError(
                    "store_corrupt faults target a PlanStore, not the "
                    "fleet; inject them with "
                    "repro.chaos.corrupt_store_entry")
            rep = self.replica_by_name(spec.target)
            if phase == "inject":
                self._count("fault_injected_total",
                            "Chaos fault injections delivered, by kind",
                            kind=spec.kind)
                if spec.kind == "crash":
                    self._strike(rep, now, records, "crashed")
                elif spec.kind == "slow":
                    rep.slow_factor = spec.factor
                elif spec.kind == "pool_pressure":
                    rep.server.backend.shrink_pool(spec.pages)
                elif spec.kind == "nan_plan":
                    rep.nan_undo = poison_params(rep.server)
            else:                       # restore
                if spec.kind == "slow":
                    rep.slow_factor = 1.0
                elif spec.kind == "pool_pressure":
                    rep.server.backend.restore_pool()
                elif spec.kind in ("crash", "nan_plan"):
                    if rep.nan_undo is not None:
                        rep.nan_undo()
                        rep.nan_undo = None
                    if rep.down:
                        rep.down = False
                        rep.down_cause = ""
                        # reopen the session but keep the trace: the
                        # crashed/recovered history must survive
                        rep.server.begin(fresh_trace=False)
                        rep.busy_until = now
                        # the monitor sees the heartbeat return on its
                        # next observation -> warming -> probe

    def _strike(self, rep: Replica, now: float, records: dict,
                kind: str):
        """Kill a replica's session (``crashed`` or ``quarantined``):
        cancel every in-flight request with the fault terminal, close
        the session, and -- with failover on -- recover the requests
        recompute-style onto survivors.  Each victim gets a
        ``recovered`` marker on the struck replica's tracer, then a
        front-of-queue re-dispatch; front-pushing in reverse seniority
        order restores FCFS order on the survivor, and the per-uid
        sampling stream replays byte-identically."""
        name = rep.tier.name
        server = rep.server
        tracer = server.obs.tracer if server.obs is not None else None
        victims = []
        for uid in server.live_uids():       # FCFS seniority order
            server.cancel(uid, reason=kind)
            if uid == rep.probe_uid:
                self.health.probe_done(name, False, now)
                rep.probe_uid = None
                continue
            rec = records.get(uid)
            if rec is not None and rec.status == "running":
                victims.append(rec)
        server.end()
        rep.down = True
        rep.down_cause = kind
        rep.busy_until = now
        # mark down from the dead heartbeat BEFORE routing, so no
        # recovered request can land back on the struck replica
        self.health.observe(rep, now)
        for rec in reversed(victims):
            if not self.failover:
                rec.status = kind
                rec.finish_ms = now
                if rec.sla_deadline_abs is not None:
                    self._count("fleet_deadline_missed_total",
                                "Requests that missed their deadline, "
                                "by tier", tier=name)
                continue
            if tracer is not None:
                tracer.event(rec.fr.uid, "recovered", cause=kind)
            self._count("fault_recovered_requests_total",
                        "In-flight requests recovered off a struck "
                        "replica, by tier", tier=name)
            self._dispatch(rec.fr, now, records,
                           cause=f"recovered:{kind}", front=True,
                           trace_extra={"cause": f"recovered:{kind}"})

    def _submit_probe(self, rep: Replica, now: float):
        """Send a tiny greedy warm-up request through a warming
        replica; :meth:`_after_step` reports its completion to the
        health monitor, which re-admits the replica to routing."""
        uid = PROBE_UID_BASE + self._probe_seq
        self._probe_seq += 1
        req = Request(uid=uid,
                      prompt=np.array([1, 2, 3, 1], np.int32),
                      sampling=SamplingParams(max_tokens=2))
        rep.server.submit(req, trace_extra={"probe": True})
        rep.probe_uid = uid

    # ------------------------------------------------------- step results
    def _after_step(self, rep: Replica, res, t_done: float,
                    records: dict, now: float):
        name = rep.tier.name
        for uid, n_toks in res.produced.items():
            rec = records.get(uid)
            if (rec is not None and rec.status == "running"
                    and rec.replica == name
                    and rec.first_token_ms is None):
                rec.first_token_ms = t_done
        for uid in res.finished:
            if uid == rep.probe_uid:
                # warm-up probe came back: the replica is re-admitted
                self.health.probe_done(name, True, now)
                rep.probe_uid = None
                continue
            rec = records.get(uid)
            if rec is None or rec.replica != name \
                    or rec.status != "running":
                continue
            rec.status = "finished"
            rec.finish_ms = t_done
            rec.tokens = rep.server.result(uid)
            self._count("fleet_completed_total",
                        "Requests completed, by tier", tier=name)
            if rec.sla_deadline_abs is not None:
                met = t_done <= rec.sla_deadline_abs + 1e-9
                self._count(
                    "fleet_deadline_met_total" if met
                    else "fleet_deadline_missed_total",
                    "Requests that met their deadline, by tier" if met
                    else "Requests that missed their deadline, by tier",
                    tier=name)
        # preemption budget: a request thrashing in/out of the pool gets
        # evicted (cancelled) and re-routed instead of thrashing forever
        counts = rep.server.preemption_counts
        for uid, cnt in list(counts.items()):
            rec = records.get(uid)
            if rec is None or rec.status != "running" \
                    or rec.replica != name:
                continue
            base = rec.attempts[-1].preempt_base if rec.attempts else 0
            if cnt - base > rec.fr.preempt_budget:
                toks = rep.server.cancel(uid, reason="cancelled")
                if toks is None:
                    continue
                self._count("fleet_cancelled_total",
                            "Preemption-budget evictions, by tier",
                            tier=name)
                self._retry_or_fail(rec, now, records, "preempt")

    # ------------------------------------------------------ trace merging
    def trace_events(self) -> list:
        """All replica trace events merged into one globally-ordered
        stream; each event JSON gains a ``replica`` field."""
        evs = []
        for rep in self.replicas:
            tracer = (rep.server.obs.tracer
                      if rep.server.obs is not None else None)
            if tracer is None:
                continue
            for ev in tracer.events:
                d = ev.to_json()
                d["replica"] = rep.tier.name
                evs.append(d)
        evs.sort(key=lambda d: d["t"])
        return evs

    def write_trace(self, path: str):
        with open(path, "w") as f:
            for d in self.trace_events():
                f.write(json.dumps(d, sort_keys=True) + "\n")

    def metrics_snapshot(self) -> dict:
        return {"metrics": (self.registry.snapshot()
                            if self.registry.enabled else {}),
                "load": {rep.tier.name: rep.server.load_report()
                         for rep in self.replicas}}

    # -------------------------------------------------------- predictions
    def predicted_completion_ms(self, rep: Replica, fr: FleetRequest,
                                now: float) -> float:
        """Fluid-model ETA for ``fr`` on ``rep``: finish the current
        step, drain the backlog at ``max_batch`` tokens per step, then
        decode the request's own tokens one per step.  The per-step
        cost is inflated by the health monitor's observed slowdown, so
        a watchdog-degraded replica's ETAs are honest."""
        load = rep.server.load_report()
        backlog = load["queued_tokens"] + load["active_tokens"]
        own = int(fr.request.sampling.max_tokens)
        busy = max(0.0, rep.busy_until - now)
        step = (rep.tier.step_ms
                * self.health.eta_multiplier(rep.tier.name))
        return (now + busy + step
                * (backlog / rep.server.max_batch + own))
