"""Open-loop load generation + SLO attainment reporting.

Open-loop means arrivals do not wait for completions: request *i*
arrives at its trace time whether or not the fleet has drained request
*i-1*, which is what exposes queueing collapse (closed-loop harnesses
famously hide it by self-throttling).  Arrival times are virtual
milliseconds on the fleet's modeled clock, so traces are deterministic
given a seed and identical on any machine.

``slo_report`` turns a finished run's ``RequestRecord`` map into the
numbers the bench and CLI print: overall + per-tier p50/p95/p99 TTFT
and per-token latency (virtual ms), deadline attainment, and the
shed / timeout / degrade / retry counts.
"""
from __future__ import annotations

import numpy as np

from repro.obs import percentiles
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import Request
from repro.fleet.fleet import FleetRequest


def _mk_request(uid: int, rng, vocab: int, prompt_len: int,
                sampling: SamplingParams) -> Request:
    prompt = rng.integers(0, vocab, size=prompt_len).astype(np.int32)
    return Request(uid=uid, prompt=prompt, sampling=sampling)


def poisson_trace(n_requests: int, *, rate_rps: float, vocab: int,
                  prompt_len: int = 8, max_tokens: int = 8,
                  deadline_ms: float | None = None,
                  retry_budget: int = 1, preempt_budget: int = 3,
                  temperature: float = 0.0, top_k: int = 0,
                  seed: int = 0, uid0: int = 0) -> list:
    """Open-loop Poisson arrivals: exponential inter-arrival gaps at
    ``rate_rps`` requests per (virtual) second, seeded prompts."""
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
    rng = np.random.default_rng(seed)
    sampling = SamplingParams(temperature=temperature, top_k=top_k,
                              max_tokens=max_tokens, seed=seed)
    t, out = 0.0, []
    for i in range(n_requests):
        t += float(rng.exponential(1000.0 / rate_rps))
        out.append(FleetRequest(
            request=_mk_request(uid0 + i, rng, vocab, prompt_len,
                                sampling),
            arrival_ms=t, deadline_ms=deadline_ms,
            retry_budget=retry_budget, preempt_budget=preempt_budget))
    return out


def burst_trace(n_bursts: int, burst_size: int, *,
                burst_every_ms: float, vocab: int, prompt_len: int = 8,
                max_tokens: int = 8, deadline_ms: float | None = None,
                retry_budget: int = 1, preempt_budget: int = 3,
                temperature: float = 0.0, top_k: int = 0,
                seed: int = 0, uid0: int = 0) -> list:
    """Synchronized bursts: ``burst_size`` simultaneous arrivals every
    ``burst_every_ms`` -- the adversarial pattern for queue-wait
    prediction (Poisson is the friendly one)."""
    rng = np.random.default_rng(seed)
    sampling = SamplingParams(temperature=temperature, top_k=top_k,
                              max_tokens=max_tokens, seed=seed)
    out = []
    uid = uid0
    for b in range(n_bursts):
        t = b * float(burst_every_ms)
        for _ in range(burst_size):
            out.append(FleetRequest(
                request=_mk_request(uid, rng, vocab, prompt_len,
                                    sampling),
                arrival_ms=t, deadline_ms=deadline_ms,
                retry_budget=retry_budget,
                preempt_budget=preempt_budget))
            uid += 1
    return out


def slo_report(fleet, records: dict) -> dict:
    """SLO attainment + latency percentiles for one finished run.

    All latencies are virtual milliseconds.  TTFT is first token of the
    *successful* attempt minus trace arrival (a retried request's
    discarded partial stream does not count as delivery); per-token
    latency is the finished stream's mean inter-token gap.  Deadline
    attainment counts sheds/timeouts/evictions as misses -- an SLO is
    about what the client got.
    """
    per_tier: dict = {rep.tier.name: {"requests": 0, "ttft_ms": [],
                                      "token_ms": [], "met": 0,
                                      "with_deadline": 0}
                      for rep in fleet.replicas}
    status = {"finished": 0, "shed": 0, "timeout": 0, "cancelled": 0,
              "queued": 0, "running": 0}
    met = with_deadline = degraded = retries = 0
    for rec in records.values():
        status[rec.status] = status.get(rec.status, 0) + 1
        degraded += bool(rec.degraded)
        retries += rec.fr.retries_used
        if rec.fr.deadline_ms is not None:
            with_deadline += 1
            met += bool(rec.deadline_met)
        tier = per_tier.get(rec.replica)
        if tier is None or rec.status != "finished":
            continue
        tier["requests"] += 1
        if rec.fr.deadline_ms is not None:
            tier["with_deadline"] += 1
            tier["met"] += bool(rec.deadline_met)
        if rec.first_token_ms is not None:
            tier["ttft_ms"].append(rec.first_token_ms - rec.fr.arrival_ms)
        n = 0 if rec.tokens is None else len(rec.tokens)
        if n > 1 and rec.first_token_ms is not None:
            tier["token_ms"].append(
                (rec.finish_ms - rec.first_token_ms) / (n - 1))
    out_tiers = {}
    for name, t in per_tier.items():
        out_tiers[name] = {
            "requests": t["requests"],
            "ttft_ms": percentiles(t["ttft_ms"]),
            "token_latency_ms": percentiles(t["token_ms"]),
            "deadline_attainment": (t["met"] / t["with_deadline"]
                                    if t["with_deadline"] else None),
        }
    all_ttft = [x for t in per_tier.values() for x in t["ttft_ms"]]
    all_tok = [x for t in per_tier.values() for x in t["token_ms"]]
    return {
        "requests": len(records),
        "status": status,
        "deadline_attainment": (met / with_deadline
                                if with_deadline else None),
        "degraded": degraded,
        "retries": retries,
        "ttft_ms": percentiles(all_ttft),
        "token_latency_ms": percentiles(all_tok),
        "per_tier": out_tiers,
    }
