"""repro.fleet: multi-replica serving over the plan Pareto front.

A :class:`Fleet` binds N :class:`~repro.serve.engine.InferenceServer`
replicas to plan tiers (float / 8-bit / mixed / 2-bit points from one
compression search), routes requests across them with pluggable
policies (``round_robin`` / ``least_loaded`` / ``pareto_degrade`` /
``static:<tier>``), enforces per-request deadlines by cancelling
overdue work (pages freed, ``timeout`` lifecycle event, bounded
retries), and reports SLO attainment through the ``repro.obs``
exporters.  See ``fleet.py`` for the virtual-time model.

Robustness: a :class:`~repro.fleet.health.HealthMonitor` infers each
replica's state (healthy/degraded/down/draining/warming) from
heartbeats, a decode-progress watchdog and warm-up probes; routers
filter on it, and crashed/quarantined replicas' in-flight requests are
recovered recompute-style onto survivors with their token streams
byte-identical to the fault-free run (see ``repro.chaos`` for the
deterministic fault injection that exercises all of this).
"""
from repro.fleet.fleet import (Attempt, Fleet, FleetRequest, Replica,
                               RequestRecord, TierSpec, plan_mean_bits,
                               tier_from_plan)
from repro.fleet.health import (HEALTH_STATES, ROUTABLE_STATES,
                                HealthMonitor, ReplicaHealth)
from repro.fleet.loadgen import burst_trace, poisson_trace, slo_report
from repro.fleet.router import (ROUTERS, LeastLoaded, ParetoDegrade,
                                RoundRobin, Router, StaticTier,
                                make_router)

__all__ = [
    "Fleet", "FleetRequest", "Replica", "RequestRecord", "Attempt",
    "TierSpec", "plan_mean_bits", "tier_from_plan",
    "HealthMonitor", "ReplicaHealth", "HEALTH_STATES",
    "ROUTABLE_STATES",
    "poisson_trace", "burst_trace", "slo_report",
    "Router", "RoundRobin", "LeastLoaded", "ParetoDegrade",
    "StaticTier", "ROUTERS", "make_router",
]
