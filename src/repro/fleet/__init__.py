"""repro.fleet: multi-replica serving over the plan Pareto front.

A :class:`Fleet` binds N :class:`~repro.serve.engine.InferenceServer`
replicas to plan tiers (float / 8-bit / mixed / 2-bit points from one
compression search), routes requests across them with pluggable
policies (``round_robin`` / ``least_loaded`` / ``pareto_degrade`` /
``static:<tier>``), enforces per-request deadlines by cancelling
overdue work (pages freed, ``timeout`` lifecycle event, bounded
retries), and reports SLO attainment through the ``repro.obs``
exporters.  See ``fleet.py`` for the virtual-time model.
"""
from repro.fleet.fleet import (Attempt, Fleet, FleetRequest, Replica,
                               RequestRecord, TierSpec, plan_mean_bits,
                               tier_from_plan)
from repro.fleet.loadgen import burst_trace, poisson_trace, slo_report
from repro.fleet.router import (ROUTERS, LeastLoaded, ParetoDegrade,
                                RoundRobin, Router, StaticTier,
                                make_router)

__all__ = [
    "Fleet", "FleetRequest", "Replica", "RequestRecord", "Attempt",
    "TierSpec", "plan_mean_bits", "tier_from_plan",
    "poisson_trace", "burst_trace", "slo_report",
    "Router", "RoundRobin", "LeastLoaded", "ParetoDegrade",
    "StaticTier", "ROUTERS", "make_router",
]
