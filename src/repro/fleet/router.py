"""Routing policies: which replica serves the next request.

Routers are stateless-ish strategy objects over a :class:`Fleet`; each
``route(fr, now)`` call returns ``(replica, degraded)`` -- or
``(None, _)`` to shed the request.  ``degraded`` flags a dispatch below
the fleet's top-quality tier, which the SLO report surfaces so quality
give-ups are visible, not silent.

- ``round_robin`` -- cyclic, load-blind; the parity baseline.
- ``least_loaded`` -- fewest in-flight requests, then fewest pages in
  use (both from the replica's host-side ``load_report()``).
- ``pareto_degrade`` -- walk tiers from highest quality down, pick the
  first whose fluid-model ETA (:meth:`Fleet.predicted_completion_ms`)
  meets the request's deadline; shed when even the cheapest misses it.
  Deadline-less requests always take the top tier: at low load the
  fleet serves full quality, under pressure it slides down the Pareto
  front, and it recovers as predicted waits shrink.
- ``static:<tier>`` -- pin one tier; the single-tier baseline the bench
  compares ``pareto_degrade`` against.

Every policy routes over the fleet's health-filtered candidate set
(:meth:`Router.candidates`): ``down``/``warming`` replicas are never
eligible, ``draining`` ones only when nothing healthier exists (a
saturated pool is survivable, a dead session is not).  With every
replica healthy the candidate set is the whole fleet and routing is
exactly the pre-failover behavior.
"""
from __future__ import annotations


class Router:
    """Base policy: subclasses implement :meth:`route`."""

    name = "base"

    def __init__(self, fleet):
        self.fleet = fleet

    def candidates(self):
        """Replicas ordinary traffic may target: the health monitor's
        routable set (healthy/degraded), falling back to draining
        replicas when no routable one exists.  Empty means every
        dispatch sheds until something recovers."""
        health = self.fleet.health
        out = [r for r in self.fleet.replicas
               if health.routable(r.tier.name)]
        if not out:
            out = [r for r in self.fleet.replicas
                   if health.state(r.tier.name) == "draining"]
        return out

    def route(self, fr, now):
        """-> (Replica | None, degraded: bool); None sheds."""
        raise NotImplementedError


class RoundRobin(Router):
    name = "round_robin"

    def __init__(self, fleet):
        super().__init__(fleet)
        self._i = 0

    def route(self, fr, now):
        reps = self.candidates()
        if not reps:
            return None, False
        rep = reps[self._i % len(reps)]
        self._i += 1
        return rep, False


class LeastLoaded(Router):
    name = "least_loaded"

    def route(self, fr, now):
        reps = self.candidates()
        if not reps:
            return None, False

        def key(pair):
            idx, rep = pair
            load = rep.server.load_report()
            return (load["queued"] + load["active"],
                    load["pages_in_use"], idx)
        _, rep = min(enumerate(reps), key=key)
        return rep, False


class ParetoDegrade(Router):
    name = "pareto_degrade"

    def route(self, fr, now):
        by_quality = lambda r: (-r.tier.quality, r.tier.name)  # noqa: E731
        reps = sorted(self.candidates(), key=by_quality)
        if not reps:
            return None, True
        # "degraded" is judged against the fleet's overall top tier:
        # routing around a down top replica is a quality give-up too
        top = min(self.fleet.replicas, key=by_quality)
        if fr.deadline_ms is None:
            return reps[0], reps[0] is not top
        deadline_abs = now + fr.deadline_ms
        for rep in reps:
            eta = self.fleet.predicted_completion_ms(rep, fr, now)
            if eta <= deadline_abs + 1e-9:
                return rep, rep is not top
        return None, True          # hopeless everywhere: shed

    # the recovery property is free: predicted waits are a pure
    # function of current backlog, so when load drains the top tier
    # becomes feasible again and deadline-carrying requests move back up


class StaticTier(Router):
    """Pin every request to one named tier (``static:<name>``).
    Requests still queue on a draining pinned tier (old single-replica
    behavior), but shed while it is down or warming."""

    name = "static"

    def __init__(self, fleet, tier: str):
        super().__init__(fleet)
        self.rep = fleet.replica_by_name(tier)

    def route(self, fr, now):
        state = self.fleet.health.state(self.rep.tier.name)
        if state in ("down", "warming"):
            return None, False
        return self.rep, False


ROUTERS = {r.name: r for r in (RoundRobin, LeastLoaded, ParetoDegrade)}


def make_router(spec: str, fleet) -> Router:
    """``spec``: a name from :data:`ROUTERS` or ``static:<tier>``."""
    if spec.startswith("static:"):
        return StaticTier(fleet, spec.split(":", 1)[1])
    try:
        return ROUTERS[spec](fleet)
    except KeyError:
        raise ValueError(
            f"unknown routing policy {spec!r}; have "
            f"{sorted(ROUTERS)} or 'static:<tier>'") from None
