"""Replica health: heartbeat + progress watchdog + warm-up probes.

The :class:`HealthMonitor` infers each replica's state from the same
host-side signals a real deployment would export -- it never reads
fault schedules.  Signals per observation (one per fleet event-loop
iteration):

- **heartbeat**: ``Replica.heartbeat()`` returns the engine's
  ``load_report()`` or ``None`` when the session is dead.  A dead
  heartbeat means ``down``; a returning one on a down replica means
  ``warming`` (the fleet then issues a warm-up probe, and only a
  finished probe re-admits the replica to routing).
- **progress watchdog**: ``load_report()["steps"]`` is the engine's
  decode-step counter.  The monitor timestamps counter advances on the
  virtual clock; when consecutive steps are spaced wider than
  ``watchdog_factor`` times the tier's modeled ``step_ms``, the replica
  is ``degraded`` (and the observed spacing ratio is published as its
  ETA multiplier for the routers' completion model).  Spacing back
  under the threshold heals it.
- **admission pressure**: a paged replica with zero free pages and a
  non-empty queue is ``draining`` -- it keeps decoding residents but
  takes no new routes until pages free up.

States: ``healthy -> degraded -> down -> draining -> warming`` (see
:data:`HEALTH_STATES`).  ``routable()`` is ``healthy``/``degraded``;
``warming`` accepts only its probe; ``down``/``draining`` accept
nothing.  Transitions feed the ``health_*`` metric family:
``health_state{replica}`` (the state's index in ``HEALTH_STATES``) and
``health_transitions_total{replica,state}``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

HEALTH_STATES = ("healthy", "degraded", "down", "draining", "warming")
# states a router may send ordinary traffic to
ROUTABLE_STATES = ("healthy", "degraded")


@dataclasses.dataclass
class ReplicaHealth:
    """Mutable health record for one replica."""

    state: str = "healthy"
    since_ms: float = 0.0
    cause: str = ""
    last_steps: int = 0               # last observed decode-step count
    last_step_ms: Optional[float] = None   # virtual time of last advance
    eta_multiplier: float = 1.0       # observed step spacing / modeled


class HealthMonitor:
    """Per-replica health state machine over host-side signals."""

    def __init__(self, *, watchdog_factor: float = 3.0, registry=None):
        if watchdog_factor <= 1.0:
            raise ValueError(f"watchdog_factor must be > 1, "
                             f"got {watchdog_factor}")
        self.watchdog_factor = float(watchdog_factor)
        self.registry = registry if (registry is not None
                                     and registry.enabled) else None
        self._health: dict[str, ReplicaHealth] = {}

    def start(self, names, now: float = 0.0):
        """Reset every replica to ``healthy`` at ``now`` (one fleet
        run = one health epoch)."""
        self._health = {n: ReplicaHealth(since_ms=now) for n in names}
        for n in names:
            self._gauge(n)

    # ------------------------------------------------------------- queries
    def health(self, name: str) -> ReplicaHealth:
        h = self._health.get(name)
        if h is None:
            h = self._health[name] = ReplicaHealth()
        return h

    def state(self, name: str) -> str:
        return self.health(name).state

    def routable(self, name: str) -> bool:
        return self.health(name).state in ROUTABLE_STATES

    def eta_multiplier(self, name: str) -> float:
        """Observed decode-step slowdown (>= 1.0) for the routers'
        completion-time model; 1.0 unless the watchdog measured
        wider-than-modeled step spacing."""
        return max(1.0, self.health(name).eta_multiplier)

    def states(self) -> dict:
        return {n: h.state for n, h in self._health.items()}

    # --------------------------------------------------------- transitions
    def mark(self, name: str, state: str, now: float, cause: str = ""):
        if state not in HEALTH_STATES:
            raise ValueError(f"unknown health state {state!r}")
        h = self.health(name)
        if h.state == state:
            return
        h.state = state
        h.since_ms = now
        h.cause = cause
        if state in ("down", "warming"):
            # forget stale progress so the watchdog restarts cleanly
            # against the reopened session's zeroed step counter
            h.last_step_ms = None
            h.last_steps = 0
            h.eta_multiplier = 1.0
        if self.registry is not None:
            self.registry.counter(
                "health_transitions_total",
                "Replica health-state transitions",
                labels=("replica", "state")).inc(replica=name,
                                                 state=state)
        self._gauge(name)

    def _gauge(self, name: str):
        if self.registry is not None:
            h = self.health(name)
            self.registry.gauge(
                "health_state",
                "Replica health state (index into "
                "healthy/degraded/down/draining/warming)",
                labels=("replica",)).set(
                HEALTH_STATES.index(h.state), replica=name)

    # --------------------------------------------------------- observation
    def observe(self, rep, now: float):
        """One observation of ``rep`` (a :class:`repro.fleet.fleet.
        Replica`) at virtual time ``now``."""
        name = rep.tier.name
        h = self.health(name)
        load = rep.heartbeat()
        if load is None:
            if h.state != "down":
                self.mark(name, "down", now, cause=rep.down_cause)
            return
        if h.state == "down":
            # the session answers again: warm up, don't route yet --
            # the fleet issues a probe and probe_done() re-admits
            self.mark(name, "warming", now, cause="heartbeat")
            return
        if h.state == "warming":
            return                      # gated on the warm-up probe
        # progress watchdog over the decode-step counter.  Spacing only
        # means "stalled" while the replica continuously has work: an
        # idle gap between bursts resets the watchdog instead of
        # reading as a 100x slowdown.
        steps = int(load.get("steps", 0))
        if steps < h.last_steps:          # session was reopened
            h.last_steps = steps
            h.last_step_ms = None
        if load.get("active", 0) == 0 and load.get("queued", 0) == 0:
            h.last_step_ms = None
            h.eta_multiplier = 1.0
        elif steps > h.last_steps:
            if h.last_step_ms is not None:
                spacing = now - h.last_step_ms
                modeled = max(rep.tier.step_ms, 1e-9)
                h.eta_multiplier = max(1.0, spacing / modeled)
            h.last_steps = steps
            h.last_step_ms = now
        slow = h.eta_multiplier > self.watchdog_factor
        # admission pressure: no free pages + queued work = draining
        report = load if "pages_free" in load else None
        starved = (report is not None and report["pages_free"] == 0
                   and load.get("queued", 0) > 0)
        if starved:
            if h.state != "draining":
                self.mark(name, "draining", now, cause="pool")
        elif slow:
            if h.state != "degraded":
                self.mark(name, "degraded", now, cause="watchdog")
        elif h.state in ("degraded", "draining"):
            self.mark(name, "healthy", now, cause="recovered")

    def probe_done(self, name: str, ok: bool, now: float):
        """A warm-up probe finished (``ok``) or died; a passed probe
        re-admits the replica to routing."""
        if self.registry is not None:
            self.registry.counter(
                "health_probes_total",
                "Warm-up probes issued to recovering replicas, by "
                "outcome", labels=("replica", "ok")).inc(
                replica=name, ok="true" if ok else "false")
        if ok and self.state(name) == "warming":
            self.mark(name, "healthy", now, cause="probe")
