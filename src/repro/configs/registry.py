"""Registry mapping --arch ids to ArchConfig (+ reduced smoke variants)."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig

# ---------------------------------------------------------------------------
# the 10 assigned architectures (exact numbers from the assignment pool)
# ---------------------------------------------------------------------------

JAMBA_1_5_LARGE = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    vocab=65536, head_dim=128,
    n_experts=16, experts_per_token=2, moe_every=2, moe_d_ff=24576,
    attn_every=8, ssm_state=128, ssm_expand=2, ssm_head_dim=128,
    param_dtype="bfloat16", optimizer="adam_int8", train_microbatches=8,
)

MAMBA2_780M = ArchConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50280, head_dim=0,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64,
)

QWEN3_32B = ArchConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, d_ff=25600,
    vocab=151936, head_dim=128, qk_norm=True, rope_theta=1e6,
    train_microbatches=2,
)

LLAMA32_1B = ArchConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, d_ff=8192,
    vocab=128256, head_dim=64, rope_theta=5e5,
)

MINICPM_2B = ArchConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_ff=5760,
    vocab=122753, head_dim=64,
)

GEMMA2_2B = ArchConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_ff=9216,
    vocab=256000, head_dim=256,
    attn_pattern="local_global", local_window=4096,
    attn_softcap=50.0, final_softcap=30.0,
)

SEAMLESS_M4T_MEDIUM = ArchConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab=256206, head_dim=64, enc_layers=12, frontend="audio",
)

LLAMA4_SCOUT = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab=202048, head_dim=128,
    n_experts=16, experts_per_token=1, moe_d_ff=8192, dense_residual=True,
    attn_pattern="chunked", local_window=8192, rope_theta=5e5,
    train_microbatches=4,
)

ARCTIC_480B = ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab=32000, head_dim=128,
    n_experts=128, experts_per_token=2, moe_d_ff=4864, dense_residual=True,
    param_dtype="bfloat16", optimizer="adam_int8", train_microbatches=4,
)

QWEN2_VL_72B = ArchConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab=152064, head_dim=128, frontend="vision", rope_theta=1e6,
    param_dtype="bfloat16", optimizer="adam_int8", train_microbatches=4,
)

# beyond-paper performance variants (Sec-Perf hillclimb): pad heads up to
# a TP16-divisible count so attention shards instead of replicating
MINICPM_2B_PADHEADS = dataclasses.replace(
    MINICPM_2B, name="minicpm-2b-padheads",
    n_heads_padded=48, n_kv_heads_padded=48)

GEMMA2_2B_PADHEADS = dataclasses.replace(
    GEMMA2_2B, name="gemma2-2b-padheads",
    n_heads_padded=16, n_kv_heads_padded=16)

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in [
        JAMBA_1_5_LARGE, MAMBA2_780M, QWEN3_32B, LLAMA32_1B, MINICPM_2B,
        GEMMA2_2B, SEAMLESS_M4T_MEDIUM, LLAMA4_SCOUT, ARCTIC_480B,
        QWEN2_VL_72B, MINICPM_2B_PADHEADS, GEMMA2_2B_PADHEADS,
    ]
}

# per-arch sharding rule overrides (heads not divisible by TP=16 -> shard
# only the fused H*hd projection axis and let attention run data-parallel)
RULE_OVERRIDES: dict[str, dict] = {
    "gemma2-2b": {"heads": None, "kv_heads": None},
    "minicpm-2b": {"heads": None, "kv_heads": None},
    "minicpm-2b-padheads": {},     # 48 heads / 16-way TP shards cleanly
    "gemma2-2b-padheads": {},
    "seamless-m4t-medium": {},
    "llama3.2-1b": {"kv_heads": None},
    "qwen3-32b": {"kv_heads": None},
    "llama4-scout-17b-a16e": {"kv_heads": None},
    "arctic-480b": {"kv_heads": None},
    "qwen2-vl-72b": {"kv_heads": None},
    "jamba-1.5-large-398b": {"kv_heads": None},
}


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family variant for CPU smoke tests."""
    pat_len = {"hybrid": 8, "ssm": 1, "dense": 2 if cfg.attn_pattern ==
               "local_global" else 1, "moe": 4 if cfg.attn_pattern ==
               "chunked" else 1, "encdec": 1, "vlm": 1}[cfg.family]
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=pat_len * (2 if pat_len <= 2 else 1),
        d_model=64,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16 if cfg.head_dim else 0,
        d_ff=128 if cfg.d_ff else 0,
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        vocab=512,
        n_experts=4 if cfg.n_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2),
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=32,
        local_window=32 if cfg.local_window else 0,
        enc_layers=1 if cfg.enc_layers else 0,
        param_dtype="float32", optimizer="adam", remat=False,
        train_microbatches=1,
        n_heads_padded=0, n_kv_heads_padded=0,
    )


def get(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return reduced(ARCHS[name[: -len("-smoke")]])
    return ARCHS[name]
