"""Architecture + run configuration dataclasses and the input-shape table."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # --- attention flavour ---
    qk_norm: bool = False
    attn_softcap: float = 0.0          # gemma2 attention-logit softcap
    final_softcap: float = 0.0         # gemma2 final-logit softcap
    local_window: int = 0              # window for local/chunked attention
    attn_pattern: str = "full"         # full | local_global | chunked
    rope_theta: float = 10_000.0
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1                 # MoE on every k-th layer
    dense_residual: bool = False       # dense FFN in parallel with MoE
    moe_d_ff: int = 0                  # expert hidden dim (0 -> d_ff)
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    attn_every: int = 0                # jamba: 1 attention layer per N
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4
    # --- encoder-decoder ---
    enc_layers: int = 0
    # --- modality frontend stub ---
    frontend: str = "none"             # none | audio | vision
    # --- numerics / scale ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    param_dtype: str = "float32"       # master weights
    optimizer: str = "adam"            # adam | adam_int8
    remat: bool = True
    train_microbatches: int = 1        # grad-accumulation microbatches
    # pad attention heads up to a TP-divisible count (dummy heads; exact
    # when the extra wo rows are zero) -- used by the -padheads variants
    n_heads_padded: int = 0
    n_kv_heads_padded: int = 0
    # --- technique integration (the paper's search) ---
    mps_precisions: tuple[int, ...] = (0, 2, 4, 8)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.ssm_state > 0 and self.attn_every == 0

    @property
    def is_hybrid(self) -> bool:
        return self.ssm_state > 0 and self.attn_every > 0

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """Supports long_500k (attention-free / mostly-SSM / chunked)."""
        return self.is_ssm or self.is_hybrid or self.attn_pattern == "chunked"

    @property
    def h_eff(self) -> int:
        return self.n_heads_padded or self.n_heads

    @property
    def hkv_eff(self) -> int:
        return self.n_kv_heads_padded or self.n_kv_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def cell_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Is (arch x shape) a runnable cell? Returns (ok, reason-if-not)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention architecture; long_500k "
                       "mandates sub-quadratic attention (DESIGN.md skip "
                       "list)")
    return True, ""
