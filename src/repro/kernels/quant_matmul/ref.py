"""Pure-jnp oracle for quant_matmul (+ the bit-packing helpers)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def pack_weights(wq: np.ndarray, bits: int) -> np.ndarray:
    """Pack signed `bits`-bit integers (N, K) little-endian into int8
    (N, K*bits/8). K must be a multiple of 8/bits."""
    if bits == 8:
        return wq.astype(np.int8)
    per = 8 // bits
    n, k = wq.shape
    assert k % per == 0
    u = (wq.astype(np.int32) & ((1 << bits) - 1)).astype(np.uint8)
    u = u.reshape(n, k // per, per)
    out = np.zeros((n, k // per), np.uint8)
    for i in range(per):
        out |= u[:, :, i] << (bits * i)
    return out.astype(np.int8)


def quant_matmul_ref(xq: jax.Array, wq: jax.Array, sw: jax.Array,
                     sx: jax.Array) -> jax.Array:
    """xq: (M, K) int8; wq: (N, K) int8 *unpacked*; sw: (N,) f32; sx: ()."""
    acc = jnp.einsum("mk,nk->mn", xq.astype(jnp.int32),
                     wq.astype(jnp.int32))
    return acc.astype(jnp.float32) * sw[None, :] * sx


def quantize_activations(x: jax.Array):
    """Per-tensor symmetric int8 activation quantization -> (xq, sx)."""
    sx = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / 127.0
    xq = jnp.clip(jnp.round(x / sx), -127, 127).astype(jnp.int8)
    return xq, sx
