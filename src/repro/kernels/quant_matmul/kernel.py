"""Pallas TPU kernel: int8 x int8 tiled matmul with fused per-channel dequant.

Serving path for the discretized models (paper Sec. 4.5 / Fig. 3): after
channel reordering, each layer is a set of dense per-precision sub-matmuls.
Sub-8-bit weights are stored bit-packed in int8 words and unpacked in-kernel
(bandwidth win; the MXU computes at int8 regardless -- see DESIGN.md
"hardware adaptation").

Y[m, n] = (sum_k Xq[m, k] * Wq[n, k]) * sx * sw[n]

Grid: (M/BM, N/BN, K/BK); K is the innermost (sequential) axis, accumulated
in an f32 VMEM scratch-free accumulator held in the output block (int32
partials fit f32 exactly: 127*127*BK < 2^24 for BK <= 1024).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _qmm_kernel(x_ref, w_ref, sw_ref, sx_ref, out_ref, *, nk: int,
                w_bits: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)            # (BM, BK)
    w = w_ref[...]                                # (BN, BK') packed int8
    w = _unpack(w, w_bits).astype(jnp.float32)    # (BN, BK)
    out_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        sw = sw_ref[...]                          # (1, BN)
        sx = sx_ref[0, 0]
        out_ref[...] = out_ref[...] * sw * sx


def _unpack(w: jax.Array, bits: int) -> jax.Array:
    """Unpack 8/4/2-bit signed values stored little-endian in int8 words."""
    if bits == 8:
        return w
    per = 8 // bits
    w_u = w.astype(jnp.uint8)
    parts = []
    mask = (1 << bits) - 1
    sign = 1 << (bits - 1)
    for i in range(per):
        v = (w_u >> (bits * i)) & mask
        v = v.astype(jnp.int32)
        v = jnp.where(v >= sign, v - (1 << bits), v)  # sign-extend
        parts.append(v.astype(jnp.int8))
    # (BN, BK/per, per) -> (BN, BK)
    return jnp.stack(parts, axis=-1).reshape(w.shape[0], -1)


def quant_matmul_fwd(xq: jax.Array, wq_packed: jax.Array, sw: jax.Array,
                     sx: jax.Array, *, w_bits: int = 8,
                     bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                     bk: int = DEFAULT_BK, interpret: bool = True
                     ) -> jax.Array:
    """xq: (M, K) int8; wq_packed: (N, K*bits/8) int8; sw: (1, N) f32;
    sx: (1, 1) f32. Shapes must already be tile-aligned."""
    m, k = xq.shape
    n = wq_packed.shape[0]
    per = 8 // w_bits
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    nk = k // bk
    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_qmm_kernel, nk=nk, w_bits=w_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk // per), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(xq, wq_packed, sw, sx)
