"""Jitted wrapper for quant_matmul: padding, packing, backend dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.quant_matmul import kernel as _k
from repro.kernels.quant_matmul import ref as _ref

pack_weights = _ref.pack_weights
quantize_activations = _ref.quantize_activations


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("w_bits",))
def quant_matmul(xq: jax.Array, wq_packed: jax.Array, sw: jax.Array,
                 sx: jax.Array, w_bits: int = 8) -> jax.Array:
    """Y = (Xq @ Wq^T) * sx * sw.  xq: (M, K) int8; wq_packed:
    (N, K*w_bits/8) int8; sw: (N,); sx: scalar. Returns (M, N) f32."""
    m, k = xq.shape
    n = wq_packed.shape[0]
    per = 8 // w_bits
    bm = min(_k.DEFAULT_BM, max(8, m))
    bn = min(_k.DEFAULT_BN, max(128, n))
    bk = min(_k.DEFAULT_BK, max(128, k))
    xp = _pad_to(_pad_to(xq, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(wq_packed, bn, 0), bk // per, 1)
    swp = _pad_to(sw.reshape(1, -1), bn, 1)
    out = _k.quant_matmul_fwd(
        xp, wp, swp, sx.reshape(1, 1).astype(jnp.float32), w_bits=w_bits,
        bm=bm, bn=bn, bk=bk, interpret=not _on_tpu())
    return out[:m, :n]


def quantized_linear_apply(x: jax.Array, packed_layers) -> jax.Array:
    """Apply a reordered mixed-precision layer (paper Fig. 3): the layer is
    a list of per-precision sub-matmuls whose outputs concatenate along N.

    packed_layers: [(w_bits, wq_packed (Ni, K*bits/8), sw (Ni,)), ...]
    Delegates to ``repro.nn.quantized.mixed_precision_matmul`` (per-row
    activation scales, batch-invariant; a fully-pruned empty layer list
    yields a zero-width (M, 0) result).
    """
    from repro.nn import quantized as nnq
    return nnq.mixed_precision_matmul(x, packed_layers)
