"""Pallas TPU kernel: Mamba-2 SSD inter-chunk state recurrence.

The chunked SSD algorithm (Dao & Gu, arXiv:2405.21060) splits the sequence
into chunks; intra-chunk terms are dense matmuls (MXU-friendly, left to
XLA), while the inter-chunk state pass is the sequential, memory-bound part:

    prefix[c] = state before chunk c
    state     = decay[c] * state + S_in[c],        state(init) = S0

with S in (C, H, P, N) -- chunks x heads x head_dim x state_dim -- and decay
(C, H). On TPU the grid's last axis executes sequentially and revisited
output blocks stay resident, so the running state is carried in the `final`
output block (no HBM round-trip per chunk); each head-tile streams chunk
contributions through VMEM exactly once.

Grid: (H/BH, C) -- C innermost/sequential.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BH = 8


def _ssd_kernel(dec_ref, s_ref, init_ref, prefix_ref, final_ref):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        final_ref[...] = init_ref[...]        # (BH, P, N) carry := S0

    prefix_ref[0] = final_ref[...]            # state before chunk c
    dec = dec_ref[...][0, :, None, None]      # (BH, 1, 1)
    final_ref[...] = dec * final_ref[...] + s_ref[0]


def ssd_scan_fwd(decay: jax.Array, s_in: jax.Array, s0: jax.Array, *,
                 bh: int = DEFAULT_BH, interpret: bool = True):
    """decay: (C, H); s_in: (C, H, P, N); s0: (H, P, N).

    Returns (prefix_states (C, H, P, N), final_state (H, P, N)).
    """
    c, h = decay.shape
    p, n = s_in.shape[2], s_in.shape[3]
    bh = min(bh, h)
    grid = (h // bh, c)
    prefix, final = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bh), lambda i, cc: (cc, i)),
            pl.BlockSpec((1, bh, p, n), lambda i, cc: (cc, i, 0, 0)),
            pl.BlockSpec((bh, p, n), lambda i, cc: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bh, p, n), lambda i, cc: (cc, i, 0, 0)),
            pl.BlockSpec((bh, p, n), lambda i, cc: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, h, p, n), s_in.dtype),
            jax.ShapeDtypeStruct((h, p, n), s_in.dtype),
        ],
        interpret=interpret,
    )(decay, s_in, s0)
    return prefix, final
