"""Jitted wrapper for the SSD inter-chunk scan (backend dispatch + padding)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import kernel as _k
from repro.kernels.ssd_scan import ref as _ref

ssd_scan_ref = _ref.ssd_scan_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ssd_scan(decay: jax.Array, s_in: jax.Array, s0: jax.Array,
             use_kernel: bool | None = None):
    """Inter-chunk SSD state pass. See kernel.py for semantics.

    ``use_kernel=None`` -> Pallas on TPU, lax.scan reference on CPU (the
    interpret-mode kernel is functionally identical but Python-slow; tests
    exercise it explicitly).
    """
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel:
        return _ref.ssd_scan_ref(decay, s_in, s0)
    h = decay.shape[1]
    bh = _k.DEFAULT_BH
    if h % bh != 0:
        bh = 1
    return _k.ssd_scan_fwd(decay, s_in, s0, bh=bh,
                           interpret=not _on_tpu())
