"""Pure-jnp oracle for the ssd_scan kernel (lax.scan over chunks)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(decay: jax.Array, s_in: jax.Array, s0: jax.Array):
    """decay: (C, H); s_in: (C, H, P, N); s0: (H, P, N).
    Returns (prefix_states (C, H, P, N), final_state (H, P, N))."""

    def step(state, inputs):
        dec, s = inputs                      # (H,), (H, P, N)
        prefix = state
        new_state = dec[:, None, None] * state + s
        return new_state, prefix

    final, prefixes = jax.lax.scan(step, s0, (decay, s_in))
    return prefixes, final
