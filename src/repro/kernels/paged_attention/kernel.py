"""Pallas TPU kernel: decode attention directly over a paged KV pool.

The serving stack's :class:`~repro.serve.cache.PagedCache` stores KV in a
fixed pool of ``page_size``-token pages plus per-slot block tables of
physical page ids.  Before this kernel, every decode step gathered the
slot's pages into a dense ``(B, max_len, Hkv, D)`` view and ran ordinary
masked attention over it -- strictly more memory traffic than dense
decode, and every never-written position was still scanned.  This kernel
reads the pool **in place**:

    grid = (slot, page-block); the page-block axis is innermost, so it
    executes sequentially per slot and the online-softmax state (running
    max / denominator / weighted-value accumulator) lives in VMEM scratch
    across page blocks.

    The K/V block specs index the pool THROUGH the scalar-prefetched
    block table: ``index_map = (tables[b, p], 0, 0, 0)``.  Entries beyond
    a slot's live length are 0 (the reserved null page), so consecutive
    dead iterations map to the same physical block and Pallas elides the
    re-fetch; ``pl.when`` skips their compute entirely.  HBM traffic per
    step is therefore proportional to the tokens actually held, not to
    ``max_batch * max_len``.

    GQA is handled in-kernel (one 2-D MXU dot per KV head group against
    the shared K page) -- no head-repeated cache materialization.

Numerics contract: masked positions score ``-1e30`` exactly like the
dense ``blocks.decode_attention`` path; a slot whose table row is all
null (inactive / freed mid-batch) produces a finite all-zero output (the
denominator is clamped).  ``ref.paged_attention_ref`` mirrors this
kernel's math operation-for-operation (same per-page 2-D dots, same
online-softmax update order), and the kernel tests assert bitwise
equality against it in interpret mode.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def page_mask(page_start, posn: jax.Array, t: int, *, window: int,
              chunked: bool):
    """(1, t) bool mask of attendable positions inside one page.

    ``page_start`` may be a python int (reference path) or a traced
    scalar (kernel path); ``posn`` is the slot's current decode position
    (the newest written token, always attendable).
    """
    pos_k = page_start + jax.lax.broadcasted_iota(jnp.int32, (1, t), 1)
    mask = pos_k <= posn
    if window > 0 and not chunked:
        mask &= pos_k > posn - window
    if window > 0 and chunked:
        mask &= (pos_k // window) == (posn // window)
    return mask


def page_live(phys, page_start, posn: jax.Array, page_size: int, *,
              window: int, chunked: bool):
    """Whether a page contributes at all: physically backed (non-null)
    AND not wholly beyond the slot's live length AND not wholly below the
    attention window."""
    live = jnp.logical_and(phys != 0, page_start <= posn)
    page_end = page_start + page_size - 1
    if window > 0 and not chunked:
        live = jnp.logical_and(live, page_end > posn - window)
    if window > 0 and chunked:
        live = jnp.logical_and(live, page_end >= (posn // window) * window)
    return live


def page_update(q, k, v, m, l, acc, page_start, posn, *, scale: float,
                window: int, chunked: bool, cap: float):
    """One page's online-softmax contribution.  Shared by the kernel body
    and :func:`ref.paged_attention_ref` so the two are bitwise identical.

    q: (H, D) f32; k/v: (T, Hkv, D) f32; m/l: (H, 1) f32 running
    max/denominator; acc: (H, D) f32.  Returns updated (m, l, acc).
    """
    h, d = q.shape
    t, hkv, _ = k.shape
    g = h // hkv
    rows = []
    for i in range(hkv):
        rows.append(jax.lax.dot_general(
            q[i * g:(i + 1) * g], k[:, i, :],
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32))       # (G, T)
    s = jnp.concatenate(rows, axis=0) * scale          # (H, T)
    if cap > 0:
        s = cap * jnp.tanh(s / cap)
    mask = page_mask(page_start, posn, t, window=window, chunked=chunked)
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m - m_new)
    # the barriers pin the rescale-then-add to two instructions in BOTH
    # consumers: whether XLA contracts a*b+c into an FMA otherwise
    # depends on the surrounding graph, and the kernel (VMEM scratch
    # round-trips) and the python-looped reference would disagree by an
    # ULP on multi-page slots
    l_new = jax.lax.optimization_barrier(l * corr) \
        + jnp.sum(p, axis=-1, keepdims=True)
    outs = []
    for i in range(hkv):
        outs.append(jax.lax.dot_general(
            p[i * g:(i + 1) * g], v[:, i, :],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32))       # (G, D)
    acc_new = jax.lax.optimization_barrier(acc * corr) \
        + jnp.concatenate(outs, axis=0)
    return m_new, l_new, acc_new


def _paged_attn_kernel(tables_ref, pos_ref, q_ref, k_ref, v_ref, out_ref,
                       m_ref, l_ref, acc_ref, *, page_size: int, n_pb: int,
                       scale: float, window: int, chunked: bool,
                       cap: float):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    phys = tables_ref[b, p]
    posn = pos_ref[b]
    page_start = p * page_size
    live = page_live(phys, page_start, posn, page_size, window=window,
                     chunked=chunked)

    @pl.when(live)
    def _compute():
        m_new, l_new, acc_new = page_update(
            q_ref[0].astype(jnp.float32), k_ref[0].astype(jnp.float32),
            v_ref[0].astype(jnp.float32), m_ref[...], l_ref[...],
            acc_ref[...], page_start, posn, scale=scale, window=window,
            chunked=chunked, cap=cap)
        m_ref[...] = m_new
        l_ref[...] = l_new
        acc_ref[...] = acc_new

    @pl.when(p == n_pb - 1)
    def _epilogue():
        out_ref[0] = (acc_ref[...]
                      / jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


def paged_attention_fwd(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        tables: jax.Array, pos: jax.Array, *,
                        window: int = 0, chunked: bool = False,
                        cap: float = 0.0, interpret: bool = True
                        ) -> jax.Array:
    """q: (B, H, D); k_pool/v_pool: (n_pages + 1, page_size, Hkv, D) with
    physical page 0 the reserved null page; tables: (B, P) int32 physical
    page ids (0 = unbacked); pos: (B,) int32 per-slot decode positions.
    Returns (B, H, D) in q's dtype.
    """
    b, h, d = q.shape
    page_size, hkv = k_pool.shape[1], k_pool.shape[2]
    n_pb = tables.shape[1]
    assert h % hkv == 0, (h, hkv)
    scale = 1.0 / math.sqrt(d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_pb),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda bb, p, tbl, ps: (bb, 0, 0)),
            pl.BlockSpec((1, page_size, hkv, d),
                         lambda bb, p, tbl, ps: (tbl[bb, p], 0, 0, 0)),
            pl.BlockSpec((1, page_size, hkv, d),
                         lambda bb, p, tbl, ps: (tbl[bb, p], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda bb, p, tbl, ps: (bb, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),       # running max
            pltpu.VMEM((h, 1), jnp.float32),       # running denominator
            pltpu.VMEM((h, d), jnp.float32),       # weighted-V accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_attn_kernel, page_size=page_size,
                          n_pb=n_pb, scale=scale, window=window,
                          chunked=chunked, cap=cap),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), pos.astype(jnp.int32), q, k_pool, v_pool)
