"""Dispatch wrapper for paged attention (decode AND prefill): kernel on
TPU, gathered view off-TPU, exact-mirror reference for tests.

``impl`` resolution (also overridable process-wide via :func:`force_impl`
for tests; the override pins BOTH entry points):

* ``"kernel"`` -- the Pallas kernels (compiled on TPU, interpret mode
  elsewhere).  The production TPU path.
* ``"view"``   -- the gathered dense view + the dense attention op
  sequence (``decode_attention`` for decode, ``flash_attention`` for
  prefill); bitwise identical to the dense cache backend, and the fast
  formulation for CPU/GPU where the pool gather compiles to one fused
  XLA op.
* ``"ref"``    -- the bitwise mirrors of the kernels (python-looped;
  oracles only).
"""
from __future__ import annotations

import contextlib
import math

import jax

from repro.kernels.paged_attention import kernel as _k
from repro.kernels.paged_attention import prefill as _pf
from repro.kernels.paged_attention import ref as _ref

paged_attention_ref = _ref.paged_attention_ref
paged_attention_view = _ref.paged_attention_view
paged_prefill_ref = _pf.paged_prefill_ref
paged_prefill_view = _pf.paged_prefill_view

# widest q chunk the prefill kernel tiles with; the actual chunk is the
# largest power-of-two divisor of the (padded) prompt length up to this
PREFILL_Q = 16

_IMPLS = ("kernel", "view", "ref")
_impl_override: str | None = None


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_impl(impl: str | None = None) -> str:
    if impl is None:
        impl = _impl_override
    if impl is None:
        impl = "kernel" if _on_tpu() else "view"
    if impl not in _IMPLS:
        raise ValueError(f"unknown paged-attention impl {impl!r} "
                         f"(expected one of {_IMPLS})")
    return impl


@contextlib.contextmanager
def force_impl(impl: str | None):
    """Test hook: pin the implementation for every call in the block."""
    global _impl_override
    prev = _impl_override
    _impl_override = resolve_impl(impl) if impl is not None else None
    try:
        yield
    finally:
        _impl_override = prev


def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    tables: jax.Array, pos: jax.Array, *, window: int = 0,
                    chunked: bool = False, cap: float = 0.0,
                    impl: str | None = None) -> jax.Array:
    """Decode attention over the page pool.  q: (B, H, D);
    k_pool/v_pool: (n_pages + 1, page_size, Hkv, D); tables: (B, P)
    physical page ids (0 = null); pos: (B,) per-slot positions.
    Returns (B, H, D) in q's dtype."""
    impl = resolve_impl(impl)
    if impl == "ref":
        return _ref.paged_attention_ref(q, k_pool, v_pool, tables, pos,
                                        window=window, chunked=chunked,
                                        cap=cap)
    if impl == "view":
        return _ref.paged_attention_view(q, k_pool, v_pool, tables, pos,
                                         window=window, chunked=chunked,
                                         cap=cap)
    return _k.paged_attention_fwd(q, k_pool, v_pool, tables, pos,
                                  window=window, chunked=chunked, cap=cap,
                                  interpret=not _on_tpu())


def prefill_q_chunk(s: int) -> int:
    """Largest power-of-two q-chunk width up to :data:`PREFILL_Q` that
    tiles a length-``s`` prompt (the engine pads paged attention-only
    prompts to a multiple of PREFILL_Q, so serving always gets the full
    width; exact-length hybrid prefill degrades gracefully)."""
    return math.gcd(s, PREFILL_Q)


def paged_prefill_attention(q: jax.Array, k_pool: jax.Array,
                            v_pool: jax.Array, tables: jax.Array,
                            lens: jax.Array, *, window: int = 0,
                            chunked: bool = False, cap: float = 0.0,
                            impl: str | None = None) -> jax.Array:
    """Prefill attention over the page pool.  q: (B, S, H, D) -- the
    prompt's queries, rows at or beyond ``lens`` being discarded
    padding; k_pool/v_pool: (n_pages + 1, page_size, Hkv, D); tables:
    (B, P) physical page ids (0 = null); lens: (B,) real prompt
    lengths.  Returns (B, S, H, D) in q's dtype."""
    impl = resolve_impl(impl)
    if impl == "ref":
        return _pf.paged_prefill_ref(q, k_pool, v_pool, tables, lens,
                                     window=window, chunked=chunked,
                                     cap=cap,
                                     q_chunk=prefill_q_chunk(q.shape[1]))
    if impl == "view":
        return _pf.paged_prefill_view(q, k_pool, v_pool, tables, lens,
                                      window=window, chunked=chunked,
                                      cap=cap)
    return _pf.paged_prefill_fwd(q, k_pool, v_pool, tables, lens,
                                 window=window, chunked=chunked, cap=cap,
                                 q_chunk=prefill_q_chunk(q.shape[1]),
                                 interpret=not _on_tpu())
