"""Reference implementations for the paged-attention decode kernel.

Two oracles with different jobs:

* :func:`paged_attention_ref` -- the EXACT mirror of ``kernel.py``: the
  same python loop over KV head groups, the same per-page 2-D dots, the
  same online-softmax update order (it calls the kernel's own
  :func:`~repro.kernels.paged_attention.kernel.page_update`).  Kernel
  tests assert bitwise equality against it in interpret mode.  It loops
  over slots and pages in python, so it is an oracle, not a fast path.

* :func:`paged_attention_view` -- the production off-TPU fallback: one
  vectorized gather of the slot's pages into the logically-ordered dense
  view followed by the exact op sequence of ``blocks.decode_attention``.
  When ``page_size`` divides ``max_len`` this is bitwise identical to
  the dense backend's attention (the PR 3 invariant), so CPU serving
  keeps dense-vs-paged token equality while TPU serving runs the
  in-place kernel.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention import kernel as _k


def paged_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        tables: jax.Array, pos: jax.Array, *,
                        window: int = 0, chunked: bool = False,
                        cap: float = 0.0) -> jax.Array:
    """Bitwise mirror of the Pallas kernel (see module docstring).

    q: (B, H, D); k_pool/v_pool: (n_pages + 1, page_size, Hkv, D);
    tables: (B, P); pos: (B,).  Returns (B, H, D) in q's dtype.
    """
    b, h, d = q.shape
    page_size = k_pool.shape[1]
    n_pb = tables.shape[1]
    scale = 1.0 / math.sqrt(d)
    outs = []
    for bi in range(b):
        qi = q[bi].astype(jnp.float32)
        posn = pos[bi]
        m = jnp.full((h, 1), _k.NEG_INF, jnp.float32)
        l = jnp.zeros((h, 1), jnp.float32)
        acc = jnp.zeros((h, d), jnp.float32)
        for p in range(n_pb):
            phys = tables[bi, p]
            page_start = p * page_size
            live = _k.page_live(phys, page_start, posn, page_size,
                                window=window, chunked=chunked)
            k = jax.lax.dynamic_index_in_dim(
                k_pool, phys, 0, keepdims=False).astype(jnp.float32)
            v = jax.lax.dynamic_index_in_dim(
                v_pool, phys, 0, keepdims=False).astype(jnp.float32)
            m2, l2, a2 = _k.page_update(qi, k, v, m, l, acc, page_start,
                                        posn, scale=scale, window=window,
                                        chunked=chunked, cap=cap)
            # dead pages leave the state untouched, exactly like the
            # kernel's pl.when skip (jnp.where also drops any NaN the
            # null page may hold)
            m = jnp.where(live, m2, m)
            l = jnp.where(live, l2, l)
            acc = jnp.where(live, a2, acc)
            # the kernel round-trips its state through VMEM scratch each
            # page; the barrier stops XLA from FMA-fusing across pages
            # here, keeping the two float pipelines bitwise identical
            m, l, acc = jax.lax.optimization_barrier((m, l, acc))
        outs.append((acc / jnp.maximum(l, 1e-30)).astype(q.dtype))
    return jnp.stack(outs)


def paged_attention_view(q: jax.Array, k_pool: jax.Array,
                         v_pool: jax.Array, tables: jax.Array,
                         pos: jax.Array, *, window: int = 0,
                         chunked: bool = False, cap: float = 0.0
                         ) -> jax.Array:
    """Gathered-view fallback: pool pages -> dense (B, P * page_size)
    rows, then the dense decode-attention math.  NOTE: the op sequence
    below deliberately replicates ``blocks.decode_attention`` (repeat_kv,
    the einsum specs, -1e30 masking, jax.nn.softmax) so the result is
    bitwise identical to the dense cache backend.
    """
    b, h, d = q.shape
    hkv = k_pool.shape[2]
    ck = k_pool[tables].reshape(b, -1, hkv, d)
    cv = v_pool[tables].reshape(b, -1, hkv, d)
    s = ck.shape[1]
    n_rep = h // hkv
    if n_rep > 1:
        ck = jnp.broadcast_to(ck[:, :, :, None, :],
                              (b, s, hkv, n_rep, d)).reshape(b, s, h, d)
        cv = jnp.broadcast_to(cv[:, :, :, None, :],
                              (b, s, hkv, n_rep, d)).reshape(b, s, h, d)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q[:, None].astype(jnp.float32),
                        ck.astype(jnp.float32)) / math.sqrt(d)
    if cap > 0:
        logits = cap * jnp.tanh(logits / cap)
    pos_k = jnp.arange(s)
    pos_b = jnp.asarray(pos)                                # (B,)
    mask = pos_k[None, :] <= pos_b[:, None]                 # (B, S)
    if window > 0 and not chunked:
        mask &= pos_k[None, :] > pos_b[:, None] - window
    if window > 0 and chunked:
        mask &= (pos_k[None, :] // window) == (pos_b[:, None] // window)
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, cv.astype(jnp.float32))
    return out[:, 0].astype(q.dtype)
