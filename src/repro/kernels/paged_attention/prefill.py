"""Pallas TPU kernel: q-chunked prefill attention directly over a paged
KV pool.

Decode (``kernel.py``) reads one query token per slot against the slot's
pages.  Admission-time prefill is the other half: the WHOLE prompt's
queries attend the prompt's own keys, which the serving stack has just
scattered into :class:`~repro.serve.cache.PagedCache` pages.  Before
this kernel, prefill ran dense flash attention on a page-count-padded
copy of the prompt and a host-side ``_scatter_pages`` jit round-tripped
the dense KV into the pool afterwards.  This kernel reads the pool **in
place**:

    grid = (slot, q-chunk, page-block); the page-block axis is
    innermost, so it executes sequentially per (slot, q-chunk) and the
    online-softmax state (running max / denominator / weighted-value
    accumulator, one row per query in the chunk) lives in VMEM scratch
    across page blocks.

    The K/V block specs index the pool THROUGH the scalar-prefetched
    block table, exactly like decode: ``index_map = (tables[b, p], 0, 0,
    0)``.  Null (physical page 0) entries collapse consecutive dead
    iterations onto one block -- Pallas elides the re-fetch -- and
    ``pl.when`` skips their compute entirely, including every page that
    lies wholly above the q chunk (causal) or wholly below the attention
    window.

    GQA is in-kernel: one (Q*G, T) MXU dot per KV head group against the
    shared K page -- no head-repeated materialization.

Numerics contract: identical to decode -- masked positions score
``-1e30``, the two optimization barriers pin the rescale-then-add pair,
and :func:`paged_prefill_ref` mirrors the kernel operation-for-operation
(the tests assert bitwise equality in interpret mode).  Because every
output row is an independent online softmax over its own key range, the
result is bitwise independent of the q-chunk width.  Padded query rows
(positions at or beyond the slot's ``lens``) produce finite garbage that
the caller discards; they never influence real rows (causality).

:func:`paged_prefill_view` is the production off-TPU fallback: one
vectorized pool gather followed by the exact op sequence of
``blocks.flash_attention``, so CPU serving keeps the dense-vs-paged
token-equality invariant while TPU serving runs the in-place kernel.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.paged_attention.kernel import NEG_INF


def prefill_page_mask(page_start, qc_start, q_chunk: int, t: int, *,
                      window: int, chunked: bool):
    """(q_chunk, t) bool mask of attendable (query, key) position pairs
    for one q chunk against one page.

    ``page_start`` / ``qc_start`` may be python ints (reference path) or
    traced scalars (kernel path).  Matches ``blocks.flash_attention``'s
    causal / sliding-window / chunk-local mask formulas exactly.
    """
    pos_q = qc_start + jax.lax.broadcasted_iota(jnp.int32, (q_chunk, t), 0)
    pos_k = page_start + jax.lax.broadcasted_iota(jnp.int32, (q_chunk, t), 1)
    mask = pos_k <= pos_q
    if window > 0 and not chunked:
        mask &= pos_k > pos_q - window
    if window > 0 and chunked:
        mask &= (pos_k // window) == (pos_q // window)
    return mask


def prefill_page_live(phys, page_start, page_size: int, qc_start,
                      qc_end, *, window: int, chunked: bool):
    """Whether a page contributes to a q chunk at all: physically backed
    (non-null) AND not wholly above the chunk's last query (causal) AND
    not wholly below the chunk's attention window."""
    live = jnp.logical_and(phys != 0, page_start <= qc_end)
    page_end = page_start + page_size - 1
    if window > 0 and not chunked:
        live = jnp.logical_and(live, page_end > qc_start - window)
    if window > 0 and chunked:
        live = jnp.logical_and(live,
                               page_end >= (qc_start // window) * window)
    return live


def prefill_page_update(q, k, v, m, l, acc, page_start, qc_start, *,
                        scale: float, window: int, chunked: bool,
                        cap: float):
    """One page's online-softmax contribution for one q chunk.  Shared by
    the kernel body and :func:`paged_prefill_ref` so the two are bitwise
    identical.

    q: (Q, H, D) f32; k/v: (T, Hkv, D) f32; m/l: (Q, H, 1) f32 running
    max/denominator; acc: (Q, H, D) f32.  Returns updated (m, l, acc).
    """
    qc, h, d = q.shape
    t, hkv, _ = k.shape
    g = h // hkv
    rows = []
    for i in range(hkv):
        qg = q[:, i * g:(i + 1) * g, :].reshape(qc * g, d)
        rows.append(jax.lax.dot_general(
            qg, k[:, i, :], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32
        ).reshape(qc, g, t))                               # (Q, G, T)
    s = jnp.concatenate(rows, axis=1) * scale              # (Q, H, T)
    if cap > 0:
        s = cap * jnp.tanh(s / cap)
    mask = prefill_page_mask(page_start, qc_start, qc, t, window=window,
                             chunked=chunked)
    s = jnp.where(mask[:, None, :], s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m - m_new)
    # same contract as decode's page_update: the barriers pin the
    # rescale-then-add to two instructions in BOTH consumers, so the
    # kernel (VMEM scratch round-trips) and the python-looped reference
    # stay bitwise identical on multi-page prompts
    l_new = jax.lax.optimization_barrier(l * corr) \
        + jnp.sum(p, axis=-1, keepdims=True)
    outs = []
    for i in range(hkv):
        pg = p[:, i * g:(i + 1) * g, :].reshape(qc * g, t)
        outs.append(jax.lax.dot_general(
            pg, v[:, i, :], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32
        ).reshape(qc, g, d))                               # (Q, G, D)
    acc_new = jax.lax.optimization_barrier(acc * corr) \
        + jnp.concatenate(outs, axis=1)
    return m_new, l_new, acc_new


def _paged_prefill_kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref,
                          out_ref, m_ref, l_ref, acc_ref, *,
                          page_size: int, q_chunk: int, n_pb: int,
                          scale: float, window: int, chunked: bool,
                          cap: float):
    del lens_ref  # masking is purely positional; lens rides along so the
    #               engine's jit signature stays static across prompts
    b = pl.program_id(0)
    qc = pl.program_id(1)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    phys = tables_ref[b, p]
    page_start = p * page_size
    qc_start = qc * q_chunk
    qc_end = qc_start + q_chunk - 1
    live = prefill_page_live(phys, page_start, page_size, qc_start,
                             qc_end, window=window, chunked=chunked)

    @pl.when(live)
    def _compute():
        m_new, l_new, acc_new = prefill_page_update(
            q_ref[0].astype(jnp.float32), k_ref[0].astype(jnp.float32),
            v_ref[0].astype(jnp.float32), m_ref[...], l_ref[...],
            acc_ref[...], page_start, qc_start, scale=scale,
            window=window, chunked=chunked, cap=cap)
        m_ref[...] = m_new
        l_ref[...] = l_new
        acc_ref[...] = acc_new

    @pl.when(p == n_pb - 1)
    def _epilogue():
        out_ref[0] = (acc_ref[...]
                      / jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


def paged_prefill_fwd(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                      tables: jax.Array, lens: jax.Array, *,
                      window: int = 0, chunked: bool = False,
                      cap: float = 0.0, q_chunk: int = 16,
                      interpret: bool = True) -> jax.Array:
    """q: (B, S, H, D) with S a multiple of ``q_chunk`` (the caller pads;
    padded rows produce discarded garbage); k_pool/v_pool: (n_pages + 1,
    page_size, Hkv, D) with physical page 0 the reserved null page;
    tables: (B, P) int32 physical page ids (0 = unbacked); lens: (B,)
    int32 real prompt lengths.  Returns (B, S, H, D) in q's dtype.
    """
    b, s, h, d = q.shape
    page_size, hkv = k_pool.shape[1], k_pool.shape[2]
    n_pb = tables.shape[1]
    q_chunk = min(q_chunk, s)
    assert s % q_chunk == 0, (s, q_chunk)
    assert h % hkv == 0, (h, hkv)
    n_qc = s // q_chunk
    scale = 1.0 / math.sqrt(d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_qc, n_pb),
        in_specs=[
            pl.BlockSpec((1, q_chunk, h, d),
                         lambda bb, qc, p, tbl, ln: (bb, qc, 0, 0)),
            pl.BlockSpec((1, page_size, hkv, d),
                         lambda bb, qc, p, tbl, ln: (tbl[bb, p], 0, 0, 0)),
            pl.BlockSpec((1, page_size, hkv, d),
                         lambda bb, qc, p, tbl, ln: (tbl[bb, p], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_chunk, h, d),
                               lambda bb, qc, p, tbl, ln: (bb, qc, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((q_chunk, h, 1), jnp.float32),   # running max
            pltpu.VMEM((q_chunk, h, 1), jnp.float32),   # running denom
            pltpu.VMEM((q_chunk, h, d), jnp.float32),   # weighted-V acc
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_prefill_kernel, page_size=page_size,
                          q_chunk=q_chunk, n_pb=n_pb, scale=scale,
                          window=window, chunked=chunked, cap=cap),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, s, h, d), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), lens.astype(jnp.int32), q, k_pool, v_pool)


def paged_prefill_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                      tables: jax.Array, lens: jax.Array, *,
                      window: int = 0, chunked: bool = False,
                      cap: float = 0.0, q_chunk: int = 16) -> jax.Array:
    """Bitwise mirror of the Pallas prefill kernel: the same python loop
    over KV head groups, the same per-(q-chunk, page) 2-D dots, the same
    online-softmax update order (it calls the kernel's own
    :func:`prefill_page_update`).  Slots and q chunks unroll in python;
    the page axis is a ``lax.fori_loop`` whose carried state mirrors the
    kernel's VMEM scratch and whose ``lax.cond`` mirrors the ``pl.when``
    dead-page skip -- XLA compiles a python-unrolled page chain with
    different elementwise fusion than the kernel's sequential grid, so
    the loop structure itself is part of the bitwise contract.  An
    oracle, not a fast path."""
    b, s, h, d = q.shape
    page_size = k_pool.shape[1]
    n_pb = tables.shape[1]
    q_chunk = min(q_chunk, s)
    assert s % q_chunk == 0, (s, q_chunk)
    scale = 1.0 / math.sqrt(d)
    del lens  # masking is purely positional, exactly like the kernel
    outs = []
    for bi in range(b):
        chunks = []
        for ci in range(s // q_chunk):
            qc_start = ci * q_chunk
            qc_end = qc_start + q_chunk - 1
            qi = q[bi, qc_start:qc_start + q_chunk].astype(jnp.float32)
            m = jnp.full((q_chunk, h, 1), NEG_INF, jnp.float32)
            l = jnp.zeros((q_chunk, h, 1), jnp.float32)
            acc = jnp.zeros((q_chunk, h, d), jnp.float32)

            def page_body(p, state, qi=qi, bi=bi, qc_start=qc_start,
                          qc_end=qc_end):
                m, l, acc = state
                phys = tables[bi, p]
                page_start = p * page_size
                live = prefill_page_live(phys, page_start, page_size,
                                         qc_start, qc_end, window=window,
                                         chunked=chunked)
                k = jax.lax.dynamic_index_in_dim(
                    k_pool, phys, 0, keepdims=False).astype(jnp.float32)
                v = jax.lax.dynamic_index_in_dim(
                    v_pool, phys, 0, keepdims=False).astype(jnp.float32)
                # dead pages leave the state untouched and run no
                # arithmetic at all, exactly like pl.when (any NaN the
                # null page may hold never enters the taken branch)
                return jax.lax.cond(
                    live,
                    lambda st: prefill_page_update(
                        qi, k, v, *st, page_start, qc_start, scale=scale,
                        window=window, chunked=chunked, cap=cap),
                    lambda st: st,
                    (m, l, acc))

            m, l, acc = jax.lax.fori_loop(0, n_pb, page_body,
                                          (m, l, acc))
            chunks.append((acc / jnp.maximum(l, 1e-30)).astype(q.dtype))
        outs.append(jnp.concatenate(chunks, axis=0))
    return jnp.stack(outs)


def paged_prefill_view(q: jax.Array, k_pool: jax.Array,
                       v_pool: jax.Array, tables: jax.Array,
                       lens: jax.Array, *, window: int = 0,
                       chunked: bool = False, cap: float = 0.0
                       ) -> jax.Array:
    """Gathered-view fallback: pool pages -> dense (B, P * page_size)
    KV rows, then the dense flash-attention math.  NOTE: the op sequence
    below deliberately replicates ``blocks.flash_attention`` (repeat_kv,
    the per-q-chunk static kv ranges, the kv lax.scan with the carried
    chunk counter, the einsum specs, -1e30 masking) so real query rows
    are bitwise identical to the dense cache backend's prefill -- the
    extra masked tail keys score -1e30 and contribute exact zeros.
    ``blocks`` cannot be imported here (it imports this package), hence
    the inline replica.
    """
    b, s, h, d = q.shape
    hkv = k_pool.shape[2]
    k = k_pool[tables].reshape(b, -1, hkv, d)
    v = v_pool[tables].reshape(b, -1, hkv, d)
    del lens  # real rows self-select via the causal mask
    skv = k.shape[1]
    n_rep = h // hkv
    if n_rep > 1:
        k = jnp.broadcast_to(k[:, :, :, None, :],
                             (b, skv, hkv, n_rep, d)).reshape(b, skv, h, d)
        v = jnp.broadcast_to(v[:, :, :, None, :],
                             (b, skv, hkv, n_rep, d)).reshape(b, skv, h, d)
    scale = 1.0 / math.sqrt(d)
    q_chunk = min(1024, s)
    kv_chunk = min(1024, skv)
    assert s % q_chunk == 0 and skv % kv_chunk == 0

    outs = []
    for i in range(s // q_chunk):
        q0 = i * q_chunk
        qi = q[:, q0:q0 + q_chunk]                       # (B, Q, H, D)
        pos_q = q0 + jnp.arange(q_chunk)
        hi = min(q0 + q_chunk, skv)
        lo = 0
        if window > 0:
            lo = max(0, q0 - (window - 1)) if not chunked \
                else (q0 // window) * window
        lo = (lo // kv_chunk) * kv_chunk
        hi_pad = -(-hi // kv_chunk) * kv_chunk
        hi_pad = min(hi_pad, skv)
        n_kv = max((hi_pad - lo) // kv_chunk, 1)
        ks = jax.lax.dynamic_slice_in_dim(k, lo, n_kv * kv_chunk, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, lo, n_kv * kv_chunk, 1)
        ks = ks.reshape(b, n_kv, kv_chunk, h, d).transpose(1, 0, 2, 3, 4)
        vs = vs.reshape(b, n_kv, kv_chunk, h, d).transpose(1, 0, 2, 3, 4)

        def body(carry, inp):
            m, l, acc, j = carry
            kj, vj = inp
            p0 = lo + j * kv_chunk
            pos_k = p0 + jnp.arange(kv_chunk)
            sij = jnp.einsum("bqhd,bkhd->bhqk", qi.astype(jnp.float32),
                             kj.astype(jnp.float32)) * scale
            if cap > 0:
                sij = cap * jnp.tanh(sij / cap)
            mask = pos_k[None, :] <= pos_q[:, None]
            if window > 0 and not chunked:
                mask &= pos_k[None, :] > pos_q[:, None] - window
            if window > 0 and chunked:
                mask &= (pos_k[None, :] // window) == \
                    (pos_q[:, None] // window)
            sij = jnp.where(mask[None, None], sij, -1e30)
            m_new = jnp.maximum(m, jnp.max(sij, axis=-1))
            p = jnp.exp(sij - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vj.astype(jnp.float32))
            return (m_new, l_new, acc_new, j + 1), None

        m0 = jnp.full((b, h, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, d), jnp.float32)
        (m, l, acc, _), _ = jax.lax.scan(
            body, (m0, l0, a0, jnp.asarray(0, jnp.int32)), (ks, vs))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(out.transpose(0, 2, 1, 3).astype(q.dtype))
    return jnp.concatenate(outs, axis=1)                 # (B, S, H, D)
