"""Pure-jnp oracle for the mps_combine kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mps_combine_ref(w: jax.Array, probs: jax.Array,
                    precisions: tuple[int, ...]) -> jax.Array:
    """w: (M, K); probs: (M, |P|) rows summing to 1. Matches
    repro.core.mps.effective_weight with channel_axis=0 (given probs)."""
    absmax = jnp.max(jnp.abs(w), axis=1, keepdims=True)
    acc = jnp.zeros_like(w)
    for idx, bits in enumerate(precisions):
        if bits == 0:
            continue
        qmax = float(2 ** (bits - 1) - 1)
        scale = jnp.maximum(absmax, 1e-8) / qmax
        q = jnp.clip(jnp.round(w / scale), -qmax, qmax) * scale
        acc = acc + probs[:, idx:idx + 1] * q
    return acc
