"""Jitted wrapper around the mps_combine kernel with a custom VJP.

Forward: Pallas kernel (interpret=True on CPU, compiled on TPU).
Backward: straight-through-estimator gradients in plain jnp --
  dL/dW[i,k]   = g[i,k] * sum_p probs[i,p] * 1{|W/s_p| <= qmax_p}  (STE)
  dL/dprobs[i,p] = sum_k g[i,k] * Q_p(W)[i,k]
(the per-channel min-max scale is treated as a constant, as in
repro.core.quantizers.quantize_weights_symmetric).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mps_combine import kernel as _k
from repro.kernels.mps_combine import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def mps_combine(w: jax.Array, probs: jax.Array,
                precisions: tuple[int, ...]) -> jax.Array:
    """Effective weight sum_p probs[:, p] * Q_p(w). w: (M, K) f32."""
    return _fwd_impl(w, probs, precisions)


def _fwd_impl(w, probs, precisions):
    m, k = w.shape
    absmax = jnp.max(jnp.abs(w), axis=1, keepdims=True)
    bm = min(_k.DEFAULT_BM, max(8, m))
    bk = min(_k.DEFAULT_BK, max(128, k))
    wp = _pad_to(_pad_to(w, bm, 0), bk, 1)
    ap = _pad_to(absmax, bm, 0)
    pp = _pad_to(probs, bm, 0)
    out = _k.mps_combine_fwd(wp, ap, pp, precisions, bm=bm, bk=bk,
                             interpret=not _on_tpu())
    return out[:m, :k]


def _vjp_fwd(w, probs, precisions):
    return _fwd_impl(w, probs, precisions), (w, probs)


def _vjp_bwd(precisions, res, g):
    w, probs = res
    absmax = jnp.max(jnp.abs(w), axis=1, keepdims=True)
    dw = jnp.zeros_like(w)
    dprobs_cols = []
    for idx, bits in enumerate(precisions):
        if bits == 0:
            dprobs_cols.append(jnp.zeros(w.shape[0], w.dtype))
            continue
        qmax = float(2 ** (bits - 1) - 1)
        scale = jnp.maximum(absmax, 1e-8) / qmax
        ratio = w / scale
        # match jnp.clip's tie-splitting convention: gradient 0.5 exactly
        # on the clip boundary (each row's absmax element lands there)
        inside = (jnp.abs(ratio) < qmax).astype(w.dtype) \
            + 0.5 * (jnp.abs(ratio) == qmax).astype(w.dtype)
        q = jnp.clip(jnp.round(ratio), -qmax, qmax) * scale
        dw = dw + probs[:, idx:idx + 1] * inside * g
        dprobs_cols.append(jnp.sum(g * q, axis=1))
    return dw, jnp.stack(dprobs_cols, axis=-1)


mps_combine.defvjp(_vjp_fwd, _vjp_bwd)

mps_combine_ref = _ref.mps_combine_ref
