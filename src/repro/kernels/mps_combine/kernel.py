"""Pallas TPU kernel: fused multi-precision fake-quant + convex combine.

The search-phase hot spot (paper Eq. 5): W_hat = sum_p gamma_hat[:, p] *
Q_p(W). A naive implementation reads/writes W once per precision (|P_W|
quantize passes + a weighted sum: ~2|P|+1 HBM round trips of W). This kernel
computes all precisions from a single VMEM-resident tile: 1 read + 1 write.

Tiling: W is blocked (BM x BK) with BM on the output-channel axis; the
per-channel absmax (precomputed, BM x 1) and selection probabilities
(BM x |P|) ride along the row axis. All shapes are padded to (8, 128)
multiples by ops.py so MXU/VPU lanes stay aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 256
DEFAULT_BK = 512


def _combine_kernel(w_ref, absmax_ref, probs_ref, out_ref, *, precisions):
    w = w_ref[...]                       # (BM, BK)
    absmax = absmax_ref[...]             # (BM, 1)
    probs = probs_ref[...]               # (BM, |P|)
    acc = jnp.zeros_like(w)
    for idx, bits in enumerate(precisions):
        if bits == 0:
            continue                     # 0-bit variant contributes zeros
        qmax = float(2 ** (bits - 1) - 1)
        scale = jnp.maximum(absmax, 1e-8) / qmax
        q = jnp.clip(jnp.round(w / scale), -qmax, qmax) * scale
        acc = acc + probs[:, idx:idx + 1] * q
    out_ref[...] = acc


def mps_combine_fwd(w: jax.Array, absmax: jax.Array, probs: jax.Array,
                    precisions: tuple[int, ...], *, bm: int = DEFAULT_BM,
                    bk: int = DEFAULT_BK, interpret: bool = True
                    ) -> jax.Array:
    """w: (M, K) padded; absmax: (M, 1); probs: (M, |P|). Returns (M, K)."""
    m, k = w.shape
    n_p = probs.shape[-1]
    bm = min(bm, m)
    bk = min(bk, k)
    grid = (m // bm, k // bk)
    return pl.pallas_call(
        functools.partial(_combine_kernel, precisions=tuple(precisions)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, n_p), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), w.dtype),
        interpret=interpret,
    )(w, absmax, probs)
