"""Learning-rate and temperature schedules (all return step -> value)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(v: float):
    return lambda step: jnp.asarray(v, jnp.float32)


def exponential_decay(base: float, decay: float, steps_per_epoch: int = 1):
    """Paper: LR * 0.99 per epoch (CIFAR-10)."""
    def fn(step):
        epoch = step // steps_per_epoch
        return jnp.asarray(base, jnp.float32) * jnp.power(
            jnp.asarray(decay, jnp.float32), epoch)
    return fn


def step_decay(base: float, boundaries: tuple, factors: tuple,
               steps_per_epoch: int = 1):
    """Paper GSC: halve at epochs 50/100, /2.5 at 150. Boundaries in epochs."""
    def fn(step):
        epoch = step // steps_per_epoch
        v = jnp.asarray(base, jnp.float32)
        for b, f in zip(boundaries, factors):
            v = jnp.where(epoch >= b, v * f, v)
        return v
    return fn


def cosine(base: float, total_steps: int, warmup_steps: int = 0,
           final_frac: float = 0.0):
    def fn(step):
        step_f = jnp.asarray(step, jnp.float32)
        warm = step_f / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((step_f - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(
            jnp.pi * prog))
        return base * jnp.where(step_f < warmup_steps, warm, cos)
    return fn


def wsd(base: float, total_steps: int, warmup_frac: float = 0.01,
        decay_frac: float = 0.1, final_frac: float = 0.0):
    """Warmup-Stable-Decay (MiniCPM, arXiv:2404.06395)."""
    warmup = max(int(total_steps * warmup_frac), 1)
    decay_start = int(total_steps * (1 - decay_frac))

    def fn(step):
        step_f = jnp.asarray(step, jnp.float32)
        warm = step_f / warmup
        decay_prog = jnp.clip((step_f - decay_start)
                              / jnp.maximum(total_steps - decay_start, 1),
                              0, 1)
        dec = 1 - (1 - final_frac) * decay_prog
        v = jnp.where(step_f < warmup, warm,
                      jnp.where(step_f < decay_start, 1.0, dec))
        return base * v
    return fn
