"""Gradient utilities: clipping, micro-batch accumulation, int8
error-feedback compression for cross-pod all-reduce."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda x: x * scale, tree), norm


def accumulate_grads(loss_fn, params, batches):
    """Average grads over micro-batches with a lax.scan (constant memory)."""
    def body(acc, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        acc_g, acc_l = acc
        new_g = jax.tree.map(jnp.add, acc_g, grads)
        return (new_g, acc_l + loss), None

    zero = jax.tree.map(jnp.zeros_like, params)
    (tot_g, tot_l), _ = jax.lax.scan(body, (zero, 0.0), batches)
    n = jax.tree.leaves(batches)[0].shape[0]
    return (jax.tree.map(lambda g: g / n, tot_g), tot_l / n)


# ---------------------------------------------------------------------------
# int8 error-feedback gradient compression (cross-pod all-reduce)
# ---------------------------------------------------------------------------

def compress_int8(g: jax.Array):
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, error):
    """Error-feedback compression: q = Q(g + e); new_e = (g + e) - dq(q).

    The residual ``error`` pytree is carried across steps so quantization
    noise is unbiased over time (Karimireddy et al. style EF-SGD).
    """
    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = compress_int8(corrected)
        dq = decompress_int8(q, s)
        return (q, s), corrected - dq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    comp = treedef.unflatten([o[0] for o in out])
    new_err = treedef.unflatten([o[1] for o in out])
    return comp, new_err


def ef_decompress_tree(comp):
    return jax.tree.map(lambda qs: decompress_int8(*qs), comp,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and len(x) == 2 and hasattr(x[0], "dtype"))


def init_error_tree(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
