"""Functional optimizers (optax-style init/update pairs) over pytrees.

Includes an int8 block-quantized-state Adam for 100B+ parameter models
(optimizer memory 2 bytes/param + scales instead of 8).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable   # (grads, state, params, step) -> (new_params, state)


def _tree_map(f, *trees):
    return jax.tree.map(f, *trees)


def sgd(lr: Callable | float, momentum: float = 0.0,
        weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        if momentum == 0.0:
            return ()
        return _tree_map(jnp.zeros_like, params)

    def update(grads, state, params, step):
        lr_t = lr_fn(step)
        if weight_decay:
            grads = _tree_map(lambda g, p: g + weight_decay * p, grads,
                              params)
        if momentum == 0.0:
            new_params = _tree_map(lambda p, g: p - lr_t * g, params, grads)
            return new_params, state
        new_state = _tree_map(lambda m, g: momentum * m + g, state, grads)
        new_params = _tree_map(lambda p, m: p - lr_t * m, params, new_state)
        return new_params, new_state

    return Optimizer(init, update)


def adam(lr: Callable | float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    """AdamW when weight_decay > 0 (decoupled decay)."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"m": _tree_map(jnp.zeros_like, params),
                "v": _tree_map(jnp.zeros_like, params)}

    def update(grads, state, params, step):
        t = step + 1
        lr_t = lr_fn(step)
        m = _tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"],
                      grads)
        v = _tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"],
                      grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def step_fn(p, m_, v_):
            upd = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                upd = upd + weight_decay * p
            return p - lr_t * upd

        new_params = _tree_map(step_fn, params, m, v)
        return new_params, {"m": m, "v": v}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# int8 quantized optimizer state (distributed-scale memory saver)
# ---------------------------------------------------------------------------
# Moments are stored int8 *with the parameter's shape* (so they inherit the
# parameter's sharding unchanged) plus one f32 scale per last-axis row
# (shape = param.shape[:-1], sharded like the parameter minus its last
# axis). v is quantized in sqrt-space for relative precision.


def _q8_row(x: jax.Array):
    if x.ndim == 0:
        scale = jnp.maximum(jnp.abs(x), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return q, scale.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0].astype(jnp.float32)


def _dq8_row(q: jax.Array, scale: jax.Array):
    if q.ndim == 0:
        return q.astype(jnp.float32) * scale
    return q.astype(jnp.float32) * scale[..., None]


def adam_int8(lr: Callable | float, b1: float = 0.9, b2: float = 0.999,
              eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    """Adam with int8 row-quantized first/second moments (2 bytes+/param)."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        def leaf(p):
            z = jnp.zeros(p.shape, jnp.float32)
            mq, ms = _q8_row(z)
            vq, vs = _q8_row(z)
            return {"mq": mq, "ms": ms, "vq": vq, "vs": vs}
        return _tree_map(leaf, params)

    def update(grads, state, params, step):
        t = step + 1
        lr_t = lr_fn(step)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def leaf(p, g, s):
            g = g.astype(jnp.float32)
            m = _dq8_row(s["mq"], s["ms"])
            vsqrt = _dq8_row(s["vq"], s["vs"])
            v = vsqrt * vsqrt
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr_t * upd).astype(p.dtype)
            mq, ms = _q8_row(m)
            vq, vs = _q8_row(jnp.sqrt(v))
            return new_p, {"mq": mq, "ms": ms, "vq": vq, "vs": vs}

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state)
        outs = [leaf(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = treedef.unflatten([o[0] for o in outs])
        new_state = treedef.unflatten([o[1] for o in outs])
        return new_params, new_state

    return Optimizer(init, update)


def state_logical_axes(opt_name: str, params_logical):
    """Logical-axis tree matching the optimizer state structure.

    params_logical leaves are tuples of logical axis names (or None).
    """
    def like(l):
        return l

    def minus_last(l):
        return tuple(l[:-1]) if isinstance(l, tuple) and len(l) > 0 else ()

    is_leaf = lambda x: isinstance(x, tuple)  # noqa: E731
    if opt_name == "adam":
        return {"m": params_logical, "v": params_logical}
    if opt_name == "adam_int8":
        return jax.tree.map(
            lambda l: {"mq": like(l), "ms": minus_last(l),
                       "vq": like(l), "vs": minus_last(l)},
            params_logical, is_leaf=is_leaf)
    if opt_name == "sgd":
        return ()
    raise ValueError(opt_name)


def make_optimizer(name: str, lr) -> Optimizer:
    if name == "adam":
        return adam(lr)
    if name == "adam_int8":
        return adam_int8(lr)
    if name == "sgd":
        return sgd(lr, momentum=0.9)
    raise ValueError(name)


def multi_optimizer(partition_fn, optimizers: dict) -> Optimizer:
    """Route different pytree leaves to different optimizers.

    ``partition_fn(path, leaf) -> key in optimizers``. Used for the search
    phase: DNN weights -> Adam/SGD, selection parameters -> SGD(0.9) with
    their own LR (paper Sec. 5.1.1).
    """
    def init(params):
        # each sub-optimizer keeps state for the full tree (simple + correct;
        # non-owned leaves see zero gradients and are never written back)
        return {key: opt.init(params) for key, opt in optimizers.items()}

    def update(grads, state, params, step):
        labels = jax.tree_util.tree_map_with_path(partition_fn, params)
        new_params = params
        new_states = {}
        for key, opt in optimizers.items():
            g_masked = jax.tree.map(
                lambda g, l: g if l == key else jnp.zeros_like(g), grads,
                labels)
            p_upd, s_new = opt.update(g_masked, state[key], new_params,
                                      step)
            new_params = jax.tree.map(
                lambda p, pn, l: pn if l == key else p, new_params, p_upd,
                labels)
            new_states[key] = s_new
        return new_params, new_states

    return Optimizer(init, update)
