"""Graph-interpreted CNNs: the paper's three reference networks.

A model is a tuple of :class:`Node` records executed in order. The same
graph definition drives:
  * float training (warmup)            mode="float"
  * joint MPS + pruning search         mode="search"  (paper Sec. 4)
  * discretized quantized inference    mode="quant"   (after Eq. 7/8)
and produces the :class:`~repro.core.costs.LayerGeom` records the cost
regularizers consume.

Reference architectures (sizes match the paper's Sec. 5.1 baselines):
  * resnet9   -- CIFAR-10, 9 conv layers, ~77.4k params (309.44 kB FP32)
  * dscnn     -- Google Speech Commands, ~22k params (88.06 kB FP32)
  * resnet18  -- Tiny ImageNet (200 classes), ~11.26M params (45.05 MB FP32)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costs, mps, quantizers
from repro.nn import layers


@dataclasses.dataclass(frozen=True)
class Node:
    name: str
    kind: str                       # conv|dwconv|linear|add|maxpool|avgpool|gap|input
    inputs: tuple[str, ...] = ()
    cout: int = 0
    k: tuple[int, int] = (1, 1)
    stride: tuple[int, int] = (1, 1)
    pad: str = "SAME"
    act: str = "none"               # none | relu
    bn: bool = True
    gamma_group: str = ""           # shared selection-parameter group

    def group(self) -> str:
        return self.gamma_group or self.name


WEIGHT_KINDS = ("conv", "dwconv", "linear")


@dataclasses.dataclass(frozen=True)
class GraphDef:
    nodes: tuple[Node, ...]
    in_shape: tuple[int, int, int]      # (H, W, C)
    num_classes: int

    def weight_nodes(self):
        return [n for n in self.nodes if n.kind in WEIGHT_KINDS]

    def node(self, name: str) -> Node:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)


# ---------------------------------------------------------------------------
# shape/channel inference
# ---------------------------------------------------------------------------

def _trace_shapes(g: GraphDef):
    """Channel count and spatial (H, W) at every node output."""
    ch = {"input": g.in_shape[2]}
    hw = {"input": (g.in_shape[0], g.in_shape[1])}
    for n in g.nodes:
        if n.kind == "input":
            continue
        src = n.inputs[0]
        h, w = hw[src]
        if n.kind in ("conv", "dwconv"):
            sy, sx = n.stride
            if n.pad == "SAME":
                oh, ow = -(-h // sy), -(-w // sx)
            else:
                oh = (h - n.k[0]) // sy + 1
                ow = (w - n.k[1]) // sx + 1
            ch[n.name] = n.cout if n.kind == "conv" else ch[src]
            hw[n.name] = (oh, ow)
        elif n.kind == "linear":
            ch[n.name], hw[n.name] = n.cout, (1, 1)
        elif n.kind == "add":
            ch[n.name], hw[n.name] = ch[src], hw[src]
        elif n.kind in ("maxpool", "avgpool"):
            sy, sx = n.stride
            ch[n.name], hw[n.name] = ch[src], (h // sy, w // sx)
        elif n.kind == "gap":
            ch[n.name], hw[n.name] = ch[src], (1, 1)
        else:
            raise ValueError(n.kind)
    return ch, hw


def _producer_weight_node(g: GraphDef, name: str) -> Optional[Node]:
    """Nearest upstream weight node (through pools/gap; `add` returns one of
    the two producers -- they share a gamma group by construction)."""
    n = g.node(name) if name != "input" else None
    if n is None:
        return None
    if n.kind in WEIGHT_KINDS:
        return n
    return _producer_weight_node(g, n.inputs[0])


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(g: GraphDef, key: jax.Array):
    ch, _ = _trace_shapes(g)
    params = {}
    for n in g.weight_nodes():
        key, sub = jax.random.split(key)
        cin = ch[n.inputs[0]]
        if n.kind == "conv":
            shape = (n.cout, cin, n.k[0], n.k[1])
            fan_in = cin * n.k[0] * n.k[1]
        elif n.kind == "dwconv":
            shape = (cin, 1, n.k[0], n.k[1])
            fan_in = n.k[0] * n.k[1]
        else:
            shape = (n.cout, cin)
            fan_in = cin
        p = {"w": layers.he_init(sub, shape, fan_in),
             "b": jnp.zeros((shape[0],))}
        if n.bn:
            p["bn"] = layers.bn_init(shape[0])
        params[n.name] = p
    return params


def init_mps_params(g: GraphDef, pw: tuple[int, ...], px: tuple[int, ...],
                    layerwise: bool = False):
    """gamma per shared group, delta+alpha per weight node output.

    layerwise=True emulates EdMIPS-style per-layer precision assignment:
    a single gamma row per layer, broadcast over channels."""
    ch, _ = _trace_shapes(g)
    gammas, deltas, alphas = {}, {}, {}
    last = g.weight_nodes()[-1]
    for n in g.weight_nodes():
        grp = n.group()
        if grp not in gammas:
            c = 1 if layerwise else ch[n.name]
            gamma = mps.init_mps_weight(c, pw)
            if n.name == last.name and 0 in pw:
                # never prune the classifier's output channels -- they are
                # the classes (cf. paper Fig. 7: L_Out is never pruned)
                gamma = gamma.at[..., pw.index(0)].set(-40.0)
            gammas[grp] = gamma
        d, a = mps.init_mps_act(px)
        deltas[n.name] = d
        alphas[n.name] = a
    return {"gamma": gammas, "delta": deltas, "alpha": alphas}


def param_count(params) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(params)))


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------

def apply(g: GraphDef, params, x, *, mode: str = "float", train: bool = False,
          mps_params=None, ctx: mps.SearchCtx | None = None,
          pw=(0, 2, 4, 8), px=(8,), assignment=None, folded: bool = False):
    """Run the graph. Returns (logits, new_params_with_bn_stats).

    mode="float" : plain float network (+BN when not folded)
    mode="search": effective weights/activations from the MPS parameters
    mode="quant" : discrete per-channel fake-quant at `assignment` bits
    """
    vals = {"input": x}
    new_params = dict(params)
    tag = 0
    for n in g.nodes:
        if n.kind == "input":
            continue
        src = vals[n.inputs[0]]
        if n.kind in WEIGHT_KINDS:
            tag += 1
            p = params[n.name]
            w = p["w"]
            if mode == "search":
                gamma = mps_params["gamma"][n.group()]
                w = mps.effective_weight(w, gamma, pw, ctx, 0, tag)
            elif mode == "quant":
                w = _assigned_quant_weight(w, assignment["gamma"][n.group()])
            if n.kind == "dwconv":
                out = layers.conv2d(src, w, p["b"], n.stride[0], n.pad,
                                    groups=src.shape[-1])
            elif n.kind == "conv":
                out = layers.conv2d(src, w, p["b"], n.stride[0], n.pad)
            else:
                if src.ndim > 2:
                    src = src.reshape(src.shape[0], -1)
                out = layers.linear(src, w, p["b"])
            if n.bn and not folded:
                out, new_bn = layers.batchnorm(out, p["bn"], train)
                if train:
                    np_ = dict(new_params[n.name])
                    np_["bn"] = new_bn
                    new_params[n.name] = np_
            # activation (float relu, or PACT-quantized in search/quant)
            if n.act == "relu":
                if mode == "float":
                    out = jax.nn.relu(out)
                elif mode == "search":
                    out = mps.effective_activation(
                        out, mps_params["delta"][n.name],
                        mps_params["alpha"][n.name], px, ctx, tag)
                else:
                    out = quantizers.pact_quantize(
                        out, assignment["alpha"][n.name],
                        assignment["delta"][n.name])
            vals[n.name] = out
        elif n.kind == "add":
            vals[n.name] = vals[n.inputs[0]] + vals[n.inputs[1]]
        elif n.kind == "maxpool":
            vals[n.name] = layers.max_pool(src, n.k[0], n.stride[0])
        elif n.kind == "avgpool":
            vals[n.name] = layers.avg_pool(src, n.k[0], n.stride[0])
        elif n.kind == "gap":
            vals[n.name] = layers.global_avg_pool(src)
        else:
            raise ValueError(n.kind)
    return vals[g.nodes[-1].name], new_params


def _assigned_quant_weight(w, channel_bits):
    """Discrete per-channel fake quantization at the assigned precisions."""
    out = jnp.zeros_like(w)
    for b in (2, 4, 8):
        mask = (channel_bits == b).reshape((-1,) + (1,) * (w.ndim - 1))
        out = out + jnp.where(mask, quantizers.quantize_weights_symmetric(
            w, b, 0), 0.0)
    return out  # 0-bit channels stay zero (pruned)


# ---------------------------------------------------------------------------
# BN folding (paper Sec. 4.2, before the search phase)
# ---------------------------------------------------------------------------

def fold_batchnorm(g: GraphDef, params):
    new = {}
    for n in g.weight_nodes():
        p = dict(params[n.name])
        if n.bn and "bn" in p:
            w, b = layers.fold_bn_into_conv(p["w"], p["b"], p["bn"])
            p = {"w": w, "b": b}
        new[n.name] = {"w": p["w"], "b": p["b"]}
    return new


# ---------------------------------------------------------------------------
# cost geometry extraction
# ---------------------------------------------------------------------------

def cost_geoms(g: GraphDef) -> list[costs.LayerGeom]:
    ch, hw = _trace_shapes(g)
    geoms = []
    for n in g.weight_nodes():
        prod = _producer_weight_node(g, n.inputs[0])
        oh, ow = hw[n.name]
        geoms.append(costs.LayerGeom(
            name=n.name,
            kind=n.kind if n.kind != "linear" else "linear",
            cin=ch[n.inputs[0]] if n.kind != "linear"
                else int(np.prod(hw[n.inputs[0]])) * ch[n.inputs[0]],
            cout=ch[n.name],
            kx=n.k[1], ky=n.k[0],
            out_h=oh, out_w=ow,
            gamma=n.group(),
            in_gamma=prod.group() if prod is not None else None,
            in_delta=prod.name if prod is not None else None,
        ))
    return geoms


# ---------------------------------------------------------------------------
# the three reference networks
# ---------------------------------------------------------------------------

def resnet9(num_classes: int = 10, in_shape=(32, 32, 3), width: int = 16
            ) -> GraphDef:
    """MLPerf-Tiny-style ResNet with 9 conv layers (paper CIFAR-10 net)."""
    w = width
    nodes = [Node("input", "input")]

    def conv(name, src, cout, k=3, s=1, act="relu", grp=""):
        nodes.append(Node(name, "conv", (src,), cout, (k, k), (s, s),
                          act=act, gamma_group=grp))
        return name

    conv("stem", "input", w)
    # stack 1 (no downsample, identity shortcut): the residual add makes
    # stem and c1b share one gamma group (reconvergent channels, Sec. 4.1)
    conv("s1a", "stem", w)
    conv("s1b", "s1a", w, act="none", grp="stem")
    nodes.append(Node("add1", "add", ("s1b", "stem")))
    # stack 2 (stride-2, 1x1 conv shortcut) -- shortcut + main share gammas
    conv("s2a", "add1", 2 * w, s=2)
    conv("s2b", "s2a", 2 * w, act="none", grp="blk2")
    conv("sc2", "add1", 2 * w, k=1, s=2, act="none", grp="blk2")
    nodes.append(Node("add2", "add", ("s2b", "sc2")))
    # stack 3
    conv("s3a", "add2", 4 * w, s=2)
    conv("s3b", "s3a", 4 * w, act="none", grp="blk3")
    conv("sc3", "add2", 4 * w, k=1, s=2, act="none", grp="blk3")
    nodes.append(Node("add3", "add", ("s3b", "sc3")))
    nodes.append(Node("gap", "gap", ("add3",)))
    nodes.append(Node("fc", "linear", ("gap",), num_classes, bn=False,
                      act="none"))
    return GraphDef(tuple(nodes), in_shape, num_classes)


def dscnn(num_classes: int = 12, in_shape=(49, 10, 1), width: int = 64
          ) -> GraphDef:
    """MLPerf-Tiny DS-CNN for keyword spotting (paper GSC net).

    Pointwise->depthwise gamma sharing (Sec. 4.1): each depthwise conv
    shares the selection parameters of the pointwise conv that feeds it.
    """
    w = width
    nodes = [Node("input", "input"),
             Node("stem", "conv", ("input",), w, (10, 4), (2, 2),
                  act="relu")]
    prev = "stem"
    prev_grp = "stem"
    for i in range(4):
        dw, pw_ = f"dw{i}", f"pw{i}"
        # depthwise filters are tied to the channels produced upstream
        nodes.append(Node(dw, "dwconv", (prev,), w, (3, 3), (1, 1),
                          act="relu", gamma_group=prev_grp))
        nodes.append(Node(pw_, "conv", (dw,), w, (1, 1), (1, 1), act="relu"))
        prev, prev_grp = pw_, pw_
    nodes.append(Node("gap", "gap", (prev,)))
    nodes.append(Node("fc", "linear", ("gap",), num_classes, bn=False))
    return GraphDef(tuple(nodes), in_shape, num_classes)


def resnet18(num_classes: int = 200, in_shape=(64, 64, 3)) -> GraphDef:
    """ResNet-18 with a 3x3 stem (paper Tiny ImageNet net, ~11.26M params)."""
    nodes = [Node("input", "input"),
             Node("stem", "conv", ("input",), 64, (3, 3), (1, 1),
                  act="relu")]
    prev = "stem"
    stream_grp = "stem"  # gamma group of the current residual stream
    for stage, (cout, blocks) in enumerate([(64, 2), (128, 2), (256, 2),
                                            (512, 2)]):
        for b in range(blocks):
            s = 2 if (stage > 0 and b == 0) else 1
            base = f"st{stage}b{b}"
            downsample = stage > 0 and b == 0
            # every conv feeding the residual add of one stream shares a
            # gamma group so pruned channels line up (paper Sec. 4.1)
            grp = base if downsample else stream_grp
            nodes.append(Node(base + "a", "conv", (prev,), cout, (3, 3),
                              (s, s), act="relu"))
            nodes.append(Node(base + "b", "conv", (base + "a",), cout,
                              (3, 3), (1, 1), act="none", gamma_group=grp))
            if downsample:
                nodes.append(Node(base + "sc", "conv", (prev,), cout, (1, 1),
                                  (s, s), act="none", gamma_group=grp))
                shortcut = base + "sc"
                stream_grp = grp
            else:
                shortcut = prev  # identity; same stream group by definition
            nodes.append(Node(base + "add", "add", (base + "b", shortcut)))
            prev = base + "add"
    nodes.append(Node("gap", "gap", (prev,)))
    nodes.append(Node("fc", "linear", ("gap",), num_classes, bn=False))
    return GraphDef(tuple(nodes), in_shape, num_classes)


CNN_BUILDERS = {"resnet9": resnet9, "dscnn": dscnn, "resnet18": resnet18}
