"""Generic LM covering all 10 assigned architectures.

One decoder implementation parameterized by ArchConfig:
  * layer "super-block" patterns (dense, local/global, chunked+MoE, jamba
    1:7 mamba:attn with alternating MoE, pure SSM, enc-dec)
  * jax.lax.scan over super-blocks (HLO size independent of depth) with
    optional remat
  * the paper's channel-wise MPS + pruning as a first-class mode: every
    projection weight can carry per-output-channel bit-width selection
    parameters; mode="search" computes effective weights (Eq. 5) and the
    differentiable size cost

Entry points:
  init_params(cfg, key)          -> params pytree (use jax.eval_shape for
                                    the dry-run; real init for training)
  logical_axes(cfg)              -> same-structure pytree of logical axis
                                    tuples (resolved via sharding.spec)
  loss_fn / prefill / decode_step
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import mps, sampling
from repro.distributed import sharding
from repro.nn import blocks
from repro.nn import quantized as nnq


# ---------------------------------------------------------------------------
# layer patterns
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str           # attn | attn_local | attn_chunked | attn_bidir | mamba
    ffn: Optional[str]   # dense | moe | None
    cross: bool = False


def block_pattern(cfg: ArchConfig) -> tuple[LayerSpec, ...]:
    """Decoder super-block pattern; n_layers % len(pattern) == 0."""
    if cfg.is_hybrid:  # jamba: 1:7 attn:mamba, MoE every other layer
        out = []
        for i in range(cfg.attn_every):
            mixer = "attn" if i == cfg.attn_every // 2 else "mamba"
            ffn = "moe" if (i % 2 == 1) else "dense"
            out.append(LayerSpec(mixer, ffn))
        return tuple(out)
    if cfg.is_ssm:
        return (LayerSpec("mamba", None),)
    if cfg.attn_pattern == "local_global":
        return (LayerSpec("attn_local", "dense"), LayerSpec("attn", "dense"))
    if cfg.attn_pattern == "chunked":
        ffn = "moe" if cfg.is_moe else "dense"
        return (LayerSpec("attn_chunked", ffn),) * 3 + (LayerSpec("attn",
                                                                  ffn),)
    ffn = "moe" if cfg.is_moe else "dense"
    if cfg.is_moe and cfg.moe_every > 1:
        return tuple(LayerSpec("attn", "moe" if i % cfg.moe_every ==
                               cfg.moe_every - 1 else "dense")
                     for i in range(cfg.moe_every))
    return (LayerSpec("attn", ffn, cross=cfg.is_encdec),)


def enc_pattern(cfg: ArchConfig) -> tuple[LayerSpec, ...]:
    return (LayerSpec("attn_bidir", "dense"),)


def n_superblocks(cfg: ArchConfig) -> int:
    pat = block_pattern(cfg)
    assert cfg.n_layers % len(pat) == 0, (cfg.name, len(pat))
    return cfg.n_layers // len(pat)


def padded_vocab(cfg: ArchConfig) -> int:
    return -(-cfg.vocab // 256) * 256


# ---------------------------------------------------------------------------
# init (params + logical axes, same traversal)
# ---------------------------------------------------------------------------

class _Builder:
    def __init__(self, key, dtype, mps_on: bool, precisions):
        self.key = key
        self.dtype = dtype
        self.mps_on = mps_on
        self.precisions = precisions
        self.counter = 0

    def w(self, shape, logical, scale=None, mps_ok=True, stack=None):
        """A linear weight {'w': arr[, 'gamma': ...]} with logical axes."""
        self.counter += 1
        k = jax.random.fold_in(self.key, self.counter)
        fan_in = shape[0] if len(shape) == 2 else shape[-2]
        scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
        full = (stack,) + shape if stack else shape
        llog = (("layers",) + tuple(logical)) if stack else tuple(logical)
        arr = jax.random.normal(k, full, self.dtype) * scale
        out = {"w": arr}
        log = {"w": llog}
        if self.mps_on and mps_ok:
            c_out = shape[-1]
            g = sampling.init_selection_logits(self.precisions, (c_out,))
            if stack:
                g = jnp.broadcast_to(g, (stack,) + g.shape).copy()
            out["gamma"] = g.astype(jnp.float32)
            log["gamma"] = (("layers",) if stack else ()) + (None, None)
        return out, log

    def vec(self, shape, logical, init=0.0, stack=None):
        full = (stack,) + shape if stack else shape
        llog = (("layers",) + tuple(logical)) if stack else tuple(logical)
        return jnp.full(full, init, self.dtype), llog


def _attn_params(b: _Builder, cfg: ArchConfig, nsb: int):
    h, hkv, hd, d = cfg.h_eff, cfg.hkv_eff, cfg.head_dim, cfg.d_model
    p, l = {}, {}
    p["wq"], l["wq"] = b.w((d, h * hd), ("w_embed", "heads_flat"), stack=nsb)
    p["wk"], l["wk"] = b.w((d, hkv * hd), ("w_embed", "kv_flat"), stack=nsb)
    p["wv"], l["wv"] = b.w((d, hkv * hd), ("w_embed", "kv_flat"), stack=nsb)
    p["wo"], l["wo"] = b.w((h * hd, d), ("heads_flat", "w_embed"), stack=nsb)
    if cfg.qk_norm:
        p["q_norm"], l["q_norm"] = b.vec((hd,), (None,), 0.0, stack=nsb)
        p["k_norm"], l["k_norm"] = b.vec((hd,), (None,), 0.0, stack=nsb)
    return p, l


def _ffn_params(b: _Builder, cfg: ArchConfig, nsb: int, d_ff: int):
    d = cfg.d_model
    p, l = {}, {}
    p["w_gate"], l["w_gate"] = b.w((d, d_ff), ("w_embed", "mlp"), stack=nsb)
    p["w_up"], l["w_up"] = b.w((d, d_ff), ("w_embed", "mlp"), stack=nsb)
    p["w_down"], l["w_down"] = b.w((d_ff, d), ("mlp", "w_embed"), stack=nsb)
    return p, l


def _moe_params(b: _Builder, cfg: ArchConfig, nsb: int):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    p, l = {}, {}
    rp, rl = b.w((d, e), (None, None), mps_ok=False, stack=nsb)
    p["router"], l["router"] = rp, rl
    p["w_gate"], l["w_gate"] = b.w((e, d, f),
                                   ("experts", "w_embed", None), stack=nsb)
    p["w_up"], l["w_up"] = b.w((e, d, f),
                               ("experts", "w_embed", None), stack=nsb)
    p["w_down"], l["w_down"] = b.w((e, f, d),
                                   ("experts", None, "w_embed"), stack=nsb)
    if cfg.dense_residual:
        sp, sl = _ffn_params(b, cfg, nsb, cfg.d_ff)
        p["shared"], l["shared"] = sp, sl
    return p, l


def _mamba_params(b: _Builder, cfg: ArchConfig, nsb: int):
    d, di, n, h, kk = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                       cfg.ssm_heads, cfg.ssm_conv)
    p, l = {}, {}
    p["in_z"], l["in_z"] = b.w((d, di), ("w_embed", "ssm_inner"), stack=nsb)
    p["in_x"], l["in_x"] = b.w((d, di), ("w_embed", "ssm_inner"), stack=nsb)
    p["in_b"], l["in_b"] = b.w((d, n), ("w_embed", None), stack=nsb)
    p["in_c"], l["in_c"] = b.w((d, n), ("w_embed", None), stack=nsb)
    p["in_dt"], l["in_dt"] = b.w((d, h), ("w_embed", None), stack=nsb)
    p["out_proj"], l["out_proj"] = b.w((di, d), ("ssm_inner", "w_embed"),
                                       stack=nsb)
    p["conv_x"], l["conv_x"] = b.vec((kk, di), (None, "ssm_inner"), 0.1,
                                     stack=nsb)
    p["conv_b"], l["conv_b"] = b.vec((kk, n), (None, None), 0.1, stack=nsb)
    p["conv_c"], l["conv_c"] = b.vec((kk, n), (None, None), 0.1, stack=nsb)
    p["dt_bias"], l["dt_bias"] = b.vec((h,), (None,), 0.0, stack=nsb)
    p["a_log"], l["a_log"] = b.vec((h,), (None,), 0.0, stack=nsb)
    p["d_skip"], l["d_skip"] = b.vec((h,), (None,), 1.0, stack=nsb)
    p["ssm_norm"], l["ssm_norm"] = b.vec((di,), ("ssm_inner",), 0.0,
                                         stack=nsb)
    return p, l


def _layer_params(b: _Builder, cfg: ArchConfig, spec: LayerSpec, nsb: int):
    d = cfg.d_model
    p, l = {}, {}
    p["norm1"], l["norm1"] = b.vec((d,), (None,), 0.0, stack=nsb)
    if spec.mixer == "mamba":
        p["mixer"], l["mixer"] = _mamba_params(b, cfg, nsb)
    else:
        p["mixer"], l["mixer"] = _attn_params(b, cfg, nsb)
    if spec.cross:
        p["norm_cross"], l["norm_cross"] = b.vec((d,), (None,), 0.0,
                                                 stack=nsb)
        p["cross"], l["cross"] = _attn_params(b, cfg, nsb)
    if spec.ffn is not None:
        p["norm2"], l["norm2"] = b.vec((d,), (None,), 0.0, stack=nsb)
        if spec.ffn == "moe":
            p["ffn"], l["ffn"] = _moe_params(b, cfg, nsb)
        else:
            p["ffn"], l["ffn"] = _ffn_params(b, cfg, nsb, cfg.d_ff)
    return p, l


def _build(cfg: ArchConfig, key, mps_on: bool):
    dtype = jnp.float32 if cfg.param_dtype == "float32" else jnp.bfloat16
    b = _Builder(key, dtype, mps_on, cfg.mps_precisions)
    nsb = n_superblocks(cfg)
    v = padded_vocab(cfg)
    d = cfg.d_model
    params, logical = {}, {}
    params["embed"], logical["embed"] = b.w(
        (v, d), ("vocab", "w_embed"), scale=0.02, mps_ok=False)
    pat = block_pattern(cfg)
    bp, bl = {}, {}
    for i, spec in enumerate(pat):
        bp[f"l{i}"], bl[f"l{i}"] = _layer_params(b, cfg, spec, nsb)
    params["blocks"], logical["blocks"] = bp, bl
    params["final_norm"], logical["final_norm"] = b.vec((d,), (None,), 0.0)
    params["lm_head"], logical["lm_head"] = b.w(
        (d, v), ("w_embed", "vocab"), scale=0.02, mps_ok=False)
    if cfg.is_encdec:
        ep, el = {}, {}
        epat = enc_pattern(cfg)
        n_enc_sb = cfg.enc_layers // len(epat)
        for i, spec in enumerate(epat):
            ep[f"l{i}"], el[f"l{i}"] = _layer_params(b, cfg, spec, n_enc_sb)
        params["enc_blocks"], logical["enc_blocks"] = ep, el
        params["enc_norm"], logical["enc_norm"] = b.vec((d,), (None,), 0.0)
    return params, logical


def init_params(cfg: ArchConfig, key, mps_on: bool = False):
    return _build(cfg, key, mps_on)[0]


def logical_axes(cfg: ArchConfig, mps_on: bool = False):
    captured = {}

    def f(k):
        p, l = _build(cfg, k, mps_on)
        captured["l"] = l
        return p

    jax.eval_shape(f, jax.random.key(0))
    return captured["l"]


def abstract_params(cfg: ArchConfig, mps_on: bool = False):
    return jax.eval_shape(lambda k: _build(cfg, k, mps_on)[0],
                          jax.random.key(0))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _make_effective_w(ctx: Optional[mps.SearchCtx], precisions):
    """Weight-fetch hook. Always casts to the bf16 compute dtype AT THE
    POINT OF USE: the cast output inherits the (FSDP-sharded) layout, so
    the per-layer all-gather moves bf16 instead of the f32 master -- this
    halves the dominant weight-gather collective bytes and the gathered-
    weight memory for f32-master architectures (Perf iteration 4).

    Plan-quantized serving rides the same hook: when the parameter tree
    was bound to a CompressionPlan (``serve.engine.apply_plan``), ``w`` is
    a :class:`~repro.nn.quantized.PackedLinear` and the provider hands it
    through untouched -- ``blocks.linear`` then serves the bit-packed
    per-precision groups through ``mixed_precision_matmul``."""
    if ctx is None:
        def getw(pp):
            w = pp["w"]
            if isinstance(w, nnq.PackedLinear):
                return w
            return w.astype(jnp.bfloat16)
        return getw

    def getw(pp):
        w = pp["w"]
        if "gamma" not in pp:
            return w.astype(jnp.bfloat16)
        return mps.effective_weight(
            w.astype(jnp.float32), pp["gamma"], precisions, ctx,
            channel_axis=w.ndim - 1).astype(jnp.bfloat16)
    return getw


def _layer_apply(cfg, spec: LayerSpec, p, x, *, mode, cache, pos,
                 enc_out, getw, tables=None):
    if getw is None:
        getw = _make_effective_w(None, cfg.mps_precisions)
    mixer_kind = {"attn": "full", "attn_local": "local",
                  "attn_chunked": "chunked", "attn_bidir": "bidir"}
    new_cache = {}
    h = blocks.rmsnorm(x, p["norm1"], cfg.norm_eps)
    if spec.mixer == "mamba":
        amode = mode if mode != "train" else "train"
        y, st = blocks.mamba2_layer(
            p["mixer"], h, cfg, mode=amode,
            state=None if cache is None else cache.get("mamba"),
            effective_w=getw)
        if st is not None:
            new_cache["mamba"] = st
    else:
        y, kv = blocks.attention_layer(
            p["mixer"], h, cfg, kind=mixer_kind[spec.mixer],
            mode=("train" if mode == "train" else mode),
            cache=None if cache is None else cache.get("kv"),
            pos=pos, effective_w=getw, tables=tables)
        if kv is not None:
            new_cache["kv"] = kv
    x = x + y
    if spec.cross and enc_out is not None:
        hc = blocks.rmsnorm(x, p["norm_cross"], cfg.norm_eps)
        yc, ckv = blocks.attention_layer(
            p["cross"], hc, cfg, kind="cross",
            mode=("train" if mode == "train" else mode),
            cache=None if cache is None else cache.get("cross_kv"),
            pos=pos, kv_input=enc_out)
        if ckv is not None:
            new_cache["cross_kv"] = ckv
        x = x + yc
    if spec.ffn is not None:
        h2 = blocks.rmsnorm(x, p["norm2"], cfg.norm_eps)
        if spec.ffn == "moe":
            y2 = blocks.moe_layer(p["ffn"], h2, cfg, effective_w=getw)
        else:
            y2 = blocks.ffn_swiglu(p["ffn"], h2, effective_w=getw)
        x = x + y2
    return x, (new_cache or None)


def _run_stack(cfg, pattern, stack_params, x, *, mode, caches, pos,
               enc_out, getw, remat: bool, blk_logical=None, tables=None):
    """scan over super-blocks. caches: pytree stacked on axis 0 or None.

    tables: paged-decode block tables (B, P), shared by every layer (one
    physical page id backs a token position across ALL layers, so the
    table is scan-invariant and closed over, not scanned).

    blk_logical: logical-axis tree matching one *sliced* block (leading
    'layers' axis stripped). Constraining the sliced weights inside the
    body keeps them FSDP-sharded after the scan's dynamic-slice, so the
    per-layer all-gather stays INSIDE the loop -- without this, GSPMD
    hoists the resharding of the whole stacked parameter out of the loop
    and materializes every layer's gathered weights at once (165 GiB/dev
    for jamba-398B; see EXPERIMENTS.md Sec-Perf iteration 0).
    """
    _is_axes = lambda v: isinstance(v, tuple)  # noqa: E731

    def block_fn(carry, xs):
        xv = carry
        in_dtype = xv.dtype
        blk_params, blk_cache = xs
        if blk_logical is not None and sharding.get_mesh() is not None:
            blk_params = jax.tree.map(
                lambda p, l: sharding.constrain(p, *l),
                blk_params, blk_logical)
        xv = sharding.constrain(xv, "batch", "act_seq", "embed")
        new_caches = {}
        for i, spec in enumerate(pattern):
            cache_i = None if blk_cache is None else blk_cache.get(f"l{i}")
            xv, nc = _layer_apply(cfg, spec, blk_params[f"l{i}"], xv,
                                  mode=mode, cache=cache_i, pos=pos,
                                  enc_out=enc_out, getw=getw,
                                  tables=tables)
            if nc is not None:
                new_caches[f"l{i}"] = nc
        return xv.astype(in_dtype), (new_caches or None)

    fn = block_fn
    if remat:
        fn = jax.checkpoint(block_fn,
                            policy=jax.checkpoint_policies.nothing_saveable)
    x, new_caches = jax.lax.scan(fn, x, (stack_params, caches))
    return x, new_caches


def _run_stack_unrolled(cfg, pattern, per_sb_params, x, *, mode, caches,
                        pos, enc_out, getw, tables=None):
    """Python-unrolled counterpart of :func:`_run_stack` for parameter
    trees whose super-blocks are a tuple of per-block trees instead of one
    stacked pytree.  Plan-quantized serving needs this: each block's
    :class:`~repro.nn.quantized.PackedLinear` buffers have layer-dependent
    shapes (different per-precision channel counts), so they cannot be
    stacked for a ``lax.scan``.  Caches keep the stacked ``(nsb, ...)``
    layout of :func:`init_caches`."""
    per_sb_caches = []
    for j, blk_params in enumerate(per_sb_params):
        blk_cache = None if caches is None else \
            jax.tree.map(lambda a: a[j], caches)
        new_caches = {}
        for i, spec in enumerate(pattern):
            cache_i = None if blk_cache is None else blk_cache.get(f"l{i}")
            x, nc = _layer_apply(cfg, spec, blk_params[f"l{i}"], x,
                                 mode=mode, cache=cache_i, pos=pos,
                                 enc_out=enc_out, getw=getw,
                                 tables=tables)
            if nc is not None:
                new_caches[f"l{i}"] = nc
        per_sb_caches.append(new_caches or None)
    if any(c is not None for c in per_sb_caches):
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0),
                               *per_sb_caches)
    else:
        stacked = None
    return x, stacked


def _has_gamma(tree) -> bool:
    if isinstance(tree, dict):
        return "gamma" in tree or any(_has_gamma(v) for v in tree.values())
    return False


def _sliced_block_logical(cfg, mps_on: bool, key: str = "blocks"):
    """Logical axes of one scan-sliced super-block (leading 'layers'
    stripped from every leaf)."""
    log = logical_axes(cfg, mps_on=mps_on)[key]
    return jax.tree.map(
        lambda l: tuple(l[1:]) if l and l[0] == "layers" else tuple(l),
        log, is_leaf=lambda v: isinstance(v, tuple))


def _embed_in(cfg, params, batch):
    if "embeddings" in batch:                  # vlm/audio frontend stub
        x = batch["embeddings"]
    else:
        table = params["embed"]["w"].astype(jnp.bfloat16)
        x = jnp.take(table, batch["tokens"], axis=0)
        x = x * jnp.asarray(math.sqrt(cfg.d_model), jnp.bfloat16)
    return x.astype(jnp.bfloat16)


def _encode(cfg, params, batch, getw=None):
    if "enc_embeddings" in batch:
        xe = batch["enc_embeddings"].astype(jnp.bfloat16)
    else:
        xe = _embed_in(cfg, params, batch)
    xe, _ = _run_stack(cfg, enc_pattern(cfg), params["enc_blocks"], xe,
                       mode="train", caches=None, pos=None, enc_out=None,
                       getw=getw, remat=cfg.remat,
                       blk_logical=_sliced_block_logical(
                           cfg, _has_gamma(params["enc_blocks"]),
                           "enc_blocks"))
    return blocks.rmsnorm(xe, params["enc_norm"], cfg.norm_eps)


def forward(cfg: ArchConfig, params, batch, *, mode: str = "train",
            caches=None, pos=None, ctx: Optional[mps.SearchCtx] = None,
            logits_mode: str = "full", last_pos=None, tables=None):
    """Returns (logits | hidden, new_caches).

    batch keys: tokens (B, S) int32 | embeddings (B, S, D) for stub
    frontends; + enc_embeddings/enc_tokens for enc-dec.
    mode: train | prefill | decode.
    logits_mode: "full" | "last" (final position only -- serving prefill
    never materializes (B, S, V)) | "hidden" (return the final hidden
    states; the caller computes logits, e.g. the chunked loss below).
    last_pos: with logits_mode="last", an () int32 position to read
    instead of S-1 -- paged prefill pads the prompt to a q-chunk
    boundary and reads the logits of the last REAL token (causal
    attention makes every position <= last_pos independent of the
    padding).
    tables: paged serving -- (B, P) int32 block tables; `caches` KV
    leaves are then page pools (see ``init_paged_caches``) and the
    attention layers run the paged-attention kernels in place.  For
    mode="prefill" pass `pos` as the (B,) real prompt lengths; the
    prompt K/V is scattered straight into the slot's pages and
    attention reads the pool (no dense round-trip).
    """
    getw = _make_effective_w(ctx, cfg.mps_precisions)
    enc_out = None
    if cfg.is_encdec:
        enc_out = _encode(cfg, params, batch, getw)
    x = _embed_in(cfg, params, batch)
    remat = cfg.remat and mode == "train"
    if isinstance(params["blocks"], (list, tuple)):
        # plan-quantized serving tree (serve.engine.apply_plan): one tree
        # per super-block, PackedLinear weights, Python-unrolled
        x, new_caches = _run_stack_unrolled(
            cfg, block_pattern(cfg), params["blocks"], x, mode=mode,
            caches=caches, pos=pos, enc_out=enc_out, getw=getw,
            tables=tables)
    else:
        x, new_caches = _run_stack(
            cfg, block_pattern(cfg), params["blocks"], x, mode=mode,
            caches=caches, pos=pos, enc_out=enc_out, getw=getw,
            remat=remat,
            blk_logical=_sliced_block_logical(
                cfg, _has_gamma(params["blocks"])),
            tables=tables)
    x = blocks.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if logits_mode == "hidden":
        return x, new_caches
    if logits_mode == "last":
        if last_pos is None:
            x = x[:, -1:, :]
        else:
            x = jax.lax.dynamic_slice_in_dim(x, jnp.asarray(last_pos), 1,
                                             axis=1)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]["w"].astype(
        jnp.bfloat16))
    logits = sharding.constrain(logits, "batch", None, "vocab")
    if cfg.final_softcap > 0:
        logits = blocks.softcap(logits, cfg.final_softcap)
    return logits, new_caches


LOSS_SEQ_CHUNKS = 8


def loss_fn(cfg: ArchConfig, params, batch,
            ctx: Optional[mps.SearchCtx] = None,
            lam: float = 0.0):
    """Mean next-token cross-entropy (+ lambda * MPS size cost in search
    mode). Targets use the unpadded vocab range.

    The CE is computed over sequence chunks under jax.checkpoint so the
    f32 (B, S, V) logits are never materialized -- only (B, S/8, V/TP) is
    live at once, recomputed in the backward pass (Perf iteration 3:
    dropped peak temp memory ~40% on qwen3-32b train_4k).
    """
    hidden, _ = forward(cfg, params, batch, mode="train", ctx=ctx,
                        logits_mode="hidden")
    targets = batch["targets"]
    head = params["lm_head"]["w"].astype(jnp.bfloat16)

    @jax.checkpoint
    def chunk_nll(x_c, tgt_c):
        logits = jnp.einsum("bsd,dv->bsv", x_c, head)
        logits = sharding.constrain(logits, "batch", None, "vocab")
        if cfg.final_softcap > 0:
            logits = blocks.softcap(logits, cfg.final_softcap)
        logits = logits.astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, tgt_c[..., None], axis=-1)[..., 0]
        return jnp.sum(logz - tgt)

    b, s, _ = hidden.shape
    nc = LOSS_SEQ_CHUNKS if s % LOSS_SEQ_CHUNKS == 0 else 1
    total = jnp.asarray(0.0, jnp.float32)
    for i in range(nc):
        sl = slice(i * (s // nc), (i + 1) * (s // nc))
        total = total + chunk_nll(hidden[:, sl], targets[:, sl])
    task = total / float(b * s)
    if ctx is not None and lam > 0.0:
        task = task + lam * mps_size_cost(cfg, params, ctx)
    return task


# ---------------------------------------------------------------------------
# the paper's cost model over the LM parameter tree
# ---------------------------------------------------------------------------


def mps_size_cost(cfg: ArchConfig, params, ctx: mps.SearchCtx) -> jax.Array:
    """Differentiable expected size (bytes) over all gamma-carrying weights
    (paper Eq. 9 with C_in fixed -- transformer residual streams keep
    d_model; pruning benefits show through the 0-bit channel count)."""
    precisions = cfg.mps_precisions
    total = jnp.asarray(0.0, jnp.float32)

    def visit(node):
        nonlocal total
        if isinstance(node, dict):
            if "w" in node and "gamma" in node:
                w, gm = node["w"], node["gamma"]
                cin = int(np.prod(w.shape[:-1]))
                if gm.ndim == 3:       # stacked over layers
                    cin = cin // gm.shape[0]
                    eb = jax.vmap(
                        lambda g: mps.expected_bits(g, precisions, ctx)
                    )(gm)
                else:
                    eb = mps.expected_bits(gm, precisions, ctx)
                total = total + jnp.sum(eb) * cin / 8.0
            else:
                for v in node.values():
                    visit(v)

    visit(params)
    return total


def mps_param_count(cfg: ArchConfig) -> int:
    """Number of gamma-carrying weight matrices (for reporting)."""
    tree = abstract_params(cfg, mps_on=True)
    n = 0

    def visit(node):
        nonlocal n
        if isinstance(node, dict):
            if "gamma" in node:
                n += 1
            for k, v in node.items():
                if isinstance(v, dict):
                    visit(v)

    visit(tree)
    return n


# ---------------------------------------------------------------------------
# serving entry points
# ---------------------------------------------------------------------------


def init_caches(cfg: ArchConfig, batch: int, seq_len: int,
                enc_len: int = 0, abstract: bool = False):
    """KV / SSM caches stacked (n_superblocks, ...) per pattern slot."""
    nsb = n_superblocks(cfg)
    hkv, hd = cfg.hkv_eff, cfg.head_dim

    def mk(shape, dtype=jnp.bfloat16):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    caches = {}
    for i, spec in enumerate(block_pattern(cfg)):
        c = {}
        if spec.mixer == "mamba":
            c["mamba"] = {
                "ssm": mk((nsb, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                           cfg.ssm_state), jnp.float32),
                "conv": {
                    "x": mk((nsb, batch, cfg.ssm_conv - 1, cfg.d_inner)),
                    "b": mk((nsb, batch, cfg.ssm_conv - 1, cfg.ssm_state)),
                    "c": mk((nsb, batch, cfg.ssm_conv - 1, cfg.ssm_state)),
                }}
        else:
            c["kv"] = {"k": mk((nsb, batch, seq_len, hkv, hd)),
                       "v": mk((nsb, batch, seq_len, hkv, hd))}
        if spec.cross:
            c["cross_kv"] = {"k": mk((nsb, batch, enc_len, hkv, hd)),
                             "v": mk((nsb, batch, enc_len, hkv, hd))}
        caches[f"l{i}"] = c
    return caches


def cache_logical_axes(cfg: ArchConfig):
    """Logical axes matching init_caches structure."""
    caches = {}
    for i, spec in enumerate(block_pattern(cfg)):
        c = {}
        if spec.mixer == "mamba":
            c["mamba"] = {
                "ssm": ("layers", "batch", "ssm_inner", None, None),
                "conv": {"x": ("layers", "batch", None, "ssm_inner"),
                         "b": ("layers", "batch", None, None),
                         "c": ("layers", "batch", None, None)}}
        else:
            c["kv"] = {"k": ("layers", "batch", "kv_seq", None, None),
                       "v": ("layers", "batch", "kv_seq", None, None)}
        if spec.cross:
            c["cross_kv"] = {
                "k": ("layers", "batch", None, None, None),
                "v": ("layers", "batch", None, None, None)}
        caches[f"l{i}"] = c
    return caches


def init_paged_caches(cfg: ArchConfig, batch: int, page_size: int,
                      n_pages: int, abstract: bool = False):
    """Paged counterpart of :func:`init_caches` (no cross-attention:
    serving is decoder-only).

    KV tensors become fixed page pools ``(nsb, n_pages + 1, page_size,
    hkv, hd)`` indexed by physical page id -- page 0 is the reserved null
    page that inactive block-table entries point at (written garbage is
    always masked).  SSM state is O(1) per request, so it keeps the dense
    per-slot layout ``(nsb, batch, ...)``.  The per-request block tables
    are NOT part of this tree; the cache backend composes them in at
    gather time (they are host-side bookkeeping that changes on admission
    / page allocation, not per decode step).
    """
    if cfg.is_encdec:
        raise NotImplementedError("paged caches are decoder-only")
    nsb = n_superblocks(cfg)
    hkv, hd = cfg.hkv_eff, cfg.head_dim

    def mk(shape, dtype=jnp.bfloat16):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    caches = {}
    for i, spec in enumerate(block_pattern(cfg)):
        c = {}
        if spec.mixer == "mamba":
            c["mamba"] = {
                "ssm": mk((nsb, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                           cfg.ssm_state), jnp.float32),
                "conv": {
                    "x": mk((nsb, batch, cfg.ssm_conv - 1, cfg.d_inner)),
                    "b": mk((nsb, batch, cfg.ssm_conv - 1, cfg.ssm_state)),
                    "c": mk((nsb, batch, cfg.ssm_conv - 1, cfg.ssm_state)),
                }}
        else:
            c["kv"] = {"k": mk((nsb, n_pages + 1, page_size, hkv, hd)),
                       "v": mk((nsb, n_pages + 1, page_size, hkv, hd))}
        caches[f"l{i}"] = c
    return caches


def _tree_bytes(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(l.size * jnp.dtype(l.dtype).itemsize for l in leaves))


def kv_bytes_per_token(cfg: ArchConfig) -> int:
    """Bytes of KV cache one token position pins across all attention
    layers (0 for pure-SSM architectures)."""
    tree = init_caches(cfg, 1, 1, abstract=True)
    return _tree_bytes({l: {"kv": c["kv"]} for l, c in tree.items()
                        if "kv" in c})


def ssm_bytes_per_slot(cfg: ArchConfig) -> int:
    """Bytes of recurrent (SSM + conv) state one decode slot pins (0 for
    attention-only architectures)."""
    tree = init_caches(cfg, 1, 1, abstract=True)
    return _tree_bytes({l: {"mamba": c["mamba"]} for l, c in tree.items()
                        if "mamba" in c})


def dense_cache_bytes(cfg: ArchConfig, batch: int, seq_len: int) -> int:
    """Total bytes :func:`init_caches` pins for a dense decode pool."""
    return _tree_bytes(init_caches(cfg, batch, seq_len, abstract=True))


def prefill(cfg: ArchConfig, params, batch):
    """Full-sequence forward producing logits + caches."""
    logits, caches = forward(cfg, params, batch, mode="prefill")
    return logits, caches


def decode_step(cfg: ArchConfig, params, token_batch, caches, pos,
                tables=None):
    """One-token decode. token_batch: {"tokens": (B, 1)} (or embeddings);
    pos: () int32 shared position, or (B,) int32 per-sequence positions
    (continuous batching: every slot decodes at its own offset).
    tables: (B, P) int32 block tables when `caches` holds page pools
    (paged serving); None for dense caches.
    Returns (logits (B, 1, V), caches)."""
    logits, new_caches = forward(cfg, params, token_batch, mode="decode",
                                 caches=caches, pos=pos, tables=tables)
    return logits, new_caches


# ---------------------------------------------------------------------------
# CompressionPlan group naming over the LM parameter tree
# ---------------------------------------------------------------------------
#
# Every 2-D projection that carries per-channel selection parameters in
# search mode is a plan group.  Weights are stacked (n_superblocks, K, N),
# so each (weight, super-block) pair gets its own group, named by the
# dotted parameter path plus the super-block index:
#
#     blocks.l0.mixer.wq.sb3, blocks.l1.ffn.w_down.sb0, ...
#
# MoE expert banks (4-D stacked) and the router stay float at serving
# time; embed / lm_head never carry gammas (mps_ok=False).


def _walk_plan_weights(cfg: ArchConfig, params):
    """Yield ``(dotted_path, template_node, param_node)`` for every
    plan-servable projection (gamma-carrying, 2-D per super-block)."""
    tmpl = abstract_params(cfg, mps_on=True)["blocks"]

    def visit(tnode, pnode, path):
        if not isinstance(tnode, dict):
            return
        if "w" in tnode and "gamma" in tnode and tnode["w"].ndim == 3:
            yield path, tnode, pnode
            return
        for k, tv in tnode.items():
            if isinstance(tv, dict):
                yield from visit(tv, pnode[k], f"{path}.{k}")

    for lname in tmpl:
        yield from visit(tmpl[lname], params["blocks"][lname],
                         f"blocks.{lname}")


def serve_weight_groups(cfg: ArchConfig, params) -> dict:
    """Plan-group name -> ``(C_out, C_in)`` float matrix for every
    quantizable LM projection -- the ``weights`` dict that
    ``serve.engine.export_plan_layers`` / ``CompressionPlan.bind`` take."""
    out = {}
    for path, _, pnode in _walk_plan_weights(cfg, params):
        w = np.asarray(pnode["w"], np.float32)        # (nsb, K, N)
        for j in range(w.shape[0]):
            out[f"{path}.sb{j}"] = w[j].T
    return out


def extract_plan(cfg: ArchConfig, params, px=(8,), meta=None):
    """Discretize an LM's per-channel selection logits into a
    :class:`~repro.api.plan.CompressionPlan` (paper Eq. 7/8 on the LM
    track).  ``params`` must carry gammas (``init_params(mps_on=True)``,
    e.g. after a ``make_train_step(search=True)`` run)."""
    from repro.api.plan import CompressionPlan

    pw = np.asarray(cfg.mps_precisions)
    gamma = {}
    for path, _, pnode in _walk_plan_weights(cfg, params):
        g = np.asarray(pnode["gamma"], np.float32)    # (nsb, C, |P|)
        bits = pw[np.argmax(g, axis=-1)]              # (nsb, C)
        for j in range(bits.shape[0]):
            gamma[f"{path}.sb{j}"] = bits[j]
    assignment = {"gamma": gamma, "delta": {}, "alpha": {}}
    base = {"track": "lm", "arch": cfg.name}
    return CompressionPlan.from_assignment(
        assignment, cfg.mps_precisions, px, meta={**base, **(meta or {})})
