"""Validation tooling for exported observability artifacts.

Dependency-free on purpose: the CI smoke stage runs

    python -m repro.obs.validate --metrics serve_metrics.prom \\
        --trace serve_trace.jsonl --schema tests/obs_schema.json

to prove that (a) the Prometheus text output parses and is internally
consistent (TYPE lines precede samples, histogram buckets are
cumulative and end at ``+Inf == _count``), (b) every JSONL trace event
matches the checked-in schema, and (c) every request's event sequence
is a complete lifecycle per :meth:`RequestTracer.check_lifecycle`.

The schema checker implements the subset of JSON Schema the trace
schema uses (type / enum / required / properties / additionalProperties
/ minimum / items) rather than pulling in a jsonschema dependency.
"""
from __future__ import annotations

import argparse
import json
import re

from .tracing import RequestTracer

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)$")
_LABEL_RE = re.compile(
    r'\s*(?P<k>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<v>(?:[^"\\]|\\.)*)"\s*(?:,|$)')


def parse_prometheus(text: str) -> dict:
    """Parse Prometheus text exposition into
    ``{family: {"type": str, "samples": [(name, labels, value)]}}``.

    Raises ValueError on malformed lines, samples without a preceding
    TYPE, or inconsistent histograms.
    """
    families: dict = {}
    types: dict = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line:
            continue
        if line.startswith("# HELP "):
            continue
        if line.startswith("# TYPE "):
            parts = line.split(None, 3)
            if len(parts) != 4 or parts[3] not in (
                    "counter", "gauge", "histogram", "untyped"):
                raise ValueError(f"line {lineno}: malformed TYPE line")
            types[parts[2]] = parts[3]
            families.setdefault(parts[2],
                                {"type": parts[3], "samples": []})
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {lineno}: malformed sample: {line!r}")
        name = m.group("name")
        fam = name
        for suffix in ("_bucket", "_sum", "_count"):
            if (name.endswith(suffix)
                    and types.get(name[:-len(suffix)]) == "histogram"):
                fam = name[:-len(suffix)]
                break
        if fam not in types:
            raise ValueError(f"line {lineno}: sample {name!r} has no "
                             f"preceding TYPE line")
        labels = {}
        raw = m.group("labels")
        if raw:
            pos = 0
            while pos < len(raw):
                lm = _LABEL_RE.match(raw, pos)
                if lm is None:
                    raise ValueError(f"line {lineno}: malformed labels "
                                     f"{raw!r}")
                labels[lm.group("k")] = (
                    lm.group("v").replace('\\"', '"')
                    .replace("\\n", "\n").replace("\\\\", "\\"))
                pos = lm.end()
        vs = m.group("value")
        value = float("inf") if vs == "+Inf" else float(vs)
        families[fam]["samples"].append((name, labels, value))
    _check_histograms(families)
    return families


def _check_histograms(families: dict):
    for fam, rec in families.items():
        if rec["type"] != "histogram":
            continue
        series: dict = {}
        for name, labels, value in rec["samples"]:
            key = tuple(sorted((k, v) for k, v in labels.items()
                               if k != "le"))
            s = series.setdefault(key, {"buckets": [], "sum": None,
                                        "count": None})
            if name == fam + "_bucket":
                le = labels.get("le")
                if le is None:
                    raise ValueError(f"{fam}: bucket sample missing 'le'")
                s["buckets"].append(
                    (float("inf") if le == "+Inf" else float(le), value))
            elif name == fam + "_sum":
                s["sum"] = value
            elif name == fam + "_count":
                s["count"] = value
        for key, s in series.items():
            if not s["buckets"] or s["count"] is None or s["sum"] is None:
                raise ValueError(f"{fam}{dict(key)}: incomplete "
                                 f"histogram series")
            les = [le for le, _ in s["buckets"]]
            cums = [c for _, c in s["buckets"]]
            if les != sorted(les) or les[-1] != float("inf"):
                raise ValueError(f"{fam}{dict(key)}: buckets not "
                                 f"ascending to +Inf")
            if any(c2 < c1 for c1, c2 in zip(cums, cums[1:])):
                raise ValueError(f"{fam}{dict(key)}: bucket counts "
                                 f"not cumulative")
            if cums[-1] != s["count"]:
                raise ValueError(f"{fam}{dict(key)}: +Inf bucket "
                                 f"{cums[-1]} != count {s['count']}")
    return families


# ------------------------------------------------------------ JSON schema
def check_schema(obj, schema, path: str = "$") -> list:
    """Validate ``obj`` against the JSON-Schema subset used by
    ``tests/obs_schema.json``; returns a list of error strings."""
    errors: list = []
    t = schema.get("type")
    if t is not None:
        ok = {
            "object": lambda o: isinstance(o, dict),
            "array": lambda o: isinstance(o, list),
            "string": lambda o: isinstance(o, str),
            "integer": lambda o: isinstance(o, int)
            and not isinstance(o, bool),
            "number": lambda o: isinstance(o, (int, float))
            and not isinstance(o, bool),
            "boolean": lambda o: isinstance(o, bool),
            "null": lambda o: o is None,
        }[t](obj)
        if not ok:
            return [f"{path}: expected {t}, got "
                    f"{type(obj).__name__}"]
    if "enum" in schema and obj not in schema["enum"]:
        errors.append(f"{path}: {obj!r} not in enum {schema['enum']}")
    if "minimum" in schema and isinstance(obj, (int, float)) \
            and not isinstance(obj, bool) and obj < schema["minimum"]:
        errors.append(f"{path}: {obj} < minimum {schema['minimum']}")
    if isinstance(obj, dict):
        for req in schema.get("required", ()):
            if req not in obj:
                errors.append(f"{path}: missing required key {req!r}")
        props = schema.get("properties", {})
        for k, v in obj.items():
            if k in props:
                errors.extend(check_schema(v, props[k], f"{path}.{k}"))
            elif schema.get("additionalProperties", True) is False:
                errors.append(f"{path}: unexpected key {k!r}")
    if isinstance(obj, list) and "items" in schema:
        for i, v in enumerate(obj):
            errors.extend(check_schema(v, schema["items"],
                                       f"{path}[{i}]"))
    return errors


def validate_trace_lines(lines, schema) -> list:
    """Schema-check each JSONL event and lifecycle-check each request;
    returns a list of error strings (empty == valid)."""
    errors: list = []
    lifecycles: dict = {}
    order: list = []
    last_t = None
    for lineno, line in enumerate(lines, 1):
        line = line.strip()
        if not line:
            continue
        try:
            ev = json.loads(line)
        except json.JSONDecodeError as e:
            errors.append(f"line {lineno}: not JSON ({e})")
            continue
        errs = check_schema(ev, schema, path=f"line {lineno}")
        errors.extend(errs)
        if errs:
            continue
        if last_t is not None and ev["t"] < last_t:
            errors.append(f"line {lineno}: timestamp {ev['t']} goes "
                          f"backwards (prev {last_t})")
        last_t = ev["t"]
        uid = ev["uid"]
        if uid not in lifecycles:
            order.append(uid)
        lifecycles.setdefault(uid, []).append(ev["kind"])
    for uid in order:
        err = RequestTracer.check_lifecycle(lifecycles[uid])
        if err is not None:
            errors.append(f"uid {uid}: invalid lifecycle "
                          f"{lifecycles[uid]}: {err}")
    return errors


def validate_files(metrics_path=None, trace_path=None,
                   schema_path=None) -> list:
    """Validate exported artifact files; returns error strings."""
    errors: list = []
    if metrics_path:
        with open(metrics_path) as f:
            text = f.read()
        try:
            fams = parse_prometheus(text)
            if not fams:
                errors.append(f"{metrics_path}: no metric families")
        except ValueError as e:
            errors.append(f"{metrics_path}: {e}")
    if trace_path:
        if not schema_path:
            errors.append("--trace requires --schema")
        else:
            with open(schema_path) as f:
                schema = json.load(f)
            with open(trace_path) as f:
                lines = f.readlines()
            if not any(line.strip() for line in lines):
                errors.append(f"{trace_path}: no trace events")
            errors.extend(f"{trace_path}: {e}"
                          for e in validate_trace_lines(lines, schema))
    return errors


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.validate",
        description="Validate exported metrics/trace artifacts")
    p.add_argument("--metrics", help="Prometheus text file")
    p.add_argument("--trace", help="JSONL trace file")
    p.add_argument("--schema", help="JSON schema for trace events")
    args = p.parse_args(argv)
    if not args.metrics and not args.trace:
        p.error("nothing to validate: pass --metrics and/or --trace")
    errors = validate_files(args.metrics, args.trace, args.schema)
    for e in errors:
        print(f"INVALID: {e}")
    if not errors:
        targets = [x for x in (args.metrics, args.trace) if x]
        print(f"OK: {', '.join(targets)} valid")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
