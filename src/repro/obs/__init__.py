"""repro.obs: serving + search observability.

One telemetry contract across every layer: the serving engine,
scheduler, cache backends and sampling path, and the compression
phases all write into a shared :class:`MetricsRegistry`; the serving
engine additionally records per-request lifecycle events through a
:class:`RequestTracer`.  :class:`Observability` bundles the two.

Everything is host-side and dependency-free; with the registry
disabled each instrumentation site costs a no-op method call, and no
site lives inside jitted code.  See ``src/repro/obs/README.md`` for
the metric catalog and exporter formats.
"""
from .registry import (LATENCY_BUCKETS_S, Counter, Gauge, Histogram,
                       MetricsRegistry)
from .tracing import (EVENT_KINDS, FAULT_TERMINAL_KINDS, SWEEP_KINDS,
                      TERMINAL_KINDS, RequestTracer, TraceEvent)
from .exporters import (percentiles, run_summary, to_prometheus,
                        trace_to_jsonl, write_prometheus, write_trace)


class Observability:
    """Bundle of a metrics registry and a request tracer.

    ``Observability()`` enables both; ``metrics=False`` leaves a
    disabled registry (no-op metrics), ``trace=False`` drops the tracer
    (``obs.tracer is None``).  Pass an instance to
    ``InferenceServer(..., obs=...)`` or ``server.attach_obs(obs)``.
    """

    def __init__(self, metrics: bool = True, trace: bool = True,
                 registry=None, replica=None):
        # pass registry= to share one metric namespace across several
        # servers (the fleet does this: one registry, one tracer per
        # replica tagged via replica=)
        self.registry = (registry if registry is not None
                         else MetricsRegistry(enabled=metrics))
        self.tracer = (RequestTracer(self.registry, replica=replica)
                       if trace else None)

    def summary(self) -> dict:
        """End-of-run summary (empty when tracing is off)."""
        if self.tracer is None:
            return {}
        return run_summary(self.tracer, self.registry)


__all__ = [
    "LATENCY_BUCKETS_S", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "EVENT_KINDS", "FAULT_TERMINAL_KINDS",
    "SWEEP_KINDS", "TERMINAL_KINDS", "RequestTracer", "TraceEvent",
    "Observability", "percentiles", "run_summary", "to_prometheus",
    "trace_to_jsonl", "write_prometheus", "write_trace",
]
