"""Exporters: Prometheus text format, JSONL trace, end-of-run summary.

All three read from the registry/tracer objects in ``repro.obs`` and
write plain text -- no external dependencies, so they run anywhere the
repo runs (including the CI smoke stage, which round-trips the output
through ``repro.obs.validate``).
"""
from __future__ import annotations

import json

import numpy as np


def _escape_label(value: str) -> str:
    return (str(value).replace("\\", "\\\\").replace("\n", "\\n")
            .replace('"', '\\"'))


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape_label(v)}"'
                     for k, v in labels.items())
    return "{" + inner + "}"


def _fmt_value(v) -> str:
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def to_prometheus(registry) -> str:
    """Render a registry in the Prometheus text exposition format."""
    lines = []
    for name, fam in registry.snapshot().items():
        if fam["help"]:
            lines.append(f"# HELP {name} {fam['help']}")
        lines.append(f"# TYPE {name} {fam['kind']}")
        for s in fam["series"]:
            if fam["kind"] == "histogram":
                for le, cum in s["buckets"]:
                    lbl = dict(s["labels"])
                    lbl["le"] = (le if le == "+Inf"
                                 else _fmt_value(le))
                    lines.append(f"{name}_bucket{_fmt_labels(lbl)} "
                                 f"{cum}")
                lines.append(f"{name}_sum{_fmt_labels(s['labels'])} "
                             f"{repr(float(s['sum']))}")
                lines.append(f"{name}_count{_fmt_labels(s['labels'])} "
                             f"{s['count']}")
            else:
                lines.append(f"{name}{_fmt_labels(s['labels'])} "
                             f"{_fmt_value(s['value'])}")
    return "\n".join(lines) + "\n" if lines else ""


def write_prometheus(registry, path: str):
    with open(path, "w") as f:
        f.write(to_prometheus(registry))


def trace_to_jsonl(tracer) -> str:
    """One JSON object per trace event, in recording order."""
    return "".join(json.dumps(ev.to_json(), sort_keys=True) + "\n"
                   for ev in tracer.events)


def write_trace(tracer, path: str):
    with open(path, "w") as f:
        f.write(trace_to_jsonl(tracer))


def percentiles(xs) -> dict:
    """p50/p95/p99 of a sequence (None values when empty)."""
    if xs is None or len(xs) == 0:
        return {"p50": None, "p95": None, "p99": None}
    a = np.asarray(list(xs), dtype=np.float64)
    return {"p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95)),
            "p99": float(np.percentile(a, 99))}


def run_summary(tracer, registry=None) -> dict:
    """End-of-run summary for one traced serve run.

    Latency percentiles come from the tracer (per-run); the decode-path
    breakdown and top-k skip rate come from the registry when given
    (cumulative across runs on the same server).
    """
    out = {
        "requests": len(tracer.uids()),
        "tokens": len(tracer.token_latencies()),
        "preemptions": tracer.preemption_count(),
        "pages_held_hwm": tracer.pages_held_hwm(),
        "ttft_s": percentiles(tracer.ttfts()),
        "token_latency_s": percentiles(tracer.token_latencies()),
        "queue_wait_s": percentiles(tracer.queue_waits()),
    }
    if registry is not None and registry.enabled:
        snap = registry.snapshot()
        steps = snap.get("serve_decode_steps_total")
        if steps is not None:
            width_steps: dict = {}
            widths: dict = {}
            for s in steps["series"]:
                w = s["labels"].get("width", "?")
                width_steps[w] = width_steps.get(w, 0) + int(s["value"])
                # one (path, width) series == one decode callable
                # compiled for that static width
                widths[w] = widths.get(w, 0) + 1
            out["decode_width_steps"] = width_steps
            out["decode_compiles_per_width"] = widths
        skip = snap.get("serve_topk_sort_steps_total")
        if skip is not None:
            by = {s["labels"].get("skipped"): s["value"]
                  for s in skip["series"]}
            total = sum(by.values())
            if total:
                out["topk_sort_skip_rate"] = float(
                    by.get("true", 0.0) / total)
    return out
