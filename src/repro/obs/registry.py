"""Metrics registry: Counter / Gauge / Histogram behind one namespace.

The registry is the single sink every instrumented layer writes into --
the serving engine, the cache backends, the scheduler's tracer and the
compression phases all share one :class:`MetricsRegistry`, so an export
(Prometheus text, JSON snapshot) is one call over one object.

Design constraints (the serving hot loop is the customer):

* **Cheap when disabled.**  ``MetricsRegistry(enabled=False)`` hands out
  a shared no-op metric whose ``inc``/``set``/``observe`` do nothing;
  instrumented code never branches on the registry itself.
* **Host boundaries only.**  Nothing in this module touches jax -- a
  metric update is a dict lookup plus a float add, and instrumentation
  sites live outside jitted code, so enabling metrics never changes a
  trace or forces a device sync.
* **Fixed log-spaced latency buckets.**  :data:`LATENCY_BUCKETS_S` spans
  1 us .. 100 s at four buckets per decade; histograms default to it so
  every latency series is directly comparable.

Naming follows the Prometheus conventions: ``snake_case`` metric names,
``_total`` suffix on counters, ``_seconds`` unit suffixes, label values
always strings (see ``src/repro/obs/README.md`` for the full catalog).
"""
from __future__ import annotations

import bisect

# 1e-6 s .. 1e2 s, four buckets per decade (ratio 10^0.25 ~ 1.78):
# fixed so latency histograms from different runs/layers share edges.
LATENCY_BUCKETS_S = tuple(10.0 ** (e / 4.0) for e in range(-24, 9))


class _NoopMetric:
    """Shared stand-in handed out by a disabled registry."""

    __slots__ = ()

    def inc(self, *args, **labels):
        pass

    def set(self, *args, **labels):
        pass

    def observe(self, *args, **labels):
        pass


_NOOP = _NoopMetric()


class Metric:
    """One named metric family; ``series`` maps label-value tuples (in
    ``label_names`` order) to that series' state."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "", labels=()):
        self.name = name
        self.help = help
        self.label_names = tuple(labels)
        self.series: dict = {}

    def _key(self, labels: dict) -> tuple:
        if set(labels) != set(self.label_names):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.label_names}, "
                f"got {tuple(sorted(labels))}")
        return tuple(str(labels[k]) for k in self.label_names)


class Counter(Metric):
    """Monotonically increasing total."""

    kind = "counter"

    def inc(self, value: float = 1.0, **labels):
        if value < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease "
                             f"(inc by {value})")
        key = self._key(labels)
        self.series[key] = self.series.get(key, 0.0) + float(value)

    def value(self, **labels) -> float:
        return self.series.get(self._key(labels), 0.0)


class Gauge(Metric):
    """Point-in-time value (idempotent ``set``)."""

    kind = "gauge"

    def set(self, value: float, **labels):
        self.series[self._key(labels)] = float(value)

    def inc(self, value: float = 1.0, **labels):
        key = self._key(labels)
        self.series[key] = self.series.get(key, 0.0) + float(value)

    def value(self, **labels) -> float:
        return self.series.get(self._key(labels), 0.0)


class Histogram(Metric):
    """Fixed-bucket histogram (Prometheus ``le`` semantics: a bucket
    counts observations ``<=`` its upper bound)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "", labels=(),
                 buckets=LATENCY_BUCKETS_S):
        super().__init__(name, help, labels)
        buckets = tuple(float(b) for b in buckets)
        if not buckets or any(b2 <= b1 for b1, b2 in zip(buckets,
                                                         buckets[1:])):
            raise ValueError(f"histogram {name!r} buckets must be a "
                             f"non-empty ascending sequence")
        self.buckets = buckets

    def observe(self, value: float, **labels):
        key = self._key(labels)
        h = self.series.get(key)
        if h is None:
            h = self.series[key] = {
                "counts": [0] * (len(self.buckets) + 1),   # +1: +Inf
                "sum": 0.0, "count": 0}
        h["counts"][bisect.bisect_left(self.buckets, float(value))] += 1
        h["sum"] += float(value)
        h["count"] += 1

    def count(self, **labels) -> int:
        h = self.series.get(self._key(labels))
        return 0 if h is None else h["count"]


class MetricsRegistry:
    """Get-or-create namespace of metrics plus a JSON-able snapshot.

    ``enabled=False`` makes every accessor return a shared no-op metric:
    instrumentation stays in place and costs one attribute call.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._metrics: dict[str, Metric] = {}
        # per-(phase, metric) step high-water marks backing the
        # idempotent phase-metric emission contract (see emit_phase_point)
        self._phase_hwm: dict[tuple, int] = {}

    # ------------------------------------------------------------ accessors
    def _get(self, cls, name: str, help: str, labels, **kwargs):
        if not self.enabled:
            return _NOOP
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help, labels, **kwargs)
            self._metrics[name] = m
            return m
        if not isinstance(m, cls):
            raise ValueError(f"metric {name!r} already registered as "
                             f"{m.kind}, requested {cls.kind}")
        if m.label_names != tuple(labels):
            raise ValueError(f"metric {name!r} registered with labels "
                             f"{m.label_names}, requested {tuple(labels)}")
        return m

    def counter(self, name: str, help: str = "", labels=()) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", labels=()) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "", labels=(),
                  buckets=LATENCY_BUCKETS_S) -> Histogram:
        h = self._get(Histogram, name, help, labels, buckets=buckets)
        if h is not _NOOP and h.buckets != tuple(float(b)
                                                 for b in buckets):
            raise ValueError(f"histogram {name!r} registered with "
                             f"different buckets")
        return h

    # ------------------------------------------------- phase-metric points
    def emit_phase_point(self, phase: str, step: int, values: dict):
        """Record one step's worth of compression-phase metrics.

        **Idempotent under checkpoint resume**: each (phase, metric) pair
        keeps a step high-water mark, and a point at a step at or below
        it is dropped.  A resumed run replays the steps between the
        restored checkpoint and the crash point to rebuild bit-exact
        state -- those replayed steps were already emitted by the crashed
        run into this same registry and must not be counted twice.  (Use
        a fresh registry for a genuinely new run of the same recipe.)
        """
        if not self.enabled:
            return
        for metric, value in values.items():
            key = (str(phase), str(metric))
            if int(step) <= self._phase_hwm.get(key, -1):
                continue
            self._phase_hwm[key] = int(step)
            self.gauge("compress_step_value",
                       "Latest value of a compression-phase step metric",
                       labels=("phase", "metric")).set(
                float(value), phase=phase, metric=metric)
            self.counter("compress_step_points_total",
                         "Phase step-metric points emitted (replayed "
                         "steps after a checkpoint resume are not "
                         "re-counted)",
                         labels=("phase", "metric")).inc(
                phase=phase, metric=metric)

    # ------------------------------------------------------------ snapshot
    def snapshot(self) -> dict:
        """JSON-able state of every registered metric.

        ``{name: {kind, help, labels, series: [{labels: {..}, ...}]}}``;
        counter/gauge series carry ``value``, histogram series carry
        ``count`` / ``sum`` / ``buckets`` (cumulative ``[le, count]``
        pairs ending with ``["+Inf", count]``).
        """
        out: dict = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            series = []
            for key in sorted(m.series):
                labels = dict(zip(m.label_names, key))
                if m.kind == "histogram":
                    h = m.series[key]
                    cum, buckets = 0, []
                    for le, c in zip(m.buckets, h["counts"]):
                        cum += c
                        buckets.append([le, cum])
                    buckets.append(["+Inf", cum + h["counts"][-1]])
                    series.append({"labels": labels, "count": h["count"],
                                   "sum": h["sum"], "buckets": buckets})
                else:
                    series.append({"labels": labels,
                                   "value": m.series[key]})
            out[name] = {"kind": m.kind, "help": m.help,
                         "labels": list(m.label_names), "series": series}
        return out
