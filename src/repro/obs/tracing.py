"""Request lifecycle tracer for the serving engine.

Each request moving through :class:`repro.serve.engine.InferenceServer`
leaves a trail of :class:`TraceEvent` records::

    enqueued -> admitted -> prefilled -> first_token -> decode(n)*
             -> (preempted -> admitted -> prefilled -> decode(n)* )*
             -> finished | timeout | cancelled

``timeout`` and ``cancelled`` are the cancellation terminals (the
engine's ``cancel()`` API frees the request's cache pages first); a
timed-out or cancelled uid may be *re-enqueued* -- the fleet layer's
retry path -- which starts a fresh episode of the same grammar.

Timestamps are monotonic (``time.perf_counter``) relative to the start
of the serve run, so event deltas are meaningful even across wall-clock
adjustments.  ``pages_held`` snapshots the cache pages a request holds
at the transition, which makes memory pressure attributable per request.

The tracer doubles as the feed for the latency histograms: when a
registry is attached, ``first_token`` observes ``serve_ttft_seconds``
and every token-bearing event observes ``serve_token_latency_seconds``,
so histogram counts reconcile exactly with the engine's token totals.
It also feeds the queue-side series: ``serve_queue_depth`` (requests
waiting for a slot, per ``replica`` label) and
``serve_queue_wait_seconds`` (enqueued->admitted, re-queues measured
from the preemption).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

EVENT_KINDS = ("enqueued", "admitted", "prefilled", "first_token",
               "decode", "preempted", "finished", "timeout", "cancelled",
               # fault-path lifecycle (repro.chaos / fleet failover):
               # crashed/quarantined strike every request in flight on a
               # replica that died or started emitting NaN logits;
               # recovered marks the failover re-enqueue onto a survivor
               "crashed", "quarantined", "recovered",
               # sweep-point lifecycle (repro.sweep): a search point is
               # enqueued, then either loaded from the plan store or
               # started (warm or cold) and finished into the store
               "point_enqueued", "point_started", "point_loaded",
               "point_finished")
# events that end a residency episode for a uid (a timeout/cancelled/
# crashed/quarantined uid may be re-enqueued by the fleet's retry or
# failover path; finished is final)
TERMINAL_KINDS = ("finished", "timeout", "cancelled", "crashed",
                  "quarantined")
# the fault-struck subset of TERMINAL_KINDS: episodes ended by one of
# these may be followed by a `recovered` marker before the re-enqueue
FAULT_TERMINAL_KINDS = ("crashed", "quarantined")
# the sweep-point subset: a uid uses either the serve grammar or the
# sweep grammar, never a mix
SWEEP_KINDS = ("point_enqueued", "point_started", "point_loaded",
               "point_finished")


@dataclass
class TraceEvent:
    """One lifecycle transition for one request."""

    uid: int
    kind: str
    t: float                       # seconds since tracer start (monotonic)
    n: int | None = None           # tokens: prompt size / generated so far
    pages_held: int | None = None  # cache pages held after the transition
    slot: int | None = None        # batch slot while resident
    extra: dict = field(default_factory=dict)

    def to_json(self) -> dict:
        out = {"uid": self.uid, "kind": self.kind, "t": self.t}
        for k in ("n", "pages_held", "slot"):
            v = getattr(self, k)
            if v is not None:
                out[k] = v
        out.update(self.extra)
        return out


class RequestTracer:
    """Accumulates lifecycle events for one serve run.

    ``start()`` resets the event log and the time origin; the attached
    registry (if any) is *not* reset, so metrics stay cumulative across
    runs while the trace is per-run.
    """

    def __init__(self, registry=None, replica=None):
        self.registry = registry if (registry is not None
                                     and registry.enabled) else None
        # fleet replicas share one registry; the replica tag keys the
        # queue-side series so per-replica depth/wait stay separable
        # (solo servers use the empty tag)
        self.replica = "" if replica is None else str(replica)
        self.events: list[TraceEvent] = []
        self._t0 = time.perf_counter()
        self._enq_t: dict[int, float] = {}
        self._last_token_t: dict[int, float] = {}
        self._queued: dict[int, float] = {}   # uid -> queue-entry time

    def start(self):
        self.events = []
        self._t0 = time.perf_counter()
        self._enq_t = {}
        self._last_token_t = {}
        self._queued = {}

    def rebase(self, t0: float):
        """Move the time origin to ``t0`` (a ``time.perf_counter``
        value).  The fleet rebases every replica tracer to one shared
        origin right after starting them, so the merged multi-replica
        trace is globally ordered by ``t``."""
        self._t0 = t0

    # ------------------------------------------------------------ recording
    def event(self, uid: int, kind: str, *, n=None, pages_held=None,
              slot=None, **extra):
        if kind not in EVENT_KINDS:
            raise ValueError(f"unknown trace event kind {kind!r}")
        t = time.perf_counter() - self._t0
        ev = TraceEvent(int(uid), kind, t,
                        n=None if n is None else int(n),
                        pages_held=(None if pages_held is None
                                    else int(pages_held)),
                        slot=None if slot is None else int(slot),
                        extra=extra)
        self.events.append(ev)

        if kind in SWEEP_KINDS:
            # sweep points carry none of the serve-side queue/latency
            # semantics: record the event and count it, nothing else
            if self.registry is not None:
                self.registry.counter(
                    "sweep_trace_events_total",
                    "Sweep-point lifecycle events recorded",
                    labels=("kind",)).inc(kind=kind)
            return ev

        if kind == "enqueued":
            self._enq_t[ev.uid] = t
            self._last_token_t.pop(ev.uid, None)

        reg = self.registry
        if reg is not None:
            reg.counter("serve_trace_events_total",
                        "Lifecycle trace events recorded",
                        labels=("kind",)).inc(kind=kind)
        # queue-side series: depth counts requests waiting for a decode
        # slot (enqueued or preempted back to the queue); wait is
        # queue-entry -> admitted, so re-queues measure from preemption
        if kind in ("enqueued", "preempted"):
            self._queued[ev.uid] = t
        elif kind == "admitted":
            entered = self._queued.pop(ev.uid, None)
            if reg is not None:
                reg.histogram(
                    "serve_queue_wait_seconds",
                    "Queue wait from enqueue (or re-queue on "
                    "preemption) to admission into a decode slot",
                    labels=("replica",)).observe(
                    t - (t if entered is None else entered),
                    replica=self.replica)
        elif kind in ("timeout", "cancelled", "crashed", "quarantined"):
            self._queued.pop(ev.uid, None)
        if reg is not None and kind in ("enqueued", "admitted",
                                        "preempted", "timeout",
                                        "cancelled", "crashed",
                                        "quarantined"):
            reg.gauge("serve_queue_depth",
                      "Requests waiting for a decode slot",
                      labels=("replica",)).set(len(self._queued),
                                               replica=self.replica)
        if kind in ("first_token", "decode"):
            # Every generated token passes through exactly one of these
            # events, so serve_token_latency_seconds' count equals the
            # engine's generated-token total.  The first token's latency
            # is measured from enqueue, later ones from the previous
            # token (including time spent preempted).
            prev = self._last_token_t.get(
                ev.uid, self._enq_t.get(ev.uid, t))
            if reg is not None:
                if kind == "first_token":
                    reg.histogram(
                        "serve_ttft_seconds",
                        "Time from enqueue to first generated token"
                    ).observe(t - self._enq_t.get(ev.uid, t))
                reg.histogram(
                    "serve_token_latency_seconds",
                    "Per-generated-token latency (first token measured "
                    "from enqueue)").observe(t - prev)
                reg.counter("serve_tokens_total",
                            "Tokens generated across all requests").inc()
            self._last_token_t[ev.uid] = t
        return ev

    # ------------------------------------------------------------ accessors
    def uids(self) -> list:
        seen: dict = {}
        for ev in self.events:
            seen.setdefault(ev.uid, None)
        return list(seen)

    def events_for(self, uid: int) -> list:
        return [ev for ev in self.events if ev.uid == int(uid)]

    def lifecycle(self, uid: int) -> list:
        return [ev.kind for ev in self.events_for(uid)]

    def ttfts(self) -> list:
        """Seconds from enqueue to first token, one entry per request
        that produced a first token."""
        enq: dict = {}
        out = []
        for ev in self.events:
            if ev.kind == "enqueued":
                enq[ev.uid] = ev.t
            elif ev.kind == "first_token" and ev.uid in enq:
                out.append(ev.t - enq[ev.uid])
        return out

    def token_latencies(self) -> list:
        """Per-token latency deltas, one entry per generated token."""
        prev: dict = {}
        out = []
        for ev in self.events:
            if ev.kind == "enqueued":
                prev[ev.uid] = ev.t
            elif ev.kind in ("first_token", "decode"):
                out.append(ev.t - prev.get(ev.uid, ev.t))
                prev[ev.uid] = ev.t
        return out

    def queue_waits(self) -> list:
        """Queue-entry (enqueued / preempted) to admission deltas, one
        entry per admission."""
        entered: dict = {}
        out = []
        for ev in self.events:
            if ev.kind in ("enqueued", "preempted"):
                entered[ev.uid] = ev.t
            elif ev.kind == "admitted":
                out.append(ev.t - entered.pop(ev.uid, ev.t))
        return out

    def pages_held_hwm(self) -> int:
        """High-water mark of total pages held across live requests,
        sampled at trace transitions."""
        held: dict = {}
        hwm = 0
        for ev in self.events:
            if ev.pages_held is not None:
                held[ev.uid] = ev.pages_held
                hwm = max(hwm, sum(held.values()))
        return hwm

    def preemption_count(self) -> int:
        return sum(1 for ev in self.events if ev.kind == "preempted")

    # ------------------------------------------------------------ validity
    @staticmethod
    def check_lifecycle(kinds) -> str | None:
        """Validate one request's event-kind sequence against the
        lifecycle grammar; returns None if valid, else an error string.

        Grammar (one or more *episodes*; every episode but the last
        ends in ``cancelled``/``timeout`` -- the fleet's retry path
        re-enqueues the uid -- or in ``crashed``/``quarantined`` -- the
        failover path, optionally marked by ``recovered`` before the
        re-enqueue -- and the final one ends in any terminal)::

            TRACE    := EPISODE (recovered? EPISODE)*
            EPISODE  := enqueued RESIDENCY* TERMINAL
            RESIDENCY:= admitted prefilled TOKEN decode* [preempted]
            TERMINAL := finished | cancelled | timeout
                      | crashed | quarantined

        where TOKEN is ``first_token`` on an episode's first residency
        and ``decode`` on re-admissions (the resume token is sampled
        from the re-prefill logits, which is a decode step for the
        request); ``finished`` must follow a residency (a request can
        only complete while resident), while the other terminals may
        also strike a queued or preempted request directly;
        ``finished`` must be the uid's last event overall, and
        ``recovered`` is only legal right after a ``crashed``/
        ``quarantined`` terminal -- followed by a fresh episode in a
        merged fleet trace, or ending the stream (the marker is stamped
        on the struck replica's tracer; the re-enqueue lands on the
        survivor's).
        """
        kinds = list(kinds)
        if not kinds:
            return "empty trace"
        if any(k in SWEEP_KINDS for k in kinds):
            return RequestTracer._check_sweep_lifecycle(kinds)
        i, n = 0, len(kinds)
        prev_terminal = None
        while i < n:
            if kinds[i] == "recovered":
                if prev_terminal not in FAULT_TERMINAL_KINDS:
                    return f"event {i}: 'recovered' without a " \
                           f"preceding crashed/quarantined terminal"
                i += 1
                if i >= n:
                    # valid end: the marker lives on the struck
                    # replica's tracer, the re-enqueue on the
                    # survivor's -- a single replica's stream may
                    # legally end here
                    return None
            if kinds[i] != "enqueued":
                return f"event {i}: expected 'enqueued', got {kinds[i]!r}"
            i += 1
            first_residency = True
            resident = False          # inside a residency, post-TOKEN
            terminal = None
            while terminal is None:
                if i >= n:
                    return "trace ends without a terminal event " \
                           "(finished/cancelled/timeout/crashed/" \
                           "quarantined)"
                k = kinds[i]
                if k in ("cancelled", "timeout", "crashed",
                         "quarantined"):
                    terminal = k
                    i += 1
                elif k == "finished":
                    if not resident:
                        return f"event {i}: 'finished' without a " \
                               f"residency"
                    terminal = k
                    i += 1
                elif k == "preempted":
                    if not resident:
                        return f"event {i}: 'preempted' while not " \
                               f"resident"
                    resident = False
                    i += 1
                elif k == "admitted":
                    if resident:
                        return f"event {i}: 'admitted' while already " \
                               f"resident"
                    i += 1
                    if i >= n or kinds[i] != "prefilled":
                        return f"event {i}: expected 'prefilled' " \
                               f"after 'admitted'"
                    i += 1
                    want = "first_token" if first_residency else "decode"
                    if i >= n or kinds[i] != want:
                        got = kinds[i] if i < n else "<end>"
                        return f"event {i}: expected {want!r} after " \
                               f"prefill, got {got!r}"
                    i += 1
                    first_residency = False
                    resident = True
                    while i < n and kinds[i] == "decode":
                        i += 1
                else:
                    return f"event {i}: unexpected {k!r}"
            if terminal == "finished" and i != n:
                return f"events after 'finished' at {i - 1}"
            # cancelled/timeout/crashed/quarantined: any further events
            # must be a fresh episode (the outer loop re-expects
            # 'enqueued', optionally preceded by 'recovered' after a
            # fault terminal)
            prev_terminal = terminal
        return None

    @staticmethod
    def _check_sweep_lifecycle(kinds) -> str | None:
        """Sweep-point grammar (one point per uid)::

            POINT := point_enqueued
                     (point_loaded | point_started point_finished?)?

        A bare ``point_enqueued`` (optionally followed by a bare
        ``point_started``) is a point still pending/in flight when the
        trace was written -- e.g. a sweep stopped by its ``max_points``
        execution budget; ``point_loaded`` (a store hit) and
        ``point_finished`` are terminal.
        """
        bad = [k for k in kinds if k not in SWEEP_KINDS]
        if bad:
            return f"sweep point mixes serve events: {bad[0]!r}"
        if kinds[0] != "point_enqueued":
            return f"event 0: expected 'point_enqueued', got {kinds[0]!r}"
        rest = kinds[1:]
        if rest in ([], ["point_loaded"], ["point_started"],
                    ["point_started", "point_finished"]):
            return None
        return f"invalid sweep-point sequence {kinds!r}"
