"""Transformer / SSM building blocks for the assigned LM architectures.

All functions are pure; parameters are nested dicts. Sharding is expressed
through repro.distributed.sharding logical-axis constraints so the same code
runs on 1 CPU device (constraints no-op) and on the 512-chip mesh.

Implemented here:
  * RMSNorm, RoPE
  * flash attention (online-softmax, q-chunked python loop + kv lax.scan):
    causal, bidirectional, sliding-window (gemma2), chunked (llama4),
    logit softcap (gemma2), GQA, qk-norm (qwen3)
  * decode attention against a KV cache (seq-shardable)
  * SwiGLU FFN
  * top-k MoE with capacity-based token dropping, expert-parallel via
    shard_map over the 'model' axis (TP-style: activations replicated over
    'model', each shard computes its experts, one psum)
  * Mamba-2 SSD mixer (chunked dual form; inter-chunk pass via
    repro.kernels.ssd_scan)
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding
from repro.kernels.paged_attention import ops as paged_ops
from repro.nn import quantized as nnq

# ---------------------------------------------------------------------------
# linear application (dense or plan-quantized)
# ---------------------------------------------------------------------------


def linear(x: jax.Array, w) -> jax.Array:
    """y[..., n] = x[..., k] @ w[k, n].

    ``w`` is either a dense array (the training / float-serving path) or a
    :class:`repro.nn.quantized.PackedLinear` -- the plan-quantized serving
    path, where the weight provider hands back bit-packed per-precision
    groups that are served through ``mixed_precision_matmul``.
    """
    if isinstance(w, nnq.PackedLinear):
        return w(x)
    return jnp.einsum("bsd,dk->bsk", x, w)


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); pos: (S,) or (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = pos[..., None].astype(jnp.float32) * freqs       # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                       # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return logits
    return cap * jnp.tanh(logits / cap)


# ---------------------------------------------------------------------------
# flash attention (train / prefill)
# ---------------------------------------------------------------------------


def _repeat_kv(kv: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return kv
    b, s, h, d = kv.shape
    return jnp.broadcast_to(kv[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    chunked: bool = False, cap: float = 0.0,
                    q_chunk: int = 1024, kv_chunk: int = 1024,
                    q_offset: int = 0) -> jax.Array:
    """Online-softmax attention. q: (B, S, H, D); k/v: (B, Skv, Hkv, D).

    window > 0 & not chunked -> sliding-window (pos_k > pos_q - window)
    window > 0 & chunked     -> block-local (llama4 iRoPE chunks)
    Python loop over q chunks (static trip counts: the causal kv range per
    q chunk is known at trace time -> no wasted FLOPs on masked-out chunks),
    lax.scan over kv chunks (HLO stays small).
    """
    b, s, h, d = q.shape
    skv = k.shape[1]
    n_rep = h // k.shape[2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    scale = 1.0 / math.sqrt(d)
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, skv)
    assert s % q_chunk == 0 and skv % kv_chunk == 0

    outs = []
    for i in range(s // q_chunk):
        q0 = i * q_chunk
        qi = q[:, q0:q0 + q_chunk]                       # (B, Q, H, D)
        pos_q = q_offset + q0 + jnp.arange(q_chunk)
        # static kv range for this q chunk
        hi = min(q_offset + q0 + q_chunk, skv) if causal else skv
        lo = 0
        if window > 0:
            lo = max(0, (q_offset + q0) - (window - 1)) if not chunked \
                else ((q_offset + q0) // window) * window
        lo = (lo // kv_chunk) * kv_chunk
        hi_pad = -(-hi // kv_chunk) * kv_chunk
        hi_pad = min(hi_pad, skv)
        n_kv = max((hi_pad - lo) // kv_chunk, 1)
        ks = jax.lax.dynamic_slice_in_dim(k, lo, n_kv * kv_chunk, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, lo, n_kv * kv_chunk, 1)
        ks = ks.reshape(b, n_kv, kv_chunk, h, d).transpose(1, 0, 2, 3, 4)
        vs = vs.reshape(b, n_kv, kv_chunk, h, d).transpose(1, 0, 2, 3, 4)

        def body(carry, inp):
            # NOTE: the kv-chunk start position is derived from the carried
            # counter j -- if it were a constant scan input, XLA would
            # constant-fold + hoist the masks of ALL chunks into one giant
            # pred[n_kv, B, H, Q, K] buffer (hundreds of MB per layer).
            m, l, acc, j = carry
            kj, vj = inp
            p0 = lo + j * kv_chunk
            pos_k = p0 + jnp.arange(kv_chunk)
            sij = jnp.einsum("bqhd,bkhd->bhqk", qi.astype(jnp.float32),
                             kj.astype(jnp.float32)) * scale
            sij = softcap(sij, cap)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= pos_k[None, :] <= pos_q[:, None]
            if window > 0 and not chunked:
                mask &= pos_k[None, :] > pos_q[:, None] - window
            if window > 0 and chunked:
                mask &= (pos_k[None, :] // window) == \
                    (pos_q[:, None] // window)
            sij = jnp.where(mask[None, None], sij, -1e30)
            m_new = jnp.maximum(m, jnp.max(sij, axis=-1))
            p = jnp.exp(sij - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vj.astype(jnp.float32))
            return (m_new, l_new, acc_new, j + 1), None

        m0 = jnp.full((b, h, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, d), jnp.float32)
        (m, l, acc, _), _ = jax.lax.scan(
            body, (m0, l0, a0, jnp.asarray(0, jnp.int32)), (ks, vs))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(out.transpose(0, 2, 1, 3).astype(q.dtype))
    return jnp.concatenate(outs, axis=1)                 # (B, S, H, D)


def decode_attention(q: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
                     pos: jax.Array, *, window: int = 0,
                     chunked: bool = False, cap: float = 0.0) -> jax.Array:
    """One-token attention. q: (B, 1, H, D); cache: (B, S, Hkv, D);
    pos: () shared index of the current token, or (B,) per-sequence indices
    (continuous batching: each slot decodes at its own position)."""
    b, s, hkv, d = cache_k.shape
    h = q.shape[2]
    k = _repeat_kv(cache_k, h // hkv)
    v = _repeat_kv(cache_v, h // hkv)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    logits = softcap(logits, cap)
    pos_k = jnp.arange(s)
    posv = jnp.asarray(pos)
    pos_b = posv[None] if posv.ndim == 0 else posv          # (1,) or (B,)
    mask = pos_k[None, :] <= pos_b[:, None]                 # (1|B, S)
    if window > 0 and not chunked:
        mask &= pos_k[None, :] > pos_b[:, None] - window
    if window > 0 and chunked:
        mask &= (pos_k[None, :] // window) == (pos_b[:, None] // window)
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, tables: jax.Array,
                           pos: jax.Array, *, window: int = 0,
                           chunked: bool = False, cap: float = 0.0
                           ) -> jax.Array:
    """One-token attention straight over the KV page pool (no dense
    gather).  q: (B, 1, H, D); k_pool/v_pool: (n_pages + 1, page_size,
    Hkv, D); tables: (B, P) physical page ids (0 = reserved null page);
    pos: (B,) per-slot positions.  Dispatches to the Pallas kernel on
    TPU and to the gathered-view reference (bitwise identical to
    :func:`decode_attention` over the dense row) off-TPU."""
    out = paged_ops.paged_attention(q[:, 0], k_pool, v_pool, tables, pos,
                                    window=window, chunked=chunked,
                                    cap=cap)
    return out[:, None]


def paged_prefill_attention(q: jax.Array, k_pool: jax.Array,
                            v_pool: jax.Array, tables: jax.Array,
                            lens: jax.Array, *, window: int = 0,
                            chunked: bool = False, cap: float = 0.0
                            ) -> jax.Array:
    """Prompt attention straight over the KV page pool (no dense
    round-trip).  q: (B, S, H, D) with rows at or beyond ``lens``
    being discarded padding; k_pool/v_pool: (n_pages + 1, page_size,
    Hkv, D); tables: (B, P) physical page ids (0 = reserved null
    page); lens: (B,) real prompt lengths.  Dispatches to the
    q-chunked Pallas kernel on TPU and to the gathered-view reference
    (the dense :func:`flash_attention` op sequence) off-TPU."""
    return paged_ops.paged_prefill_attention(q, k_pool, v_pool, tables,
                                             lens, window=window,
                                             chunked=chunked, cap=cap)


# ---------------------------------------------------------------------------
# attention layer (projections + cache plumbing)
# ---------------------------------------------------------------------------


def attention_layer(p: dict, x: jax.Array, cfg, *, kind: str = "full",
                    mode: str = "train", cache=None, pos=None,
                    kv_input: Optional[jax.Array] = None,
                    effective_w=None, tables=None):
    """kind: full | local | chunked | bidir | cross.

    Returns (y, new_cache). cache = {"k","v"} of (B, S, Hkv, D); for
    mode="prefill" the produced K/V are returned as the new cache; for
    mode="decode" the token's K/V are written at `pos`.

    tables (decode + prefill): (B, P) int32 per-slot block tables of a
    :class:`~repro.serve.cache.PagedCache` -- cache["k"/"v"] are then
    page POOLS of shape (n_pages + 1, page_size, Hkv, D) and attention
    runs directly on the pool (:func:`paged_decode_attention` /
    :func:`paged_prefill_attention`); for mode="prefill", `pos` carries
    the (B,) real prompt lengths.  The tables ride OUTSIDE the
    (donated) cache tree so the device copy survives across steps.
    """
    b, s, _ = x.shape
    h, hkv, hd = cfg.h_eff, cfg.hkv_eff, cfg.head_dim
    getw = effective_w or (lambda pp: pp["w"])
    kv_src = kv_input if kv_input is not None else x

    q = linear(x, getw(p["wq"]))
    kk = linear(kv_src, getw(p["wk"]))
    vv = linear(kv_src, getw(p["wv"]))
    q = sharding.constrain(q, "batch", None, "heads_flat")
    q = q.reshape(b, s, h, hd)
    kk = kk.reshape(b, kv_src.shape[1], hkv, hd)
    vv = vv.reshape(b, kv_src.shape[1], hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        kk = rmsnorm(kk, p["k_norm"], cfg.norm_eps)

    causal = kind not in ("bidir", "cross")
    window = cfg.local_window if kind in ("local", "chunked") else 0
    chunked = kind == "chunked"

    if kind == "cross":
        if mode == "decode":
            k_all, v_all = cache["k"], cache["v"]   # precomputed encoder KV
            new_cache = cache
            out = decode_attention(q, k_all, v_all, jnp.asarray(
                k_all.shape[1] - 1), cap=cfg.attn_softcap)
        else:
            out = flash_attention(q, kk, vv, causal=False,
                                  cap=cfg.attn_softcap)
            new_cache = {"k": kk, "v": vv}
    elif mode == "decode":
        posn = jnp.asarray(pos)
        # () pos: one shared position; (B,) pos: per-slot positions
        # (continuous batching), rope/cache-write/mask all row-wise.
        pos_rope = posn[None] if posn.ndim == 0 else posn[:, None]
        q = rope(q, pos_rope, cfg.rope_theta)
        kk = rope(kk, pos_rope, cfg.rope_theta)
        if cache is not None and tables is not None:
            # paged KV (serve.cache.PagedCache): cache["k"/"v"] are page
            # pools (n_pages + 1, page_size, hkv, hd), `tables` the
            # per-slot block tables (B, P) of physical page ids.  The
            # step's only cache write is the token's (B,) K/V rows
            # scattered at (tables[b, pos//ps], pos%ps) -- with the tree
            # donated this is an in-place page write -- and attention
            # reads the pool in place (null / never-written pages are
            # skipped, stale page content only ever sits at masked
            # positions).
            page_size = cache["k"].shape[1]
            pos_b = jnp.broadcast_to(posn, (b,)) if posn.ndim == 0 \
                else posn                                # (B,)
            rows = jnp.arange(b)
            phys = tables[rows, pos_b // page_size]      # (B,)
            off = pos_b % page_size
            ck = cache["k"].at[phys, off].set(kk[:, 0].astype(
                cache["k"].dtype))
            cv = cache["v"].at[phys, off].set(vv[:, 0].astype(
                cache["v"].dtype))
            new_cache = {"k": ck, "v": cv}
            out = paged_decode_attention(q, ck, cv, tables, pos_b,
                                         window=window, chunked=chunked,
                                         cap=cfg.attn_softcap)
        else:
            if cache is not None:
                kk = kk.astype(cache["k"].dtype)
                vv = vv.astype(cache["v"].dtype)
                if posn.ndim == 0:
                    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"],
                                                             kk, posn, 1)
                    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"],
                                                             vv, posn, 1)
                else:
                    rows = jnp.arange(b)
                    ck = cache["k"].at[rows, posn].set(kk[:, 0])
                    cv = cache["v"].at[rows, posn].set(vv[:, 0])
            else:
                ck, cv = kk, vv
            new_cache = {"k": ck, "v": cv}
            out = decode_attention(q, ck, cv, posn, window=window,
                                   chunked=chunked, cap=cfg.attn_softcap)
    else:
        positions = jnp.arange(s)
        q = rope(q, positions, cfg.rope_theta)
        kk = rope(kk, positions, cfg.rope_theta)
        if mode == "prefill" and cache is not None and tables is not None:
            # paged prefill (serve.cache.PagedCache): cache["k"/"v"] are
            # page pools, `tables` the per-slot block tables (B, P), and
            # `pos` the (B,) REAL prompt lengths (rows at or beyond it
            # are padding).  Prompt K/V is scattered straight into the
            # slot's pages -- with the tree donated this writes the pool
            # in place -- and attention reads the pool directly.  Padded
            # rows are routed out of bounds and dropped so the pool (in
            # particular the shared null page) only ever holds real
            # tokens; garbage past a partial page's tail never exists.
            page_size = cache["k"].shape[1]
            lens_b = jnp.broadcast_to(jnp.asarray(pos), (b,))       # (B,)
            pg = jnp.minimum(positions // page_size,
                             tables.shape[1] - 1)                   # (S,)
            phys = tables[jnp.arange(b)[:, None], pg[None, :]]      # (B,S)
            phys = jnp.where(positions[None, :] < lens_b[:, None],
                             phys, cache["k"].shape[0])             # OOB
            off = jnp.broadcast_to(positions[None, :] % page_size,
                                   (b, s))
            ck = cache["k"].at[phys, off].set(
                kk.astype(cache["k"].dtype), mode="drop")
            cv = cache["v"].at[phys, off].set(
                vv.astype(cache["v"].dtype), mode="drop")
            new_cache = {"k": ck, "v": cv}
            out = paged_prefill_attention(q, ck, cv, tables, lens_b,
                                          window=window, chunked=chunked,
                                          cap=cfg.attn_softcap)
        else:
            out = flash_attention(q, kk, vv, causal=causal, window=window,
                                  chunked=chunked, cap=cfg.attn_softcap)
            new_cache = {"k": kk, "v": vv} if mode == "prefill" else None

    out = out.reshape(b, s, h * hd)
    y = linear(out, getw(p["wo"]))
    return y, new_cache


# ---------------------------------------------------------------------------
# FFN / MoE
# ---------------------------------------------------------------------------


def ffn_swiglu(p: dict, x: jax.Array, effective_w=None) -> jax.Array:
    getw = effective_w or (lambda pp: pp["w"])
    g = linear(x, getw(p["w_gate"]))
    u = linear(x, getw(p["w_up"]))
    h = jax.nn.silu(g) * u
    h = sharding.constrain(h, "batch", None, "mlp")
    return linear(h, getw(p["w_down"]))


def _moe_local(x, router_w, w_gate, w_up, w_down, *, n_experts: int,
               top_k: int, capacity: int, e_offset):
    """Per-shard MoE: x (T, D) local tokens; w_* (E_loc, ...) local experts.

    Capacity-based dropping: each expert processes its top-`capacity`
    local tokens by gate weight; overflow tokens are dropped (contribute 0
    for that expert), matching Switch-style routing.
    """
    t, dm = x.shape
    e_loc = w_gate.shape[0]
    logits = x @ router_w                                # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, top_k)             # (T, k)
    y = jnp.zeros((t, dm), jnp.float32)
    for el in range(e_loc):
        eg = e_offset + el
        match = (ids == eg)
        gate_e = jnp.sum(gates * match, axis=-1)         # (T,)
        top_g, top_i = jax.lax.top_k(gate_e, min(capacity, t))
        xe = x[top_i]                                    # (C, D)
        hh = jax.nn.silu(xe @ w_gate[el]) * (xe @ w_up[el])
        oe = (hh @ w_down[el]).astype(jnp.float32)
        y = y.at[top_i].add(oe * top_g[:, None])
    return y.astype(x.dtype)


def moe_layer(p: dict, x: jax.Array, cfg, effective_w=None) -> jax.Array:
    """Top-k MoE over cfg.n_experts, experts sharded on 'model'."""
    getw = effective_w or (lambda pp: pp["w"])
    b, s, dm = x.shape
    mesh = sharding.get_mesh()
    rules = sharding.get_rules() or {}
    e = cfg.n_experts
    k = cfg.experts_per_token
    router_w = p["router"]["w"]
    wg, wu, wd = (getw(p["w_gate"]), getw(p["w_up"]), getw(p["w_down"]))

    tp = 1
    if mesh is not None and rules.get("experts"):
        tp = mesh.shape[rules["experts"]]
    batch_axes = rules.get("batch")
    if mesh is None or tp == 1:
        xx = x.reshape(b * s, dm)
        cap = max(1, int(math.ceil(b * s * k * cfg.capacity_factor / e)))
        y = _moe_local(xx, router_w, wg, wu, wd, n_experts=e, top_k=k,
                       capacity=cap, e_offset=0)
        out = y.reshape(b, s, dm)
    else:
        dp = 1
        for ax in (batch_axes if isinstance(batch_axes, tuple)
                   else (batch_axes,) if batch_axes else ()):
            dp *= mesh.shape[ax]
        t_loc = max(b // dp, 1) * s
        cap = max(1, int(math.ceil(t_loc * k * cfg.capacity_factor / e)))
        e_loc = e // tp
        model_ax = rules["experts"]

        def shard_fn(xs, rw, wg_, wu_, wd_):
            t_b, t_s, t_d = xs.shape
            xx = xs.reshape(t_b * t_s, t_d)
            e_off = jax.lax.axis_index(model_ax) * e_loc
            y = _moe_local(xx, rw, wg_, wu_, wd_, n_experts=e, top_k=k,
                           capacity=cap, e_offset=e_off)
            y = jax.lax.psum(y, model_ax)
            return y.reshape(t_b, t_s, t_d)

        out = jax.shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(batch_axes, None, None), P(None, None),
                      P(model_ax, None, None), P(model_ax, None, None),
                      P(model_ax, None, None)),
            out_specs=P(batch_axes, None, None),
        )(x, router_w, wg, wu, wd)

    if cfg.dense_residual:
        out = out + ffn_swiglu(p["shared"], x, effective_w)
    return out


# ---------------------------------------------------------------------------
# Mamba-2 SSD mixer
# ---------------------------------------------------------------------------


def _causal_conv1d(x: jax.Array, w: jax.Array, mode: str,
                   conv_state: Optional[jax.Array]):
    """Depthwise causal conv. x: (B, S, C); w: (K, C).
    Returns (y, new_conv_state (B, K-1, C))."""
    kk = w.shape[0]
    w = w.astype(x.dtype)   # bf16 compute (conv weights are tiny)
    if mode == "decode":
        window = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
        y = jnp.einsum("bkc,kc->bc", window, w)[:, None, :]
        return y, window[:, 1:, :]
    pad = jnp.zeros((x.shape[0], kk - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :]
            for i in range(kk))
    new_state = xp[:, xp.shape[1] - (kk - 1):, :]
    return y, new_state


def mamba2_layer(p: dict, x: jax.Array, cfg, *, mode: str = "train",
                 state=None, effective_w=None):
    """Mamba-2 (SSD) mixer. x: (B, S, D).

    Projections are kept separate (z / x / B / C / dt) so each output dim
    has a clean sharding: d_inner and heads shard on 'model' ('ssm_inner'),
    the small B/C/dt streams stay replicated.

    state (decode): {"ssm": (B, H, P, N), "conv": {"x","b","c"}}.
    Returns (y, new_state) -- None for mode="train", the final state for
    "prefill"/"decode".
    """
    getw = effective_w or (lambda pp: pp["w"])
    b, s, _ = x.shape
    di, n, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    nh = cfg.ssm_heads

    z = linear(x, getw(p["in_z"]))                          # (B,S,di)
    xs_pre = linear(x, getw(p["in_x"]))                     # (B,S,di)
    bb_pre = linear(x, getw(p["in_b"]))                     # (B,S,N)
    cc_pre = linear(x, getw(p["in_c"]))                     # (B,S,N)
    dt = linear(x, getw(p["in_dt"]))                        # (B,S,H)
    z = sharding.constrain(z, "batch", None, "ssm_inner")
    xs_pre = sharding.constrain(xs_pre, "batch", None, "ssm_inner")

    cst = None if state is None else state["conv"]
    xs_pre, ncx = _causal_conv1d(xs_pre, p["conv_x"], mode,
                                 None if cst is None else cst["x"])
    bb_pre, ncb = _causal_conv1d(bb_pre, p["conv_b"], mode,
                                 None if cst is None else cst["b"])
    cc_pre, ncc = _causal_conv1d(cc_pre, p["conv_c"], mode,
                                 None if cst is None else cst["c"])
    new_conv = {"x": ncx, "b": ncb, "c": ncc}
    xs = jax.nn.silu(xs_pre).reshape(b, s, nh, hd)          # (B,S,H,P)
    bb = jax.nn.silu(bb_pre)                                # (B,S,N)
    cc = jax.nn.silu(cc_pre)                                # (B,S,N)
    dt = jax.nn.softplus(dt + p["dt_bias"]).astype(jnp.float32)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))            # (H,)
    dta = dt * a                                            # (B,S,H) <= 0
    xs_f = xs.astype(jnp.float32)
    bb_f = bb.astype(jnp.float32)
    cc_f = cc.astype(jnp.float32)

    if mode == "decode":
        s0 = state["ssm"]                                   # (B,H,P,N)
        dec = jnp.exp(dta[:, 0])                            # (B,H)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0], xs_f[:, 0], bb_f[:, 0])
        s_new = dec[..., None, None] * s0 + upd
        y = jnp.einsum("bhpn,bn->bhp", s_new, cc_f[:, 0])
        y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xs_f[:, 0]
        y = y.reshape(b, 1, di)
        new_state = {"ssm": s_new, "conv": new_conv}
    else:
        # chunked SSD dual form, lax.scan over chunks: one chunk's (Q, Q, H)
        # decay matrix live at a time (memory O(B*Q^2*H), not O(B*S*Q*H));
        # the carried running state is exactly the inter-chunk recurrence
        # that kernels/ssd_scan implements standalone for the TPU path.
        q = min(cfg.ssm_chunk, s)
        if mode == "train":
            # training shapes must tile exactly -- fail loudly, a silent
            # divisor fallback would quietly shrink the chunk
            assert s % q == 0, (s, q)
        else:
            # serving prefill accepts arbitrary prompt lengths: largest
            # divisor of s that fits the chunk budget (prime lengths
            # degrade toward q=1 -- correct but slow; exact-length
            # prefill keeps the SSM state unpolluted by padding)
            while s % q:
                q -= 1
        nc = s // q
        tri = jnp.tril(jnp.ones((q, q), bool))
        # (nc, B, Q, ...) chunk-major for the scan
        xs_c = jnp.moveaxis(xs_f.reshape(b, nc, q, nh, hd), 1, 0)
        bb_c = jnp.moveaxis(bb_f.reshape(b, nc, q, n), 1, 0)
        cc_c = jnp.moveaxis(cc_f.reshape(b, nc, q, n), 1, 0)
        dt_c = jnp.moveaxis(dt.reshape(b, nc, q, nh), 1, 0)
        dta_c = jnp.moveaxis(dta.reshape(b, nc, q, nh), 1, 0)

        def chunk_body(s_prev, inp):
            xc, bc, cci, dtc, dtac = inp                    # (B,Q,...)
            lcum = jnp.cumsum(dtac, axis=1)                 # (B,Q,H)
            li = lcum[:, :, None, :] - lcum[:, None, :, :]  # (B,Q,Q,H)
            decay_qq = jnp.where(tri[None, :, :, None], jnp.exp(li), 0.0)
            scores = jnp.einsum("bqn,btn->bqt", cci, bc)[..., None] \
                * decay_qq                                  # (B,Q,Q,H)
            y_intra = jnp.einsum("bqth,bth,bthp->bqhp", scores, dtc, xc)
            # inter-chunk term from the carried prefix state
            dec_from_start = jnp.exp(lcum)                  # (B,Q,H)
            y_inter = jnp.einsum("bqh,bhpn,bqn->bqhp",
                                 dec_from_start, s_prev, cci)
            # state update: S <- exp(l_end) S + sum_t e^{l_end-l_t} B (dt x)
            dec_to_end = jnp.exp(lcum[:, -1:, :] - lcum)    # (B,Q,H)
            s_in = jnp.einsum("bth,bth,bthp,btn->bhpn",
                              dec_to_end, dtc, xc, bc)
            s_new = jnp.exp(lcum[:, -1, :])[..., None, None] * s_prev + s_in
            return s_new, y_intra + y_inter

        s0 = state["ssm"].astype(jnp.float32) if state is not None else \
            jnp.zeros((b, nh, hd, n), jnp.float32)
        final, y_c = jax.lax.scan(chunk_body, s0,
                                  (xs_c, bb_c, cc_c, dt_c, dta_c))
        y = jnp.moveaxis(y_c, 0, 1).reshape(b, s, nh, hd)
        y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] \
            * xs_f.reshape(b, s, nh, hd)
        y = y.reshape(b, s, di)
        new_state = None if mode == "train" else \
            {"ssm": final, "conv": new_conv}

    y = y.astype(x.dtype) * jax.nn.silu(z)
    y = rmsnorm(y, p["ssm_norm"], cfg.norm_eps)
    y = sharding.constrain(y, "batch", None, "ssm_inner")
    out = linear(y, getw(p["out_proj"]))
    return out, new_state
