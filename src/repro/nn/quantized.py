"""Packed mixed-precision linear layers for quantized serving (Fig. 3).

This is the canonical home of the deployment-side packing math: after the
search assigns per-output-channel bit-widths, a layer's channels are
reordered into contiguous per-precision groups (paper Fig. 3), bit-packed,
and served through one ``quant_matmul`` per group.  Three consumers share
this module so a plan packs byte-identically everywhere:

  * ``serve.engine.export_mixed_precision_layer`` (per-layer export API),
  * :class:`PackedLinear` -- the pytree weight object that the LM forward
    serves through its ``getw`` weight provider (plan-driven decode),
  * the kernel-level ``quant_matmul.ops.quantized_linear_apply``.

Activation quantization here is **per row** (per token): each row of the
flattened ``(tokens, features)`` input gets its own int8 scale.  Besides
being more accurate than a per-tensor scale, this makes the quantized
matmul *batch-invariant* -- a request decodes to the same tokens whether it
shares a continuous-batching step with 7 neighbours or runs alone, which
the serving parity tests rely on.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import discretize, quantizers
from repro.kernels.quant_matmul import ops as qops


def quantize_activations_per_row(x: jax.Array):
    """Symmetric int8 activation quantization with one scale per row.

    x: (M, K) float. Returns (xq int8 (M, K), sx (M, 1) f32).
    """
    x = x.astype(jnp.float32)
    sx = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-8) / 127.0
    xq = jnp.clip(jnp.round(x / sx), -127, 127).astype(jnp.int8)
    return xq, sx


def pack_channelwise(w: np.ndarray, channel_bits: np.ndarray,
                     perm: np.ndarray | None = None):
    """Reorder + bit-pack one layer (paper Fig. 3).

    w: (C_out, C_in) float weights; channel_bits: (C_out,) ints (0 = pruned).
    ``perm`` overrides the reorder permutation (e.g. the one stored in a
    :class:`~repro.api.plan.CompressionPlan`); by default it is recomputed
    from ``channel_bits``.

    Returns ``(packed, perm, kept)`` where ``packed`` is
    ``[(bits, wq_packed (Ni, C_in*bits/8) int8, scales (Ni,) f32), ...]``
    in ascending-bits order and ``kept`` counts the non-pruned channels.
    A fully-pruned layer yields ``packed == []`` and ``kept == 0``.
    """
    if perm is None:
        perm = discretize.reorder_permutations(
            {"gamma": {"l": channel_bits}})["l"]
    w_sorted = np.asarray(w)[perm]
    bits_sorted = np.asarray(channel_bits)[perm]
    packed = []
    for b in sorted(set(int(x) for x in bits_sorted if x > 0)):
        rows = w_sorted[bits_sorted == b]
        qi, scale = quantizers.integerize_weights(jnp.asarray(rows), b, 0)
        k = rows.shape[1]
        per = 8 // b
        pad = (-k) % per
        qi_np = np.asarray(qi)
        if pad:
            qi_np = np.pad(qi_np, ((0, 0), (0, pad)))
        packed.append((b, jnp.asarray(qops.pack_weights(qi_np, b)),
                       jnp.asarray(scale[:, 0])))
    kept = int(np.sum(bits_sorted > 0))
    return packed, perm, kept


def mixed_precision_matmul(x: jax.Array, packed_layers) -> jax.Array:
    """Serve ``y = x @ W^T`` for a reordered mixed-precision layer: one
    quant_matmul per precision group, outputs concatenated (Fig. 3).

    x: (M, K) float; returns (M, kept) f32 in permuted (ascending-bits)
    channel order.  An empty ``packed_layers`` (fully-pruned layer) returns
    a well-defined zero-width (M, 0) result.
    """
    if not packed_layers:
        return jnp.zeros(x.shape[:-1] + (0,), jnp.float32)
    xq, sx_row = quantize_activations_per_row(x)
    one = jnp.asarray(1.0, jnp.float32)
    outs = [qops.quant_matmul(xq, wq, sw, one, w_bits=bits)
            for bits, wq, sw in packed_layers]
    return jnp.concatenate(outs, axis=-1) * sx_row


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedLinear:
    """A bit-packed mixed-precision weight, servable inside a jitted LM
    forward.

    Stands in for a dense ``(n_in, n_out)`` projection matrix: the LM's
    weight provider returns it instead of an array and ``blocks.linear``
    dispatches to :meth:`__call__`, which runs one ``quant_matmul`` per
    precision group and scatters the concatenated group outputs back to
    the original channel order (pruned channels stay exactly zero, the
    same semantics as the search's 0-bit effective weight).

    Registered as a pytree so parameter trees containing it can cross
    ``jax.jit`` boundaries; the packed buffers and scales are leaves, the
    bit-widths and dimensions are static aux data.
    """

    groups: tuple        # ((bits, wq_packed, scales), ...) ascending bits
    out_index: jax.Array  # (kept,) int32: original positions of kept chans
    n_in: int
    n_out: int

    @classmethod
    def from_dense(cls, w_in_out: np.ndarray, channel_bits: np.ndarray,
                   perm: np.ndarray | None = None) -> "PackedLinear":
        """Pack a ``(n_in, n_out)`` projection (the LM's ``w`` layout)."""
        w = np.asarray(w_in_out, np.float32)
        packed, perm, kept = pack_channelwise(w.T, channel_bits, perm=perm)
        return cls(groups=tuple(packed),
                   out_index=jnp.asarray(np.asarray(perm)[:kept], jnp.int32),
                   n_in=int(w.shape[0]), n_out=int(w.shape[1]))

    @property
    def kept(self) -> int:
        return int(self.out_index.shape[0])

    def __call__(self, x: jax.Array) -> jax.Array:
        lead = x.shape[:-1]
        x2 = x.reshape((-1, self.n_in))
        full = jnp.zeros((x2.shape[0], self.n_out), jnp.float32)
        if self.groups:
            y = mixed_precision_matmul(x2, self.groups)
            full = full.at[:, self.out_index].set(y)
        return full.reshape(lead + (self.n_out,)).astype(x.dtype)

    # ------------------------------------------------------------- pytree
    def tree_flatten(self):
        leaves = []
        bits = []
        for b, wq, sw in self.groups:
            leaves.extend((wq, sw))
            bits.append(int(b))
        leaves.append(self.out_index)
        return leaves, (tuple(bits), self.n_in, self.n_out)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        bits, n_in, n_out = aux
        groups = tuple((b, leaves[2 * i], leaves[2 * i + 1])
                       for i, b in enumerate(bits))
        return cls(groups=groups, out_index=leaves[-1],
                   n_in=n_in, n_out=n_out)
