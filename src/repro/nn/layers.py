"""Minimal functional NN layers over plain pytrees (no flax available).

Conventions: NHWC activations, conv weights (C_out, C_in // groups, K_y, K_x)
so the *output-channel axis is 0* everywhere (matching the per-channel MPS
convention), linear weights (C_out, C_in).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def he_init(key, shape, fan_in, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * np.sqrt(2.0 / fan_in)


def conv2d(x: jax.Array, w: jax.Array, b: jax.Array | None = None,
           stride: int = 1, padding="SAME", groups: int = 1) -> jax.Array:
    """x: (N, H, W, C_in); w: (C_out, C_in//groups, K_y, K_x)."""
    out = jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding=padding,
        feature_group_count=groups,
        dimension_numbers=("NHWC", "OIHW", "NHWC"),
    )
    if b is not None:
        out = out + b
    return out


def linear(x: jax.Array, w: jax.Array, b: jax.Array | None = None
           ) -> jax.Array:
    """x: (..., C_in); w: (C_out, C_in)."""
    out = jnp.einsum("...i,oi->...o", x, w)
    if b is not None:
        out = out + b
    return out


# ---------------------------------------------------------------------------
# BatchNorm with running statistics kept in an explicit state pytree.
# ---------------------------------------------------------------------------

def bn_init(c: int):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,)),
            "mean": jnp.zeros((c,)), "var": jnp.ones((c,))}


def batchnorm(x: jax.Array, p: dict, train: bool, momentum: float = 0.9,
              eps: float = 1e-5):
    """Returns (y, updated_params). Channel axis is the last one."""
    if train:
        axes = tuple(range(x.ndim - 1))
        mean = jnp.mean(x, axis=axes)
        var = jnp.var(x, axis=axes)
        new_p = dict(p)
        new_p["mean"] = momentum * p["mean"] + (1 - momentum) * mean
        new_p["var"] = momentum * p["var"] + (1 - momentum) * var
    else:
        mean, var = p["mean"], p["var"]
        new_p = p
    inv = jax.lax.rsqrt(var + eps)
    y = (x - mean) * inv * p["scale"] + p["bias"]
    return y, new_p


def fold_bn_into_conv(w: jax.Array, b: jax.Array | None, bn: dict,
                      eps: float = 1e-5):
    """Fold BN (inference form) into the preceding conv/linear (paper 4.2).

    w has C_out on axis 0. Returns (w_folded, b_folded).
    """
    inv = 1.0 / np.sqrt(np.asarray(bn["var"]) + eps)
    g = np.asarray(bn["scale"]) * inv                       # (C,)
    shape = (w.shape[0],) + (1,) * (w.ndim - 1)
    w_f = w * jnp.asarray(g).reshape(shape)
    b0 = b if b is not None else jnp.zeros((w.shape[0],), w.dtype)
    b_f = (b0 - jnp.asarray(bn["mean"])) * jnp.asarray(g) \
        + jnp.asarray(bn["bias"])
    return w_f, b_f


def max_pool(x, k=2, stride=2):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, k, k, 1), (1, stride, stride, 1),
                                 "VALID")


def avg_pool(x, k=2, stride=2):
    s = jax.lax.reduce_window(x, 0.0, jax.lax.add, (1, k, k, 1),
                              (1, stride, stride, 1), "VALID")
    return s / float(k * k)


def global_avg_pool(x):
    return jnp.mean(x, axis=(1, 2))
