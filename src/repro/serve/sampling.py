"""Per-request sampling configuration + token sampling (device + host).

``SamplingParams`` replaces the hard-coded argmax of the old ServeEngine:
every request carries its own (temperature, top-k, max_tokens, seed), and
a request samples the identical token stream whether it is decoded alone
or inside a continuous batch (the parity the serving tests assert).

The default path is **on-device**: :func:`sample_tokens_device` draws the
whole batch inside the jitted decode step -- per-row temperature/top-k via
``jax.lax.top_k`` and the Gumbel-max trick, with each row's randomness
derived by ``fold_in``-ing (seed, uid, token-index) so the draw is a
function of the request alone, never of its batch neighbors.  No host
round-trip per token; only the sampled ids come back.

:func:`sample_token` is the retained host fallback (numpy generator per
request, ``InferenceServer(sample_on_device=False)``); greedy decode is
bit-identical on both paths.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """How one request turns logits into tokens.

    temperature == 0 is greedy (argmax); top_k == 0 means no top-k
    truncation; ``seed`` keys the per-request random stream.
    """

    temperature: float = 0.0
    top_k: int = 0
    max_tokens: int = 16
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"SamplingParams.temperature must be >= 0, "
                             f"got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"SamplingParams.top_k must be >= 0, "
                             f"got {self.top_k}")
        if self.max_tokens < 1:
            raise ValueError(f"SamplingParams.max_tokens must be >= 1, "
                             f"got {self.max_tokens}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


def sample_tokens_device(logits: jax.Array, temperature: jax.Array,
                         top_k: jax.Array, seed: jax.Array, uid: jax.Array,
                         token_index: jax.Array,
                         need_top_k: bool = True) -> jax.Array:
    """Batched on-device sampling: (B, V) logits -> (B,) token ids.

    All per-row params are (B,) arrays.  temperature == 0 rows are greedy
    (argmax, bit-identical to the host fallback); top_k == 0 means no
    truncation.  Randomness per row is ``fold_in(fold_in(key(seed), uid),
    token_index)`` -- independent of batch composition, so batched ==
    solo == streaming, and a preempted request resumed later continues
    the exact stream (token_index counts tokens sampled so far).

    Jit-friendly: every argument is traced (no per-batch recompiles); the
    per-row k threshold comes from the full ``lax.top_k`` descending sort
    + a dynamic take, the draw from argmax(z + Gumbel) over the truncated
    support.

    ``need_top_k`` is a trace-time flag: pass False when NO row truncates
    (every ``top_k`` is <= 0 or >= V) and the full-vocab descending sort
    is skipped entirely -- pure-temperature batches then pay only the
    Gumbel draw.  Truncating rows with ``need_top_k=False`` would be
    silently un-truncated; the caller (``InferenceServer``) derives the
    flag from the active requests' SamplingParams.
    """
    logits = logits.astype(jnp.float32)
    b, v = logits.shape
    greedy_tok = jnp.argmax(logits, axis=-1)

    safe_t = jnp.where(temperature > 0, temperature, 1.0)
    z = logits / safe_t[:, None]
    if need_top_k:
        svals, _ = jax.lax.top_k(z, v)                 # descending sort
        kth_idx = jnp.clip(top_k - 1, 0, v - 1)
        kth = jnp.take_along_axis(svals, kth_idx[:, None], axis=-1)
        keep = (top_k <= 0)[:, None] | (z >= kth)
        z = jnp.where(keep, z, -jnp.inf)

    def row_gumbel(s, u, t):
        key = jax.random.fold_in(jax.random.fold_in(
            jax.random.key(s), u), t)
        return jax.random.gumbel(key, (v,), jnp.float32)

    g = jax.vmap(row_gumbel)(seed.astype(jnp.uint32),
                             uid.astype(jnp.uint32),
                             token_index.astype(jnp.uint32))
    sampled_tok = jnp.argmax(z + g, axis=-1)
    return jnp.where(temperature > 0, sampled_tok, greedy_tok).astype(
        jnp.int32)


def batch_need_top_k(samplings, vocab: int, registry=None) -> bool:
    """The trace-time ``need_top_k`` flag for one decode step's batch:
    True iff any row actually truncates (``0 < top_k < vocab``).

    When a metrics registry is given, counts the step into
    ``serve_topk_sort_steps_total{skipped}`` so the top-k-skip hit rate
    (fraction of decode steps that avoided the full-vocab sort) is
    observable.
    """
    need = any(0 < sp.top_k < vocab for sp in samplings)
    if registry is not None:
        registry.counter(
            "serve_topk_sort_steps_total",
            "Sampled decode steps by whether the full-vocab top-k sort "
            "was skipped", labels=("skipped",)).inc(
            skipped="false" if need else "true")
    return need


def make_rng(params: SamplingParams, uid: int) -> np.random.Generator:
    """The request's random stream: a function of (seed, uid) only, so
    re-serving the same request replays identical draws."""
    return np.random.default_rng((int(params.seed), int(uid)))


def sample_token(logits: np.ndarray, params: SamplingParams,
                 rng: np.random.Generator) -> int:
    """Draw one token id from a (V,) logits row."""
    logits = np.asarray(logits, np.float64)
    if params.greedy:
        return int(np.argmax(logits))
    z = logits / params.temperature
    if 0 < params.top_k < z.size:
        kth = np.partition(z, -params.top_k)[-params.top_k]
        z = np.where(z >= kth, z, -np.inf)
    z = z - z.max()
    p = np.exp(z)
    p /= p.sum()
    return int(rng.choice(z.size, p=p))
