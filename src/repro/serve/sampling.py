"""Per-request sampling configuration + host-side token sampling.

``SamplingParams`` replaces the hard-coded argmax of the old ServeEngine:
every request carries its own (temperature, top-k, max_tokens, seed), and
the engine draws from a per-request ``numpy`` generator so a request
samples the identical token stream whether it is decoded alone or inside a
continuous batch (the parity the serving tests assert).

Sampling runs on the host over the (small) vocab row of the current token.
At production vocab sizes the draw should move on-device (batched gumbel
top-k over the sharded logits); that is an open ROADMAP item -- the
SamplingParams surface is already shaped for it.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """How one request turns logits into tokens.

    temperature == 0 is greedy (argmax); top_k == 0 means no top-k
    truncation; ``seed`` keys the per-request random stream.
    """

    temperature: float = 0.0
    top_k: int = 0
    max_tokens: int = 16
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"SamplingParams.temperature must be >= 0, "
                             f"got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"SamplingParams.top_k must be >= 0, "
                             f"got {self.top_k}")
        if self.max_tokens < 1:
            raise ValueError(f"SamplingParams.max_tokens must be >= 1, "
                             f"got {self.max_tokens}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


def make_rng(params: SamplingParams, uid: int) -> np.random.Generator:
    """The request's random stream: a function of (seed, uid) only, so
    re-serving the same request replays identical draws."""
    return np.random.default_rng((int(params.seed), int(uid)))


def sample_token(logits: np.ndarray, params: SamplingParams,
                 rng: np.random.Generator) -> int:
    """Draw one token id from a (V,) logits row."""
    logits = np.asarray(logits, np.float64)
    if params.greedy:
        return int(np.argmax(logits))
    z = logits / params.temperature
    if 0 < params.top_k < z.size:
        kth = np.partition(z, -params.top_k)[-params.top_k]
        z = np.where(z >= kth, z, -np.inf)
    z = z - z.max()
    p = np.exp(z)
    p /= p.sum()
    return int(rng.choice(z.size, p=p))
