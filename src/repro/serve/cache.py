"""Unified cache backends for the serving stack.

The :class:`~repro.serve.engine.InferenceServer` no longer owns raw
KV/SSM buffers; it drives a :class:`CacheBackend`:

    alloc(uid, slot, n_prompt) -> CacheHandle     (admission)
    insert(handle, prefill_caches)                (prompt KV/SSM -> cache)
    append(handle)                                (one decoded token;
                                                   may allocate a page ->
                                                   raises PoolExhausted)
    gather() -> caches pytree                     (resident tree for
                                                   decode_step; donated)
    device_tables() -> (B, P) int32 | None        (paged: device-resident
                                                   block tables, NOT
                                                   donated; cached across
                                                   steps, updated
                                                   incrementally)
    commit(new_caches)                            (store the step's output)
    free(handle)                                  (retirement/preemption)
    can_admit(n_prompt) / memory_report()         (the admission contract)

Two implementations:

* :class:`DenseCache` -- the pre-existing behavior: one dense
  ``(nsb, max_batch, max_len, ...)`` buffer per KV tensor, every slot pins
  ``max_len`` positions regardless of actual length.
* :class:`PagedCache` -- vLLM-style paging (PagedAttention, Kwon et al.
  2023): a fixed pool of ``page_size``-token pages plus per-slot block
  tables; pages are allocated on admission (prompt + first decode write)
  and lazily as decode crosses page boundaries, and freed on retirement,
  so cache memory scales with tokens actually held.  SSM state is O(1)
  per request and lives in a parallel per-slot pool.  Physical page 0 is
  a reserved null page: inactive slots and unused block-table entries
  point at it, and anything written there is only ever read at masked
  positions.

The backends' contract is *token-for-token invariance*: the same request
stream produces identical tokens on either backend (and solo vs.
batched).  ``page_size`` must divide ``max_len`` so a slot's pages cover
exactly the dense position range.

Decode reads the page pool IN PLACE: ``gather()`` returns the resident
pool tree (no per-step view materialization, no per-step host->device
table upload) and ``device_tables()`` the block tables, threaded through
``lm.decode_step`` outside the donated cache tree.  On TPU attention
runs the ``repro.kernels.paged_attention`` Pallas kernel over the pool;
off-TPU the fallback view is bitwise identical to the dense row.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm


class PoolExhausted(RuntimeError):
    """The page pool cannot serve an allocation; the engine reacts by
    preempting a request back to the queue."""


@dataclasses.dataclass
class CacheHandle:
    """One admitted request's cache residency."""

    uid: int
    slot: int                 # decode-batch row / block-table row
    n_tokens: int             # cache positions written so far
    pages: list = dataclasses.field(default_factory=list)


def _ins_slot(big, small, slot):
    """Insert a per-request state (leading batch dim 1) into slot row."""
    small = small.astype(big.dtype)
    starts = (0, slot) + (0,) * (big.ndim - 2)
    return jax.lax.dynamic_update_slice(big, small, starts)


# ---------------------------------------------------------------------------
# incremental device-side block-table updates
# ---------------------------------------------------------------------------
#
# The block tables live on device across decode steps (the decode step
# reads them as a non-donated argument); page-allocation events patch
# single entries via these jitted helpers instead of re-uploading the
# host table every step.  TRACE_COUNTS increments once per *trace* (not
# per call) -- the no-per-step-host-sync test asserts it stays flat while
# decode runs.

TRACE_COUNTS = collections.Counter()


def _counting_jit(name: str, fn):
    def traced(*args):
        TRACE_COUNTS[name] += 1          # python side effect: trace-time only
        return fn(*args)
    return jax.jit(traced)


_table_set_row = _counting_jit(
    "table_set_row", lambda t, slot, row: t.at[slot].set(row))
_table_set_entry = _counting_jit(
    "table_set_entry", lambda t, slot, pg, phys: t.at[slot, pg].set(phys))
_table_clear_row = _counting_jit(
    "table_clear_row", lambda t, slot: t.at[slot].set(0))


class CacheBackend:
    """Shared bookkeeping; subclasses fill in the storage strategy."""

    name = "abstract"

    def __init__(self, cfg, max_batch: int, max_len: int):
        self.cfg = cfg
        self.max_batch = int(max_batch)
        self.max_len = int(max_len)
        self.caches = None
        self._metrics = None

    # -- admission contract -------------------------------------------------
    def can_admit(self, n_prompt: int) -> bool:
        raise NotImplementedError

    def check_feasible(self, n_prompt: int, max_tokens: int):
        """Raise if the request could never run to completion alone."""

    def alloc(self, uid: int, slot: int, n_prompt: int) -> CacheHandle:
        raise NotImplementedError

    def free(self, handle: CacheHandle):
        raise NotImplementedError

    def append(self, handle: CacheHandle):
        """Advance one decoded token; ensure the next write position is
        backed by storage (may raise :class:`PoolExhausted`)."""
        handle.n_tokens += 1

    # -- data movement ------------------------------------------------------
    def insert(self, handle: CacheHandle, prefill_caches):
        raise NotImplementedError

    def gather(self):
        """The caches pytree ``lm.decode_step`` consumes this step."""
        return self.caches

    def device_tables(self):
        """Paged backends: the device-resident (B, P) block tables the
        decode step takes OUTSIDE the donated cache tree (None for
        backends that need none).  The engine truncates them to the
        live-page prefix INSIDE the jitted step (static width), so
        decode attention scans only pages some slot actually wrote."""
        return None

    def commit(self, new_caches):
        """Store the (donated-through) cache tree a decode step returned."""
        self.caches = new_caches

    # -- reporting ----------------------------------------------------------
    def memory_report(self) -> dict:
        raise NotImplementedError

    def bind_metrics(self, registry):
        """Attach a :class:`repro.obs.MetricsRegistry` (or None).  The
        engine calls this so ``publish_metrics`` and event counters have
        somewhere to write; instrumentation is host-side bookkeeping
        only -- cache data movement is untouched."""
        self._metrics = registry if (registry is not None
                                     and registry.enabled) else None

    def shrink_pool(self, n_pages: int) -> int:
        """Withhold up to ``n_pages`` free pages from the pool (the
        chaos layer's page-pool-pressure fault; a pure host-side
        bookkeeping change).  Returns how many were actually withheld
        (0 for backends without a pool)."""
        return 0

    def restore_pool(self) -> int:
        """Return every withheld page to the free pool; returns how
        many came back."""
        return 0

    def publish_metrics(self):
        """Mirror the numeric fields of :meth:`memory_report` into
        ``serve_cache_<key>{backend=...}`` gauges."""
        if self._metrics is None:
            return
        for key, value in self.memory_report().items():
            if isinstance(value, bool) or not isinstance(
                    value, (int, float)):
                continue
            self._metrics.gauge(
                f"serve_cache_{key}",
                f"Cache backend memory_report field {key!r}",
                labels=("backend",)).set(value, backend=self.name)

    def reset(self):
        """Drop all residency bookkeeping (buffers may keep stale data;
        every readable position is overwritten before it is unmasked)."""


class DenseCache(CacheBackend):
    """Current behavior, refactored behind the backend API: every decode
    slot pins a dense ``max_len`` KV row for its whole lifetime."""

    name = "dense"

    def __init__(self, cfg, max_batch: int, max_len: int):
        super().__init__(cfg, max_batch, max_len)
        self.caches = lm.init_caches(cfg, max_batch, max_len)
        self._bytes = lm.dense_cache_bytes(cfg, max_batch, max_len)
        self._live_tokens = 0
        self._peak_tokens = 0
        self._handles: dict[int, CacheHandle] = {}

        def ins(caches, pcaches, slot):
            return jax.tree.map(
                lambda big, small: _ins_slot(big, small, slot),
                caches, pcaches)

        self._insert = jax.jit(ins, donate_argnums=(0,))

    def can_admit(self, n_prompt: int) -> bool:
        return True

    def alloc(self, uid, slot, n_prompt):
        h = CacheHandle(uid=uid, slot=slot, n_tokens=n_prompt)
        self._handles[slot] = h
        self._live_tokens += n_prompt + 1
        self._peak_tokens = max(self._peak_tokens, self._live_tokens)
        return h

    def append(self, handle):
        handle.n_tokens += 1
        self._live_tokens += 1
        self._peak_tokens = max(self._peak_tokens, self._live_tokens)

    def free(self, handle):
        self._handles.pop(handle.slot, None)
        self._live_tokens -= handle.n_tokens + 1
        handle.pages = []

    def insert(self, handle, prefill_caches):
        self.caches = self._insert(self.caches, prefill_caches,
                                   jnp.asarray(handle.slot, jnp.int32))

    def memory_report(self) -> dict:
        return {
            "backend": self.name,
            "max_batch": self.max_batch,
            "max_len": self.max_len,
            "cache_bytes": self._bytes,
            "peak_cache_bytes": self._bytes,   # dense pins everything
            "live_tokens": self._live_tokens,
            "peak_live_tokens": self._peak_tokens,
            "gather_transient_bytes": 0,       # gather() is the resident tree
        }

    def reset(self):
        self._handles.clear()
        self._live_tokens = 0
        self._peak_tokens = 0


class PagedCache(CacheBackend):
    """Fixed-size page pool + per-request block tables.

    ``n_pages`` usable pages of ``page_size`` tokens each (plus the
    reserved null page 0).  Admission requires pages covering the prompt
    AND the first decode write, with ``reserve_pages`` extra free as the
    admission reservation; decode allocates lazily on page-boundary
    crossings via :meth:`append`.
    """

    name = "paged"

    def __init__(self, cfg, max_batch: int, max_len: int, *,
                 page_size: int = 16, n_pages: Optional[int] = None,
                 reserve_pages: int = 1):
        super().__init__(cfg, max_batch, max_len)
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if max_len % page_size:
            raise ValueError(
                f"page_size must divide max_len for dense-equivalent "
                f"attention views, got page_size={page_size} "
                f"max_len={max_len}")
        self.page_size = int(page_size)
        self.table_width = max_len // page_size
        if n_pages is None:        # dense-equivalent capacity
            n_pages = max_batch * self.table_width
        if n_pages < 1:
            raise ValueError(f"n_pages must be >= 1, got {n_pages}")
        self.n_pages = int(n_pages)
        self.reserve_pages = max(int(reserve_pages), 0)

        self.caches = lm.init_paged_caches(cfg, max_batch, self.page_size,
                                           self.n_pages)
        self._has_kv = any("kv" in c for c in self.caches.values())
        self._table = np.zeros((max_batch, self.table_width), np.int32)
        # device-resident copy of the block tables: uploaded once here,
        # then patched incrementally on admission / page allocation /
        # free -- decode steps reuse the SAME device array (no per-step
        # host->device sync; `table_host_uploads` counts full-row
        # uploads, which only happen at admission frequency)
        self._table_dev = jnp.asarray(self._table)
        self.table_host_uploads = 0
        self._free = collections.deque(range(1, self.n_pages + 1))
        self._withheld: list = []     # pages removed by shrink_pool()
        self._handles: dict[int, CacheHandle] = {}
        self._peak_pages = 0

        kv_tok = lm.kv_bytes_per_token(cfg)
        self.bytes_per_page = kv_tok * self.page_size
        self.ssm_slot_bytes = lm.ssm_bytes_per_slot(cfg)
        self.dense_equivalent_bytes = lm.dense_cache_bytes(
            cfg, max_batch, max_len)

        def ins_mamba(mstates, pstates, slot):
            return jax.tree.map(
                lambda big, small: _ins_slot(big, small, slot),
                mstates, pstates)

        self._insert_mamba = jax.jit(ins_mamba, donate_argnums=(0,))

    # -- page arithmetic ----------------------------------------------------
    def pages_for(self, n_tokens: int) -> int:
        if not self._has_kv:
            return 0               # pure-SSM: state is per-slot, no pages
        return -(-max(n_tokens, 0) // self.page_size)

    # -- admission contract -------------------------------------------------
    def _admission_pages(self, n_prompt: int) -> int:
        """Pages covering the prompt + the first decode write (clamped to
        the table width, mirroring :meth:`append`'s max_len clamp)."""
        return self.pages_for(min(n_prompt + 1, self.max_len))

    def can_admit(self, n_prompt: int) -> bool:
        need = self._admission_pages(n_prompt) + self.reserve_pages
        return len(self._free) >= need

    def check_feasible(self, n_prompt: int, max_tokens: int):
        total = min(n_prompt + max_tokens, self.max_len)
        need = self.pages_for(total) + self.reserve_pages
        if need > self.n_pages:
            raise ValueError(
                f"request needs {need} pages (prompt {n_prompt} + "
                f"max_tokens {max_tokens} + reserve {self.reserve_pages}) "
                f"but the pool only has {self.n_pages}; it could never be "
                f"admitted")

    def bind_metrics(self, registry):
        super().bind_metrics(registry)
        if self._metrics is not None:
            # pre-create so the series exists (at 0) even in runs that
            # never exhaust the pool
            self._metrics.counter(
                "serve_pool_exhausted_total",
                "Page-pool allocation failures (each triggers a "
                "preemption in the engine)").inc(0)
            self._gauge_pages()

    def _count_exhausted(self):
        if self._metrics is not None:
            self._metrics.counter("serve_pool_exhausted_total").inc()

    def _gauge_pages(self):
        if self._metrics is not None:
            self._metrics.gauge(
                "serve_pages_in_use",
                "Pages currently allocated out of the pool").set(
                self.pages_in_use)

    def alloc(self, uid, slot, n_prompt):
        n = self._admission_pages(n_prompt)
        if len(self._free) < n:
            self._count_exhausted()
            raise PoolExhausted(
                f"need {n} pages for uid {uid}, {len(self._free)} free")
        h = CacheHandle(uid=uid, slot=slot, n_tokens=n_prompt,
                        pages=[self._free.popleft() for _ in range(n)])
        self._table[slot] = 0
        self._table[slot, :n] = h.pages
        self._table_dev = _table_set_row(self._table_dev, slot,
                                         jnp.asarray(self._table[slot]))
        self.table_host_uploads += 1
        self._handles[slot] = h
        self._note_usage()
        return h

    def append(self, handle):
        # back the next write position BEFORE advancing the counter: a
        # PoolExhausted raise leaves the handle untouched, so the
        # engine's preempt-and-retry loop can safely call append again
        nxt = handle.n_tokens + 1       # next cache write position
        if nxt < self.max_len and self._has_kv:
            pg = nxt // self.page_size
            if pg >= len(handle.pages):
                if not self._free:
                    self._count_exhausted()
                    raise PoolExhausted(
                        f"uid {handle.uid} needs page {pg}, pool empty")
                phys = self._free.popleft()
                handle.pages.append(phys)
                self._table[handle.slot, pg] = phys
                self._table_dev = _table_set_entry(self._table_dev,
                                                   handle.slot, pg, phys)
                self._note_usage()
        handle.n_tokens += 1

    def free(self, handle):
        self._free.extend(handle.pages)
        handle.pages = []
        self._table[handle.slot] = 0
        self._table_dev = _table_clear_row(self._table_dev, handle.slot)
        self._handles.pop(handle.slot, None)
        self._gauge_pages()

    def _note_usage(self):
        self._peak_pages = max(self._peak_pages, self.pages_in_use)
        self._gauge_pages()

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - len(self._free) - len(self._withheld)

    def shrink_pool(self, n_pages: int) -> int:
        # withhold from the BACK of the free deque so page-id reuse
        # order for live traffic is unchanged until pressure actually
        # bites (determinism: same fault -> same allocation sequence)
        taken = 0
        while taken < int(n_pages) and self._free:
            self._withheld.append(self._free.pop())
            taken += 1
        self._gauge_pages()
        return taken

    def restore_pool(self) -> int:
        n = len(self._withheld)
        # restore in reverse so the free deque returns to its
        # pre-pressure ordering
        while self._withheld:
            self._free.append(self._withheld.pop())
        self._gauge_pages()
        return n

    # -- data movement ------------------------------------------------------
    def kv_caches(self):
        """The KV-pool subtree ``{layer: {"kv": {"k","v"}}}`` to hand to
        (and have donated by) the engine's paged prefill step; layers
        without attention are absent.  Empty for pure-SSM stacks.  After
        the step runs, the pools referenced here are dead (donated) until
        :meth:`insert` commits the step's outputs."""
        return {ln: {"kv": c["kv"]} for ln, c in self.caches.items()
                if "kv" in c}

    def insert(self, handle, prefill_caches):
        """Commit one admitted request's prefill state.

        KV leaves of ``prefill_caches`` are the page POOLS returned by
        the engine's paged prefill step -- the prompt K/V was already
        scattered into this request's pages inside the jit, with the old
        pools donated, so committing them is a pointer swap (no dense
        round-trip, no per-admission scatter dispatch).  SSM leaves are
        per-slot ``(nsb, 1, ...)`` prefill states, scattered into the
        slot's row of the state tree."""
        for lname, c in self.caches.items():
            pc = prefill_caches.get(lname) or {}
            if "kv" in c and "kv" in pc:
                c["kv"] = pc["kv"]
        m_big = {ln: c["mamba"] for ln, c in self.caches.items()
                 if "mamba" in c}
        if m_big:
            m_small = {ln: prefill_caches[ln]["mamba"] for ln in m_big}
            m_new = self._insert_mamba(m_big, m_small,
                                       jnp.asarray(handle.slot, jnp.int32))
            for ln, st in m_new.items():
                self.caches[ln]["mamba"] = st

    def device_tables(self):
        # the SAME device array across steps (it rides outside the
        # donated cache tree); only admission / page-boundary / free
        # events replace it, via the incremental jitted updaters above
        return self._table_dev

    # -- reporting ----------------------------------------------------------
    def memory_report(self) -> dict:
        in_use = self.pages_in_use
        slots = len(self._handles)
        return {
            "backend": self.name,
            "page_size": self.page_size,
            "n_pages": self.n_pages,
            "pages_in_use": in_use,
            "pages_free": len(self._free),
            "pages_withheld": len(self._withheld),
            "peak_pages_in_use": self._peak_pages,
            "bytes_per_page": self.bytes_per_page,
            "ssm_slot_bytes": self.ssm_slot_bytes,
            "cache_bytes_in_use": in_use * self.bytes_per_page
            + slots * self.ssm_slot_bytes,
            "peak_cache_bytes": self._peak_pages * self.bytes_per_page
            + self.max_batch * self.ssm_slot_bytes,
            "pool_bytes": (self.n_pages + 1) * self.bytes_per_page
            + self.max_batch * self.ssm_slot_bytes,
            "dense_equivalent_bytes": self.dense_equivalent_bytes,
            # decode reads the pool in place (paged-attention kernel /
            # bitwise-equivalent fallback view); no dense-width
            # (max_batch, max_len) KV transient is materialized per step
            "gather_transient_bytes": 0,
            "table_bytes": int(self._table_dev.size
                               * self._table_dev.dtype.itemsize),
            "table_host_uploads": self.table_host_uploads,
        }

    def reset(self):
        for h in list(self._handles.values()):
            self.free(h)
        self._table[:] = 0
        self._table_dev = jnp.asarray(self._table)
        self.table_host_uploads = 0
        self._free = collections.deque(range(1, self.n_pages + 1))
        self._withheld = []
        self._peak_pages = 0


def make_backend(kind: str, cfg, max_batch: int, max_len: int,
                 **kwargs) -> CacheBackend:
    """``kind``: "dense" | "paged" (kwargs: page_size, n_pages,
    reserve_pages)."""
    if kind == "dense":
        if kwargs:
            raise ValueError(f"DenseCache takes no options, got "
                             f"{sorted(kwargs)}")
        return DenseCache(cfg, max_batch, max_len)
    if kind == "paged":
        return PagedCache(cfg, max_batch, max_len, **kwargs)
    raise ValueError(f"unknown cache backend {kind!r} "
                     f"(expected 'dense' or 'paged')")
