"""Plan-driven serving stack.

Layers:
  * :class:`InferenceServer` -- the serving API.  Takes ``(cfg, params,
    plan)``; owns a continuous-batching scheduler (new requests are
    admitted into decode slots as others finish), a pluggable
    :class:`~repro.serve.cache.CacheBackend` (``cache="dense"`` keeps the
    historical dense slot buffers, ``cache="paged"`` virtualizes them
    behind a page pool + block tables so cache memory scales with live
    tokens), fused prefill (one full-sequence forward via
    ``launch.steps``, page-bucketed under paging), per-request
    :class:`SamplingParams` drawn **on device** inside the jitted decode
    step (Gumbel top-k, per-request fold_in'd keys; host fallback via
    ``sample_on_device=False``), and -- when a
    :class:`~repro.api.plan.CompressionPlan` is given -- end-to-end
    quantized decode: every planned projection is bound to a
    :class:`~repro.nn.quantized.PackedLinear` and served through
    ``mixed_precision_matmul`` inside the jitted forward.
  * :func:`apply_plan` -- binds a plan into an LM parameter tree.
  * export/apply of *discretized* layers (paper Fig. 3): per-layer packing
    shared with the in-forward path via ``repro.nn.quantized``.
  * :class:`ServeEngine` -- thin backward-compatible shim over
    :class:`InferenceServer` (greedy, all-at-once batch).

The cache-backend contract is *token-for-token invariance*: dense and
paged backends, solo and batched and streaming, with or without a plan,
all emit identical token streams -- the serving tests assert exactly
that.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attention import ops as paged_ops
from repro.launch import steps
from repro.models import lm
from repro.nn import quantized as nnq
from repro.obs import run_summary
from repro.serve import cache as cache_mod
from repro.serve.sampling import (SamplingParams, batch_need_top_k,
                                  make_rng, sample_token,
                                  sample_tokens_device)
from repro.serve.scheduler import Request, Scheduler, SlotState


# ---------------------------------------------------------------------------
# plan binding: CompressionPlan -> servable parameter tree
# ---------------------------------------------------------------------------

def apply_plan(cfg, params, plan, strict: bool = True):
    """Bind a :class:`CompressionPlan` into an LM parameter tree.

    Every plan group (see ``lm.serve_weight_groups`` for the naming) has
    its float projection replaced by a bit-packed
    :class:`~repro.nn.quantized.PackedLinear` built from the plan's
    recorded channel bits AND its stored Fig. 3 permutation, so a
    saved+loaded plan serves byte-identically to the in-memory one.

    Because packed buffer shapes differ per layer, the returned tree keeps
    ``blocks`` as a *tuple of per-super-block trees* (the forward unrolls
    instead of scanning).  Gammas are dropped; non-quantizable weights
    (MoE expert banks, routers, norms) are sliced per super-block and stay
    float.  ``strict=False`` leaves groups missing from the plan in float
    instead of raising.
    """
    tmpl = lm.abstract_params(cfg, mps_on=True)["blocks"]
    nsb = lm.n_superblocks(cfg)

    def build(tnode, pnode, path, j):
        if isinstance(pnode, dict):
            if (isinstance(tnode, dict) and "w" in tnode
                    and "gamma" in tnode and tnode["w"].ndim == 3):
                group = f"{path}.sb{j}"
                if group in plan.channel_bits:
                    w = np.asarray(pnode["w"], np.float32)[j]   # (K, N)
                    return {"w": nnq.PackedLinear.from_dense(
                        w, plan.channel_bits[group],
                        perm=plan.permutations[group])}
                if strict:
                    raise KeyError(
                        f"plan has no group {group!r} (plan groups: "
                        f"{len(plan.channel_bits)}; pass strict=False to "
                        f"serve unplanned projections in float)")
                return {"w": jnp.asarray(pnode["w"][j])}
            return {k: build(tnode.get(k) if isinstance(tnode, dict)
                             else None, v, f"{path}.{k}", j)
                    for k, v in pnode.items() if k != "gamma"}
        return pnode[j]          # stacked (nsb, ...) leaf -> this block's

    blocks_q = tuple(
        {lname: build(tmpl[lname], params["blocks"][lname],
                      f"blocks.{lname}", j)
         for lname in params["blocks"]}
        for j in range(nsb))
    out = dict(params)
    out["blocks"] = blocks_q
    return out


def synthetic_plan(cfg, params, bits: int | None = None, seed: int = 0,
                   pw=(0, 2, 4, 8)):
    """A deterministic demo/benchmark plan over the LM's plan groups:
    uniform ``bits`` everywhere, or (``bits=None``) a seeded random mix
    drawn from ``pw``.  Not searched -- useful for smoke tests, the
    ``--plan demo`` launcher mode and throughput benchmarks."""
    from repro.api.plan import CompressionPlan

    rng = np.random.default_rng(seed)
    # favour the higher precisions (linearly), light pruning mass on 0-bit
    weights_p = np.arange(1, len(pw) + 1, dtype=np.float64)
    p = weights_p / weights_p.sum()
    gamma = {}
    for grp, w in lm.serve_weight_groups(cfg, params).items():
        c = w.shape[0]
        if bits is None:
            gamma[grp] = rng.choice(pw, size=c, p=p).astype(np.int64)
        else:
            gamma[grp] = np.full((c,), int(bits), np.int64)
    assignment = {"gamma": gamma, "delta": {}, "alpha": {}}
    return CompressionPlan.from_assignment(
        assignment, pw, (8,), meta={"track": "lm", "arch": cfg.name,
                                    "synthetic": True,
                                    "bits": bits, "seed": seed})


# ---------------------------------------------------------------------------
# the serving API
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StepResult:
    """What one :meth:`InferenceServer.step` did.

    ``produced`` maps uid -> tokens generated so far, for every request
    that gained a token this step (admission token or decode token);
    ``idle`` means no decode ran (the engine jumped the clock to the
    next arrival, or had nothing at all to do).  ``nan`` means NaN
    logits were detected at the sampling host boundary: the step's
    tokens were DISCARDED (no stream advanced, nothing finished) and
    the caller should quarantine the server -- the fleet's failover
    path recovers the in-flight requests onto healthy replicas."""

    admitted: list
    produced: dict
    finished: list
    idle: bool = False
    nan: bool = False


class InferenceServer:
    """Plan-driven LM serving with continuous batching.

    ``plan=None`` serves float weights; a :class:`CompressionPlan` switches
    the whole decode path to quantized execution (see :func:`apply_plan`).
    ``cache="paged"`` swaps the dense per-slot KV buffers for a
    :class:`~repro.serve.cache.PagedCache` (page pool + block tables,
    memory-aware admission, preemption-to-queue on pool exhaustion) --
    token streams are identical on both backends.  Decoder-only
    token-frontend architectures only (enc-dec and vision/audio frontends
    need prompt-side encoders the request schema doesn't carry yet).
    """

    def __init__(self, cfg, params, plan=None, *, max_len: int = 512,
                 max_batch: int = 8, strict_plan: bool = True,
                 cache: str = "dense", page_size: int = 16,
                 pages: int | None = None, reserve_pages: int = 1,
                 sample_on_device: bool = True, obs=None):
        if cfg.is_encdec or cfg.frontend != "none":
            raise NotImplementedError(
                f"InferenceServer serves decoder-only token-frontend "
                f"architectures; got {cfg.name} (family={cfg.family}, "
                f"frontend={cfg.frontend})")
        self.cfg = cfg
        self.plan = plan
        self.max_len = int(max_len)
        self.max_batch = int(max_batch)
        self.params = params if plan is None else apply_plan(
            cfg, params, plan, strict=strict_plan)
        self.sample_on_device = bool(sample_on_device)
        self.stats: dict = {}

        kwargs = {} if cache == "dense" else {
            "page_size": page_size, "n_pages": pages,
            "reserve_pages": reserve_pages}
        self.backend = cache_mod.make_backend(cache, cfg, self.max_batch,
                                              self.max_len, **kwargs)
        # paged prefill writes the prompt's KV straight into the page
        # pool (no dense round-trip; see make_paged_prefill_step).
        # Attention-only stacks pad the prompt to a q-chunk boundary --
        # the coarser of one sublane tile (8) and the page bucket,
        # capped at PREFILL_Q -- one compile per (padded length, table
        # width), never prefilling past the page bucket the retired
        # dense path used; an SSM mixer's recurrent state would absorb
        # the padding, so SSM/hybrid archs prefill at exact length
        # (compiled per prompt length), still straight into the pool.
        # Pure-SSM stacks have no KV pages at all and take the dense
        # prefill step (per-slot state insert only).
        self._has_ssm = any(spec.mixer == "mamba"
                            for spec in lm.block_pattern(cfg))
        self._paged_kv = (self.backend.name == "paged"
                          and getattr(self.backend, "_has_kv", False))
        # labels of the last admission's prefill on
        # serve_prefill_tokens_total (set by _run_prefill)
        self._prefill_path = "dense"
        self._prefill_width = "dense"

        self._prefill = jax.jit(steps.make_prefill_step(cfg))
        # donate the cache tree: decode updates it in place instead of
        # copying the full pool buffers per token (no-op on CPU, where
        # XLA ignores donation).  The paged block tables ride OUTSIDE
        # the donated tree so the backend's device copy survives across
        # steps (None for the dense backend); `width` is the STATIC
        # live-page prefix this step attends over -- sliced inside the
        # jit, so it costs one compile per distinct width (bounded by
        # table_width) instead of any per-step work, and attention
        # scans only pages some slot actually wrote instead of max_len.
        def _live_tables(tables, width):
            if tables is None or width is None \
                    or width >= tables.shape[1]:
                return tables
            return jax.lax.slice_in_dim(tables, 0, width, axis=1)

        # paged prefill: the slot's block-table row is sliced ON DEVICE
        # from the backend's resident tables (slot is traced -- no
        # per-slot compile, no per-admission host upload beyond alloc's
        # incremental row patch) and narrowed to the static live width;
        # the kv pool tree is donated so the prompt scatter is in place
        _paged_prefill = steps.make_paged_prefill_step(cfg)

        def prefill_paged(p, tok, kv, tbl, slot, lens, width):
            row = jax.lax.dynamic_slice_in_dim(tbl, slot, 1, axis=0)
            return _paged_prefill(p, tok, kv, _live_tables(row, width),
                                  lens)

        self._prefill_paged = jax.jit(prefill_paged, donate_argnums=(2,),
                                      static_argnums=(6,))

        self._decode = jax.jit(
            lambda p, t, c, tbl, pos, width: lm.decode_step(
                cfg, p, t, c, pos, tables=_live_tables(tbl, width)),
            donate_argnums=(2,), static_argnums=(5,))

        vocab = cfg.vocab

        def decode_sample(params, tokens, caches, tables, pos, temps,
                          topks, seeds, uids, tidx, need_top_k, width):
            """One decode step + on-device batched sampling: only the
            (B,) sampled ids (plus the scalar NaN-guard flag) cross back
            to the host."""
            logits, caches = lm.decode_step(
                cfg, params, tokens, caches, pos,
                tables=_live_tables(tables, width))
            row = logits[:, -1, :vocab]
            next_tok = sample_tokens_device(
                row, temps, topks, seeds, uids, tidx,
                need_top_k=need_top_k)
            return next_tok, caches, jnp.isnan(row).any()

        self._decode_sample = jax.jit(decode_sample, donate_argnums=(2,),
                                      static_argnums=(10, 11))

        def decode_greedy(params, tokens, caches, tables, pos, width):
            """All-greedy fast path: plain argmax, no sort/Gumbel work."""
            logits, caches = lm.decode_step(
                cfg, params, tokens, caches, pos,
                tables=_live_tables(tables, width))
            row = logits[:, -1, :vocab].astype(jnp.float32)
            next_tok = jnp.argmax(row, axis=-1)
            return next_tok.astype(jnp.int32), caches, jnp.isnan(row).any()

        self._decode_greedy = jax.jit(decode_greedy, donate_argnums=(2,),
                                      static_argnums=(5,))
        # the NaN-guard flag rides back with the sampled id: a scalar
        # crossing an already-paid host boundary, so corrupted (e.g.
        # NaN-poisoned-plan) logits are caught before a garbage token
        # can enter a client stream
        self._sample = jax.jit(
            lambda lg, temps, topks, seeds, uids, tidx, need_top_k:
            (sample_tokens_device(lg[:, :vocab], temps, topks, seeds,
                                  uids, tidx, need_top_k=need_top_k),
             jnp.isnan(lg[:, :vocab]).any()),
            static_argnums=(6,))
        # per-step decode latency split: [gather_s, step_s, n_steps]
        self._step_timing = [0.0, 0.0, 0]
        # session state (see the "serving" section): None between runs
        self._sched = None
        self._now = 0
        self._n_steps = 0
        self._n_admitted = 0
        self._cancelled: dict = {}
        self._nan_detected = False
        self.obs = None
        self._reg = None
        self.attach_obs(obs)

    # ------------------------------------------------------- observability
    def attach_obs(self, obs):
        """Attach (or with ``obs=None`` detach) a
        :class:`repro.obs.Observability` bundle.  Instrumentation is
        host-side only -- the jitted closures are untouched, so this can
        be called on an already-warmed server without triggering
        recompiles (``benchmarks/serve_bench.py`` relies on that to
        measure obs overhead on identical compiled code)."""
        self.obs = obs
        reg = None
        if obs is not None and obs.registry.enabled:
            reg = obs.registry
        self._reg = reg
        self.backend.bind_metrics(reg)

    def metrics_snapshot(self) -> dict:
        """Current metrics + (when tracing) the last serve run's summary;
        ``{}`` when no Observability bundle is attached."""
        if self.obs is None:
            return {}
        self.backend.publish_metrics()
        out = {"metrics": (self.obs.registry.snapshot()
                           if self.obs.registry.enabled else {})}
        if self.obs.tracer is not None:
            out["summary"] = run_summary(self.obs.tracer,
                                         self.obs.registry)
        out["load"] = self.load_report()
        return out

    # ------------------------------------------------------- sampling glue
    def _sample_first(self, logits_last, st_req, uid, tidx, rng):
        """Sample from prefill logits (token index ``tidx`` of the
        request's stream): device path or host fallback."""
        if self.sample_on_device:
            sp = st_req.sampling
            tok, bad = self._sample(
                logits_last.astype(jnp.float32),
                jnp.asarray([sp.temperature], jnp.float32),
                jnp.asarray([sp.top_k], jnp.int32),
                jnp.asarray([sp.seed], jnp.int32),
                jnp.asarray([uid], jnp.int32),
                jnp.asarray([tidx], jnp.int32),
                0 < sp.top_k < self.cfg.vocab)
            if bool(np.asarray(bad)):
                self._flag_nan()
            return int(np.asarray(tok)[0])
        row = np.asarray(logits_last.astype(jnp.float32))[0]
        vrow = row[: self.cfg.vocab]
        if np.isnan(vrow).any():
            self._flag_nan()
            return 0        # untrusted step; never reaches `finished`
        return sample_token(vrow, st_req.sampling, rng)

    def _flag_nan(self):
        """Record a NaN detection at the sampling host boundary.  The
        flag makes the current step's tokens untrusted: ``step()``
        discards them and reports ``StepResult.nan``, and ``serve()``
        raises (a solo server has no failover path)."""
        self._nan_detected = True
        if self._reg is not None:
            self._reg.counter(
                "fault_nan_detected_total",
                "NaN logits detected at the sampling host boundary"
            ).inc()

    # ------------------------------------------------------------ serving
    #
    # The serving loop is a *session*: ``begin()`` opens one (resetting
    # the cache backend and per-run trace), ``submit()`` enqueues,
    # ``step()`` advances one admission+decode round, ``cancel()``
    # removes a request mid-flight, ``end()`` closes the session and
    # returns the finished streams.  ``serve()`` is the batch
    # convenience wrapping the four; the fleet drives sessions directly
    # so it can interleave arrivals, deadline scans and cancellations
    # with decode steps.

    def begin(self, requests=(), *, fresh_trace: bool = True):
        """Open a serving session (per-run trace reset, fresh scheduler,
        cache backend reset) and submit ``requests``.
        ``fresh_trace=False`` keeps the tracer's events and time origin
        -- the fleet's crash-restore path reopens a struck replica's
        session without erasing its crashed/recovered history."""
        tracer = self.obs.tracer if self.obs is not None else None
        if tracer is not None and fresh_trace:
            tracer.start()          # per-run trace; metrics cumulative
        self._sched = Scheduler(self.max_batch, self.max_len,
                                tracer=tracer)
        self.backend.reset()
        self._step_timing = [0.0, 0.0, 0]
        self._now = 0
        self._n_steps = 0
        self._n_admitted = 0
        self._cancelled: dict = {}   # uid -> (reason, tokens np.ndarray)
        self._nan_detected = False
        for r in requests:
            self.submit(r)
        return self

    def submit(self, request, *, front: bool = False, trace_extra=None):
        """Enqueue a request into the open session (feasibility-checked
        against the cache backend's admission contract).  ``front=True``
        enqueues at the front of the queue -- the fleet's failover path
        preserves FCFS seniority of recovered requests this way --
        and ``trace_extra`` keys ride on the ``enqueued`` trace event."""
        if self._sched is None:
            raise RuntimeError("no open session; call begin() first")
        self.backend.check_feasible(np.asarray(request.prompt).size,
                                    request.sampling.max_tokens)
        self._sched.submit(request, front=front, trace_extra=trace_extra)
        if self._reg is not None:
            self._reg.counter("serve_requests_total",
                              "Requests submitted to serve()").inc()

    @property
    def has_work(self) -> bool:
        return self._sched is not None and self._sched.has_work

    def _admit(self) -> list:
        """Admit every arrived request the backend has memory for;
        returns the admitted uids (in admission order)."""
        sched, backend = self._sched, self.backend
        reg, tracer = self._reg, (self.obs.tracer
                                  if self.obs is not None else None)
        admitted = []
        while True:
            adm = sched.pop_admissible(
                self._now, can_admit=lambda e: backend.can_admit(
                    e.tokens().size))
            if adm is None:
                break
            entry, slot = adm
            req = entry.request
            resumed = entry.resume is not None
            tokens_np = entry.tokens()
            handle = backend.alloc(req.uid, slot, tokens_np.size)
            if tracer is not None:
                tracer.event(req.uid, "admitted", n=tokens_np.size,
                             pages_held=len(handle.pages), slot=slot,
                             resumed=resumed)
            if reg is not None:
                reg.counter(
                    "serve_admissions_total",
                    "Requests admitted into a decode slot",
                    labels=("resumed",)).inc(
                    resumed="true" if resumed else "false")
            logits = self._run_prefill(backend, handle, tokens_np)
            if tracer is not None:
                tracer.event(req.uid, "prefilled", n=tokens_np.size,
                             pages_held=len(handle.pages), slot=slot)
            if reg is not None:
                # one series per (path, width) == one compiled prefill
                # variant on the paged path (width is a static argument
                # of the jit; "dense"/"dense" for the dense backend and
                # pure-SSM stacks)
                reg.counter("serve_prefill_tokens_total",
                            "Tokens run through prefill (resumes "
                            "re-prefill prompt + generated) by prefill "
                            "path and static live-table width",
                            labels=("path", "width")).inc(
                    int(tokens_np.size), path=self._prefill_path,
                    width=self._prefill_width)
            self._n_admitted += 1
            if entry.resume is None:
                rng = make_rng(req.sampling, req.uid)
                tok = self._sample_first(logits, req, req.uid, 0, rng)
                st = SlotState(request=req, slot=slot,
                               pos=int(tokens_np.size),
                               remaining=req.sampling.max_tokens - 1,
                               last_token=tok, out=[tok], rng=rng,
                               order=self._n_admitted, handle=handle)
            else:       # preempted request: continue its exact stream
                st = entry.resume
                tok = self._sample_first(logits, req, req.uid,
                                         len(st.out), st.rng)
                st.slot = slot
                st.pos = int(tokens_np.size)
                st.out.append(tok)
                st.last_token = tok
                st.remaining -= 1
                st.order = self._n_admitted
                st.handle = handle
            if tracer is not None:
                # first residency yields the request's first token;
                # a resume's admission token is a decode step of its
                # ongoing stream
                tracer.event(req.uid,
                             "decode" if resumed else "first_token",
                             n=len(st.out),
                             pages_held=len(handle.pages), slot=slot)
            sched.activate(slot, st)
            admitted.append(req.uid)
            # a NaN-flagged admission token is untrusted: leave the
            # request resident so the quarantine/recovery path can
            # strike it instead of letting garbage into `finished`
            if (st.remaining <= 0 or st.pos >= self.max_len) \
                    and not self._nan_detected:
                st.truncated = st.remaining > 0
                backend.free(handle)
                sched.complete(slot)
        return admitted

    def step(self) -> StepResult:
        """One admission + batched-decode round of the open session."""
        if self._sched is None:
            raise RuntimeError("no open session; call begin() first")
        sched, backend = self._sched, self.backend
        tracer = self.obs.tracer if self.obs is not None else None
        fin0 = len(sched.finished)
        admitted = self._admit()
        # every admission yields one token (sampled from the prefill
        # logits), so admitted uids are producers this step
        produced = {}
        for uid in admitted:
            st = sched.finished.get(uid) or next(
                (s for s in sched.active if s.request.uid == uid), None)
            if st is not None:
                produced[uid] = len(st.out)
        if self._nan_detected:
            # admission sampling tripped the NaN guard: nothing
            # completed (see _admit); surface and skip the decode
            return StepResult(admitted=admitted, produced=produced,
                              finished=list(sched.finished)[fin0:],
                              nan=True)

        active = sched.active
        idle = False
        if not active:
            nxt = sched.next_arrival
            if nxt is not None:
                self._now = max(self._now + 1, nxt)   # jump to arrival
            idle = True
        else:
            # one batched decode step over the active slots
            next_toks = self._decode_active(active)
            self._n_steps += 1
            if self._nan_detected:
                # discard the whole step's tokens: no stream advances,
                # nothing completes, the caller quarantines the server
                return StepResult(admitted=admitted, produced=produced,
                                  finished=list(sched.finished)[fin0:],
                                  nan=True)
            survivors = []
            for st in active:
                st.pos += 1
                tok = next_toks[st.slot]
                st.out.append(tok)
                st.last_token = tok
                st.remaining -= 1
                produced[st.request.uid] = len(st.out)
                if tracer is not None:
                    tracer.event(st.request.uid, "decode", n=len(st.out),
                                 pages_held=len(st.handle.pages),
                                 slot=st.slot)
                if st.remaining <= 0:
                    backend.free(st.handle)
                    sched.complete(st.slot)
                elif st.pos >= self.max_len:
                    st.truncated = True
                    backend.free(st.handle)
                    sched.complete(st.slot)
                else:
                    survivors.append(st)
            # page-backing AFTER every slot recorded its token: a
            # preemption victim then always requeues with its full
            # sampled stream (resume re-derives nothing)
            for st in survivors:
                if sched.slots[st.slot] is st:   # not already preempted
                    self._append_or_preempt(sched, backend, st)
            self._now += 1
        finished = list(sched.finished)[fin0:]
        return StepResult(admitted=admitted, produced=produced,
                          finished=finished, idle=idle)

    def cancel(self, uid: int, reason: str = "cancelled"):
        """Cancel a queued or in-flight request, freeing its cache pages
        immediately (``memory_report()`` returns to its pre-admission
        level).  ``reason`` is ``"cancelled"``, ``"timeout"``, or one of
        the fault terminals ``"crashed"``/``"quarantined"`` used by the
        fleet's failover path, and becomes the lifecycle terminal
        event.  Returns the tokens the request had generated so far
        (possibly empty), or None if the uid is not live in the
        session."""
        if reason not in ("cancelled", "timeout", "crashed",
                          "quarantined"):
            raise ValueError(f"cancel reason must be 'cancelled', "
                             f"'timeout', 'crashed' or 'quarantined', "
                             f"got {reason!r}")
        if self._sched is None:
            raise RuntimeError("no open session; call begin() first")
        sched = self._sched
        for st in sched.active:
            if st.request.uid == uid:
                self.backend.free(st.handle)   # before the event: the
                break                          # trace shows pages_held=0
        res = sched.cancel(uid, kind=reason)
        if res is None:
            return None
        where, obj = res
        if where == "pending":
            out = obj.resume.out if obj.resume is not None else []
        else:
            out = obj.out
        toks = np.asarray(out, np.int32)
        self._cancelled[uid] = (reason, toks)
        if self._reg is not None:
            self._reg.counter(
                "serve_cancelled_total",
                "Requests removed by cancel(), by reason",
                labels=("reason",)).inc(reason=reason)
        return toks

    def end(self) -> dict:
        """Close the session: final stats + metrics publish; returns
        ``{uid: np.ndarray(tokens)}`` for every finished request."""
        sched = self._sched
        if sched is None:
            raise RuntimeError("no open session; call begin() first")
        gather_s, step_s, timed = self._step_timing
        reasons = [r for r, _ in self._cancelled.values()]
        self.stats = {"decode_steps": self._n_steps,
                      "admitted": self._n_admitted,
                      "preemptions": sched.preemptions,
                      "generated": sum(len(s.out)
                                       for s in sched.finished.values()),
                      "cancelled": reasons.count("cancelled"),
                      "timeouts": reasons.count("timeout"),
                      # per-step decode latency split: assembling the
                      # step's inputs from the backend (gather + device
                      # tables) vs. running the jitted step itself
                      "gather_us_per_step": round(
                          gather_s / timed * 1e6, 2) if timed else 0.0,
                      "step_us_per_step": round(
                          step_s / timed * 1e6, 2) if timed else 0.0,
                      "memory": self.backend.memory_report()}
        self.backend.publish_metrics()
        out = {uid: np.asarray(s.out, np.int32)
               for uid, s in sched.finished.items()}
        self._sched = None
        return out

    def live_uids(self) -> list:
        """Every live (queued or resident) uid in FCFS seniority order;
        the fleet's failover path walks this to recover a crashed or
        quarantined replica's in-flight requests."""
        if self._sched is None:
            return []
        return self._sched.live_uids()

    def result(self, uid: int):
        """Finished tokens for ``uid`` in the open session, else None."""
        if self._sched is not None and uid in self._sched.finished:
            return np.asarray(self._sched.finished[uid].out, np.int32)
        return None

    @property
    def preemption_counts(self) -> dict:
        """uid -> times preempted, for the open session."""
        if self._sched is None:
            return {}
        return dict(self._sched.preempt_counts)

    def load_report(self) -> dict:
        """Queue/slot/page occupancy: what routers key off.  Cheap --
        pure host-side bookkeeping, no device sync."""
        if self._sched is not None:
            load = self._sched.load()
        else:
            load = {"queued": 0, "active": 0,
                    "queued_tokens": 0, "active_tokens": 0}
        load["pages_in_use"] = int(
            self.backend.memory_report().get("pages_in_use", 0))
        # decode-step progress counter: the fleet's health watchdog
        # compares successive readings to detect a stalled replica
        load["steps"] = self._n_steps
        return load

    def serve(self, requests) -> dict:
        """Run every request to completion with continuous batching.

        Requests whose ``arrival > 0`` join the queue at that decode step
        (streaming-arrivals mode); more requests than ``max_batch`` (or
        than the page pool can hold at once -- the backend's admission
        contract) simply queue for capacity.  Returns
        ``{uid: np.ndarray(tokens)}``.
        """
        self.begin(requests)
        while self.has_work:
            if self.step().nan:
                # a solo server has no failover path: refuse to loop on
                # poisoned logits (the fleet quarantines instead)
                self.end()
                raise RuntimeError(
                    "NaN logits detected at the sampling host boundary; "
                    "serving aborted (corrupted parameters or plan?)")
        return self.end()

    def _run_prefill(self, backend, handle, tokens_np):
        """Fused full-sequence prefill; insert KV/SSM into the backend.
        Paged KV stacks prefill straight into the page pool: the pool
        tree is donated into the jit, so the prompt's K/V lands in the
        request's pages in place -- no dense-shaped KV round-trip, no
        per-admission scatter dispatch.  Returns the (1, V_pad) logits
        of the last real prompt token."""
        s = int(tokens_np.size)
        # numpy operands go straight into the jit call (one C++-side
        # device put each) -- per-admission python-dispatched puts are
        # pure TTFT overhead
        if self._paged_kv:
            q = min(paged_ops.PREFILL_Q, max(8, backend.page_size))
            spad = s if self._has_ssm else -(-s // q) * q
            padded = np.zeros((1, spad), np.int32)
            padded[0, :s] = tokens_np
            width = min(-(-spad // backend.page_size),
                        backend.table_width)
            logits, pcaches = self._prefill_paged(
                self.params, {"tokens": padded},
                backend.kv_caches(), backend.device_tables(),
                np.int32(handle.slot), np.asarray([s], np.int32), width)
            self._prefill_path = "paged"
            self._prefill_width = str(width)
        else:
            logits, pcaches = self._prefill(
                self.params, {"tokens": tokens_np[None]})
            self._prefill_path = "dense"
            self._prefill_width = "dense"
        backend.insert(handle, pcaches)
        return logits[:, -1, :]

    def _live_width(self, active):
        """Live block-table width for this step: enough pages to cover
        the highest decode position in the batch.  Pages past it were
        never written by ANY slot -- the paged attention then scans the
        live prefix instead of the full ``max_len`` width (dense
        attention always pays the full width).  Each distinct width is
        one extra compile of the decode step, so widths are bucketed to
        at most 8 values per table (exact below 8 pages): a realistic
        max_len/page_size of 128 pages still compiles <= 8 variants,
        each at most table_width/8 pages wider than needed.
        """
        if self.backend.name != "paged":
            return None
        tw = self.backend.table_width
        need = max(st.pos for st in active) // self.backend.page_size + 1
        step = max(1, tw // 8)
        return min(tw, -(-need // step) * step)

    def _decode_active(self, active) -> dict:
        """One batched decode step; returns {slot: sampled token id}."""
        tokens = np.zeros((self.max_batch, 1), np.int32)
        pos = np.zeros((self.max_batch,), np.int32)
        for st in active:
            tokens[st.slot, 0] = st.last_token
            pos[st.slot] = st.pos
        t0 = time.perf_counter()
        caches = self.backend.gather()
        tables = self.backend.device_tables()
        width = self._live_width(active)
        t1 = time.perf_counter()
        step_end = None      # host-sampling path stamps the step's end
        path = "host"        # which decode callable ran (metrics label)
        try:                 # itself, excluding its python sample loop
            if self.sample_on_device and all(
                    st.request.sampling.greedy for st in active):
                # every active row is greedy: argmax decode, none of the
                # sort/Gumbel machinery (bit-identical to the full sampler)
                path = "greedy"
                next_tok, caches, bad = self._decode_greedy(
                    self.params, {"tokens": jnp.asarray(tokens)}, caches,
                    tables, jnp.asarray(pos), width)
                self.backend.commit(caches)
                if bool(np.asarray(bad)):
                    self._flag_nan()
                ids = np.asarray(next_tok)
                return {st.slot: int(ids[st.slot]) for st in active}
            if self.sample_on_device:
                path = "sample"
                temps = np.zeros(self.max_batch, np.float32)
                topks = np.zeros(self.max_batch, np.int32)
                seeds = np.zeros(self.max_batch, np.int32)
                uids = np.zeros(self.max_batch, np.int32)
                tidx = np.zeros(self.max_batch, np.int32)
                for st in active:
                    sp = st.request.sampling
                    temps[st.slot] = sp.temperature
                    topks[st.slot] = sp.top_k
                    seeds[st.slot] = sp.seed
                    uids[st.slot] = st.request.uid
                    tidx[st.slot] = len(st.out)
                # trace-time flag: rows that truncate need the full-vocab
                # sort; a pure-temperature batch skips it entirely
                need_top_k = batch_need_top_k(
                    [st.request.sampling for st in active],
                    self.cfg.vocab, self._reg)
                next_tok, caches, bad = self._decode_sample(
                    self.params, {"tokens": jnp.asarray(tokens)}, caches,
                    tables, jnp.asarray(pos), jnp.asarray(temps),
                    jnp.asarray(topks), jnp.asarray(seeds),
                    jnp.asarray(uids), jnp.asarray(tidx), need_top_k,
                    width)
                self.backend.commit(caches)
                if bool(np.asarray(bad)):
                    self._flag_nan()
                ids = np.asarray(next_tok)
                return {st.slot: int(ids[st.slot]) for st in active}
            logits, caches = self._decode(
                self.params, {"tokens": jnp.asarray(tokens)}, caches,
                tables, jnp.asarray(pos), width)
            self.backend.commit(caches)
            rows = np.asarray(logits.astype(jnp.float32))[:, -1,
                                                          : self.cfg.vocab]
            step_end = time.perf_counter()   # np.asarray synced the step
            if any(np.isnan(rows[st.slot]).any() for st in active):
                self._flag_nan()
                # don't sample from poisoned rows (the host sampler's
                # softmax would propagate the NaN); step() discards the
                # step's tokens anyway
                return {st.slot: 0 for st in active}
            return {st.slot: sample_token(rows[st.slot],
                                          st.request.sampling, st.rng)
                    for st in active}
        finally:
            t2 = step_end if step_end is not None else time.perf_counter()
            self._step_timing[0] += t1 - t0
            self._step_timing[1] += t2 - t1
            self._step_timing[2] += 1
            if self._reg is not None:
                # one series per (path, width) == one compiled decode
                # variant (width is a static argument of the jit)
                self._reg.counter(
                    "serve_decode_steps_total",
                    "Batched decode steps by decode path and static "
                    "live-table width",
                    labels=("path", "width")).inc(
                    path=path,
                    width="dense" if width is None else str(width))

    def _append_or_preempt(self, sched, backend, st):
        """Back the request's next cache write with storage; on pool
        exhaustion preempt the youngest-admitted active request (vLLM
        recompute-style) until the append succeeds or ``st`` itself was
        evicted."""
        while True:
            try:
                backend.append(st.handle)
                return
            except cache_mod.PoolExhausted:
                victim = max(sched.active, key=lambda s: s.order)
                backend.free(victim.handle)
                sched.preempt(victim.slot)
                if self._reg is not None:
                    self._reg.counter(
                        "serve_preemptions_total",
                        "Requests preempted back to the queue on pool "
                        "exhaustion").inc()
                if victim is st:
                    return

    def generate(self, prompts: np.ndarray, sampling=None,
                 n_tokens: int | None = None) -> np.ndarray:
        """Batch convenience: (B, S0) prompts -> (B, max_tokens) tokens.

        ``sampling`` is one :class:`SamplingParams` shared by every prompt
        or a per-prompt list; default greedy ``n_tokens`` continuation.
        """
        prompts = np.asarray(prompts, np.int32)
        b = prompts.shape[0]
        if sampling is None:
            sampling = SamplingParams(max_tokens=n_tokens or 16)
        per = list(sampling) if isinstance(sampling, (list, tuple)) \
            else [sampling] * b
        if len(per) != b:
            raise ValueError(f"got {len(per)} SamplingParams for "
                             f"{b} prompts")
        if len({sp.max_tokens for sp in per}) > 1:
            raise ValueError(
                "generate() stacks completions into one (B, max_tokens) "
                "array, so per-prompt max_tokens must match; use serve() "
                "for heterogeneous token budgets")
        reqs = [Request(uid=i, prompt=prompts[i], sampling=per[i])
                for i in range(b)]
        res = self.serve(reqs)
        return np.stack([res[i] for i in range(b)])


# ---------------------------------------------------------------------------
# legacy shim
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeEngine:
    """Deprecated thin shim over :class:`InferenceServer` (greedy,
    all-at-once batch).  New code should use InferenceServer directly."""

    cfg: object
    params: object
    max_len: int = 512

    def __post_init__(self):
        self._servers: dict[int, InferenceServer] = {}

    def generate(self, prompts: np.ndarray, n_tokens: int = 16):
        """prompts: (B, S0) int32. Greedy continuation of n_tokens."""
        b = int(np.asarray(prompts).shape[0])
        server = self._servers.get(b)
        if server is None:
            server = InferenceServer(self.cfg, self.params,
                                     max_len=self.max_len, max_batch=b)
            self._servers[b] = server
        return server.generate(prompts,
                               SamplingParams(max_tokens=n_tokens))


# ---------------------------------------------------------------------------
# quantized mixed-precision serving of a discretized layer (paper Fig. 3)
# ---------------------------------------------------------------------------

def export_mixed_precision_layer(w: np.ndarray, channel_bits: np.ndarray,
                                 perm: np.ndarray | None = None):
    """w: (C_out, C_in) float weights; channel_bits: (C_out,) in {0,2,4,8}.

    Returns (packed_layers, perm, kept) where packed_layers is
    [(bits, wq_packed, scales), ...] in ascending-bits order after the
    Fig. 3 reordering; pruned (0-bit) channels are dropped entirely (a
    fully-pruned layer packs to an empty list with ``kept == 0``).
    ``perm`` overrides the reorder permutation (e.g. the one recorded in a
    :class:`~repro.api.plan.CompressionPlan`); by default it is recomputed
    from ``channel_bits``.  Packing is shared with the in-forward
    :class:`~repro.nn.quantized.PackedLinear` path, so per-layer exports
    and plan-driven decode are byte-identical.
    """
    return nnq.pack_channelwise(w, channel_bits, perm=perm)


def mixed_precision_matmul(x: jax.Array, packed_layers) -> jax.Array:
    """Serve y = x @ W^T for a reordered mixed-precision layer: one
    quant_matmul per precision group, outputs concatenated (Fig. 3).
    Activations are int8-quantized per row (batch-invariant); an empty
    ``packed_layers`` returns a zero-width (M, 0) result."""
    return nnq.mixed_precision_matmul(x, packed_layers)


def export_plan_layers(plan, weights: dict) -> dict:
    """Export every layer of a :class:`CompressionPlan` for serving.

    ``weights`` maps gamma-group name -> (C_out, C_in) float matrix (conv
    kernels reshaped to 2-D; for the LM, ``lm.serve_weight_groups``).
    Uses the plan's recorded per-group channel bits AND its stored Fig. 3
    permutations, so a saved+loaded plan packs byte-identically to the
    in-memory one. Returns {group: (packed_layers, perm, kept)}.
    """
    out = {}
    for grp, w in weights.items():
        if grp not in plan.channel_bits:
            raise KeyError(f"group {grp!r} is not in the plan "
                           f"(groups: {sorted(plan.channel_bits)})")
        out[grp] = export_mixed_precision_layer(
            np.asarray(w), plan.channel_bits[grp],
            perm=plan.permutations[grp])
    return out
