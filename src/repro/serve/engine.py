"""Plan-driven serving stack.

Layers:
  * :class:`InferenceServer` -- the serving API.  Takes ``(cfg, params,
    plan)``; owns a continuous-batching scheduler (new requests are
    admitted into decode slots as others finish), fused prefill (one
    full-sequence forward via ``launch.steps.make_prefill_step`` instead of
    a per-token loop), per-request :class:`SamplingParams`, and -- when a
    :class:`~repro.api.plan.CompressionPlan` is given -- end-to-end
    quantized decode: every planned projection is bound to a
    :class:`~repro.nn.quantized.PackedLinear` and served through
    ``mixed_precision_matmul`` inside the jitted forward.
  * :func:`apply_plan` -- binds a plan into an LM parameter tree.
  * export/apply of *discretized* layers (paper Fig. 3): per-layer packing
    shared with the in-forward path via ``repro.nn.quantized``.
  * :class:`ServeEngine` -- thin backward-compatible shim over
    :class:`InferenceServer` (greedy, all-at-once batch).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import steps
from repro.models import lm
from repro.nn import quantized as nnq
from repro.serve.sampling import SamplingParams, make_rng, sample_token
from repro.serve.scheduler import Request, Scheduler, SlotState


# ---------------------------------------------------------------------------
# plan binding: CompressionPlan -> servable parameter tree
# ---------------------------------------------------------------------------

def apply_plan(cfg, params, plan, strict: bool = True):
    """Bind a :class:`CompressionPlan` into an LM parameter tree.

    Every plan group (see ``lm.serve_weight_groups`` for the naming) has
    its float projection replaced by a bit-packed
    :class:`~repro.nn.quantized.PackedLinear` built from the plan's
    recorded channel bits AND its stored Fig. 3 permutation, so a
    saved+loaded plan serves byte-identically to the in-memory one.

    Because packed buffer shapes differ per layer, the returned tree keeps
    ``blocks`` as a *tuple of per-super-block trees* (the forward unrolls
    instead of scanning).  Gammas are dropped; non-quantizable weights
    (MoE expert banks, routers, norms) are sliced per super-block and stay
    float.  ``strict=False`` leaves groups missing from the plan in float
    instead of raising.
    """
    tmpl = lm.abstract_params(cfg, mps_on=True)["blocks"]
    nsb = lm.n_superblocks(cfg)

    def build(tnode, pnode, path, j):
        if isinstance(pnode, dict):
            if (isinstance(tnode, dict) and "w" in tnode
                    and "gamma" in tnode and tnode["w"].ndim == 3):
                group = f"{path}.sb{j}"
                if group in plan.channel_bits:
                    w = np.asarray(pnode["w"], np.float32)[j]   # (K, N)
                    return {"w": nnq.PackedLinear.from_dense(
                        w, plan.channel_bits[group],
                        perm=plan.permutations[group])}
                if strict:
                    raise KeyError(
                        f"plan has no group {group!r} (plan groups: "
                        f"{len(plan.channel_bits)}; pass strict=False to "
                        f"serve unplanned projections in float)")
                return {"w": jnp.asarray(pnode["w"][j])}
            return {k: build(tnode.get(k) if isinstance(tnode, dict)
                             else None, v, f"{path}.{k}", j)
                    for k, v in pnode.items() if k != "gamma"}
        return pnode[j]          # stacked (nsb, ...) leaf -> this block's

    blocks_q = tuple(
        {lname: build(tmpl[lname], params["blocks"][lname],
                      f"blocks.{lname}", j)
         for lname in params["blocks"]}
        for j in range(nsb))
    out = dict(params)
    out["blocks"] = blocks_q
    return out


def synthetic_plan(cfg, params, bits: int | None = None, seed: int = 0,
                   pw=(0, 2, 4, 8)):
    """A deterministic demo/benchmark plan over the LM's plan groups:
    uniform ``bits`` everywhere, or (``bits=None``) a seeded random mix
    drawn from ``pw``.  Not searched -- useful for smoke tests, the
    ``--plan demo`` launcher mode and throughput benchmarks."""
    from repro.api.plan import CompressionPlan

    rng = np.random.default_rng(seed)
    # favour the higher precisions (linearly), light pruning mass on 0-bit
    weights_p = np.arange(1, len(pw) + 1, dtype=np.float64)
    p = weights_p / weights_p.sum()
    gamma = {}
    for grp, w in lm.serve_weight_groups(cfg, params).items():
        c = w.shape[0]
        if bits is None:
            gamma[grp] = rng.choice(pw, size=c, p=p).astype(np.int64)
        else:
            gamma[grp] = np.full((c,), int(bits), np.int64)
    assignment = {"gamma": gamma, "delta": {}, "alpha": {}}
    return CompressionPlan.from_assignment(
        assignment, pw, (8,), meta={"track": "lm", "arch": cfg.name,
                                    "synthetic": True,
                                    "bits": bits, "seed": seed})


# ---------------------------------------------------------------------------
# the serving API
# ---------------------------------------------------------------------------

class InferenceServer:
    """Plan-driven LM serving with continuous batching.

    ``plan=None`` serves float weights; a :class:`CompressionPlan` switches
    the whole decode path to quantized execution (see :func:`apply_plan`).
    Decoder-only token-frontend architectures only (enc-dec and
    vision/audio frontends need prompt-side encoders the request schema
    doesn't carry yet).
    """

    def __init__(self, cfg, params, plan=None, *, max_len: int = 512,
                 max_batch: int = 8, strict_plan: bool = True):
        if cfg.is_encdec or cfg.frontend != "none":
            raise NotImplementedError(
                f"InferenceServer serves decoder-only token-frontend "
                f"architectures; got {cfg.name} (family={cfg.family}, "
                f"frontend={cfg.frontend})")
        self.cfg = cfg
        self.plan = plan
        self.max_len = int(max_len)
        self.max_batch = int(max_batch)
        self.params = params if plan is None else apply_plan(
            cfg, params, plan, strict=strict_plan)
        self.stats: dict = {}

        prefill_step = steps.make_prefill_step(cfg)

        def prefill_insert(params, tokens, caches, slot):
            """Fused prefill of one request + KV/SSM insertion into its
            decode slot (compiled once per distinct prompt length)."""
            logits, pcaches = prefill_step(params, {"tokens": tokens})

            def ins(big, small):
                small = small.astype(big.dtype)
                starts = (0, slot) + (0,) * (big.ndim - 2)
                return jax.lax.dynamic_update_slice(big, small, starts)

            return logits, jax.tree.map(ins, caches, pcaches)

        # donate the cache tree: decode updates it in place instead of
        # copying the full (nsb, max_batch, max_len, ...) buffers per
        # token (no-op on CPU, where XLA ignores donation)
        self._prefill_insert = jax.jit(prefill_insert, donate_argnums=(2,))
        self._decode = jax.jit(
            lambda p, t, c, pos: lm.decode_step(cfg, p, t, c, pos),
            donate_argnums=(2,))

    # ------------------------------------------------------------ serving
    def serve(self, requests) -> dict:
        """Run every request to completion with continuous batching.

        Requests whose ``arrival > 0`` join the queue at that decode step
        (streaming-arrivals mode); more requests than ``max_batch`` simply
        queue for free slots.  Returns ``{uid: np.ndarray(tokens)}``.
        """
        sched = Scheduler(self.max_batch, self.max_len)
        for r in requests:
            sched.submit(r)
        caches = lm.init_caches(self.cfg, self.max_batch, self.max_len)
        vocab = self.cfg.vocab
        now = 0
        n_steps = n_admitted = 0

        while sched.has_work:
            # admit every arrived request that fits a free slot
            while True:
                adm = sched.pop_admissible(now)
                if adm is None:
                    break
                req, slot = adm
                tokens = jnp.asarray(np.asarray(req.prompt, np.int32))[None]
                logits, caches = self._prefill_insert(
                    self.params, tokens, caches,
                    jnp.asarray(slot, jnp.int32))
                row = np.asarray(logits.astype(jnp.float32))[0, -1, :vocab]
                rng = make_rng(req.sampling, req.uid)
                tok = sample_token(row, req.sampling, rng)
                st = SlotState(request=req, slot=slot,
                               pos=int(np.asarray(req.prompt).size),
                               remaining=req.sampling.max_tokens - 1,
                               last_token=tok, out=[tok], rng=rng)
                n_admitted += 1
                sched.activate(slot, st)
                if st.remaining <= 0:
                    sched.complete(slot)

            active = sched.active
            if not active:
                nxt = sched.next_arrival
                if nxt is None:
                    break
                now = max(now + 1, nxt)   # idle: jump to the next arrival
                continue

            # one batched decode step over the active slots
            tokens = np.zeros((self.max_batch, 1), np.int32)
            pos = np.zeros((self.max_batch,), np.int32)
            for st in active:
                tokens[st.slot, 0] = st.last_token
                pos[st.slot] = st.pos
            logits, caches = self._decode(
                self.params, {"tokens": jnp.asarray(tokens)}, caches,
                jnp.asarray(pos))
            rows = np.asarray(logits.astype(jnp.float32))[:, -1, :vocab]
            n_steps += 1
            for st in active:
                st.pos += 1
                tok = sample_token(rows[st.slot], st.request.sampling,
                                   st.rng)
                st.out.append(tok)
                st.last_token = tok
                st.remaining -= 1
                if st.remaining <= 0:
                    sched.complete(st.slot)
                elif st.pos >= self.max_len:
                    st.truncated = True
                    sched.complete(st.slot)
            now += 1

        self.stats = {"decode_steps": n_steps, "admitted": n_admitted,
                      "generated": sum(len(s.out)
                                       for s in sched.finished.values())}
        return {uid: np.asarray(s.out, np.int32)
                for uid, s in sched.finished.items()}

    def generate(self, prompts: np.ndarray, sampling=None,
                 n_tokens: int | None = None) -> np.ndarray:
        """Batch convenience: (B, S0) prompts -> (B, max_tokens) tokens.

        ``sampling`` is one :class:`SamplingParams` shared by every prompt
        or a per-prompt list; default greedy ``n_tokens`` continuation.
        """
        prompts = np.asarray(prompts, np.int32)
        b = prompts.shape[0]
        if sampling is None:
            sampling = SamplingParams(max_tokens=n_tokens or 16)
        per = list(sampling) if isinstance(sampling, (list, tuple)) \
            else [sampling] * b
        if len(per) != b:
            raise ValueError(f"got {len(per)} SamplingParams for "
                             f"{b} prompts")
        if len({sp.max_tokens for sp in per}) > 1:
            raise ValueError(
                "generate() stacks completions into one (B, max_tokens) "
                "array, so per-prompt max_tokens must match; use serve() "
                "for heterogeneous token budgets")
        reqs = [Request(uid=i, prompt=prompts[i], sampling=per[i])
                for i in range(b)]
        res = self.serve(reqs)
        return np.stack([res[i] for i in range(b)])


# ---------------------------------------------------------------------------
# legacy shim
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeEngine:
    """Deprecated thin shim over :class:`InferenceServer` (greedy,
    all-at-once batch).  New code should use InferenceServer directly."""

    cfg: object
    params: object
    max_len: int = 512

    def __post_init__(self):
        self._servers: dict[int, InferenceServer] = {}

    def generate(self, prompts: np.ndarray, n_tokens: int = 16):
        """prompts: (B, S0) int32. Greedy continuation of n_tokens."""
        b = int(np.asarray(prompts).shape[0])
        server = self._servers.get(b)
        if server is None:
            server = InferenceServer(self.cfg, self.params,
                                     max_len=self.max_len, max_batch=b)
            self._servers[b] = server
        return server.generate(prompts,
                               SamplingParams(max_tokens=n_tokens))


# ---------------------------------------------------------------------------
# quantized mixed-precision serving of a discretized layer (paper Fig. 3)
# ---------------------------------------------------------------------------

def export_mixed_precision_layer(w: np.ndarray, channel_bits: np.ndarray,
                                 perm: np.ndarray | None = None):
    """w: (C_out, C_in) float weights; channel_bits: (C_out,) in {0,2,4,8}.

    Returns (packed_layers, perm, kept) where packed_layers is
    [(bits, wq_packed, scales), ...] in ascending-bits order after the
    Fig. 3 reordering; pruned (0-bit) channels are dropped entirely (a
    fully-pruned layer packs to an empty list with ``kept == 0``).
    ``perm`` overrides the reorder permutation (e.g. the one recorded in a
    :class:`~repro.api.plan.CompressionPlan`); by default it is recomputed
    from ``channel_bits``.  Packing is shared with the in-forward
    :class:`~repro.nn.quantized.PackedLinear` path, so per-layer exports
    and plan-driven decode are byte-identical.
    """
    return nnq.pack_channelwise(w, channel_bits, perm=perm)


def mixed_precision_matmul(x: jax.Array, packed_layers) -> jax.Array:
    """Serve y = x @ W^T for a reordered mixed-precision layer: one
    quant_matmul per precision group, outputs concatenated (Fig. 3).
    Activations are int8-quantized per row (batch-invariant); an empty
    ``packed_layers`` returns a zero-width (M, 0) result."""
    return nnq.mixed_precision_matmul(x, packed_layers)


def export_plan_layers(plan, weights: dict) -> dict:
    """Export every layer of a :class:`CompressionPlan` for serving.

    ``weights`` maps gamma-group name -> (C_out, C_in) float matrix (conv
    kernels reshaped to 2-D; for the LM, ``lm.serve_weight_groups``).
    Uses the plan's recorded per-group channel bits AND its stored Fig. 3
    permutations, so a saved+loaded plan packs byte-identically to the
    in-memory one. Returns {group: (packed_layers, perm, kept)}.
    """
    out = {}
    for grp, w in weights.items():
        if grp not in plan.channel_bits:
            raise KeyError(f"group {grp!r} is not in the plan "
                           f"(groups: {sorted(plan.channel_bits)})")
        out[grp] = export_mixed_precision_layer(
            np.asarray(w), plan.channel_bits[grp],
            perm=plan.permutations[grp])
    return out
