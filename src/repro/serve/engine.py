"""Batched serving engine + mixed-precision quantized-weight serving.

Two layers:
  * ServeEngine -- prefill + step-by-step batched decode for any LM arch
    (greedy sampling), KV caches managed per request batch.
  * export/apply of *discretized* layers (paper Fig. 3): after the search
    assigns per-channel precisions, weights are reordered into contiguous
    per-precision groups, bit-packed, and served through the quant_matmul
    kernel (TPU) / oracle (CPU).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import discretize, quantizers
from repro.kernels.quant_matmul import ops as qops
from repro.models import lm


@dataclasses.dataclass
class ServeEngine:
    cfg: object
    params: object
    max_len: int = 512

    def __post_init__(self):
        self._decode = jax.jit(
            lambda p, t, c, pos: lm.decode_step(self.cfg, p, t, c, pos))

    def generate(self, prompts: np.ndarray, n_tokens: int = 16):
        """prompts: (B, S0) int32. Greedy continuation of n_tokens."""
        b, s0 = prompts.shape
        caches = lm.init_caches(self.cfg, b, self.max_len)
        # prefill by stepping (simple + exact; a fused prefill exists in
        # launch/steps.py for the dry-run path)
        logits = None
        for i in range(s0):
            tok = {"tokens": jnp.asarray(prompts[:, i:i + 1])}
            logits, caches = self._decode(self.params, tok, caches,
                                          jnp.asarray(i))
        out = []
        cur = jnp.argmax(logits[:, -1, : self.cfg.vocab], -1)[:, None]
        for i in range(n_tokens):
            out.append(np.asarray(cur))
            logits, caches = self._decode(
                self.params, {"tokens": cur}, caches,
                jnp.asarray(s0 + i))
            cur = jnp.argmax(logits[:, -1, : self.cfg.vocab], -1)[:, None]
        return np.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# quantized mixed-precision serving of a discretized layer (paper Fig. 3)
# ---------------------------------------------------------------------------

def export_mixed_precision_layer(w: np.ndarray, channel_bits: np.ndarray,
                                 perm: np.ndarray | None = None):
    """w: (C_out, C_in) float weights; channel_bits: (C_out,) in {0,2,4,8}.

    Returns (packed_layers, perm, kept) where packed_layers is
    [(bits, wq_packed, scales), ...] in ascending-bits order after the
    Fig. 3 reordering; pruned (0-bit) channels are dropped entirely.
    ``perm`` overrides the reorder permutation (e.g. the one recorded in a
    :class:`~repro.api.plan.CompressionPlan`); by default it is recomputed
    from ``channel_bits``.
    """
    if perm is None:
        perm = discretize.reorder_permutations(
            {"gamma": {"l": channel_bits}})["l"]
    w_sorted = np.asarray(w)[perm]
    bits_sorted = np.asarray(channel_bits)[perm]
    packed = []
    for b in sorted(set(int(x) for x in bits_sorted if x > 0)):
        rows = w_sorted[bits_sorted == b]
        qi, scale = quantizers.integerize_weights(jnp.asarray(rows), b, 0)
        k = rows.shape[1]
        per = 8 // b
        pad = (-k) % per
        qi_np = np.asarray(qi)
        if pad:
            qi_np = np.pad(qi_np, ((0, 0), (0, pad)))
        packed.append((b, jnp.asarray(qops.pack_weights(qi_np, b)),
                       jnp.asarray(scale[:, 0])))
    kept = int(np.sum(bits_sorted > 0))
    return packed, perm, kept


def mixed_precision_matmul(x: jax.Array, packed_layers) -> jax.Array:
    """Serve y = x @ W^T for a reordered mixed-precision layer: one
    quant_matmul per precision group, outputs concatenated (Fig. 3)."""
    xq, sx = qops.quantize_activations(x)
    outs = []
    for bits, wq, sw in packed_layers:
        outs.append(qops.quant_matmul(xq, wq, sw, sx, w_bits=bits))
    return jnp.concatenate(outs, axis=-1)


def export_plan_layers(plan, weights: dict) -> dict:
    """Export every layer of a :class:`CompressionPlan` for serving.

    ``weights`` maps gamma-group name -> (C_out, C_in) float matrix (conv
    kernels reshaped to 2-D). Uses the plan's recorded per-group channel
    bits AND its stored Fig. 3 permutations, so a saved+loaded plan packs
    byte-identically to the in-memory one. Returns
    {group: (packed_layers, perm, kept)}.
    """
    out = {}
    for grp, w in weights.items():
        if grp not in plan.channel_bits:
            raise KeyError(f"group {grp!r} is not in the plan "
                           f"(groups: {sorted(plan.channel_bits)})")
        out[grp] = export_mixed_precision_layer(
            np.asarray(w), plan.channel_bits[grp],
            perm=plan.permutations[grp])
    return out
