"""Continuous-batching scheduler: pure bookkeeping, model-agnostic.

The scheduler owns the request queue and the fixed pool of decode slots.
The :class:`~repro.serve.engine.InferenceServer` drives it: every decode
step it first admits pending requests into free slots (the engine prefills
each admitted request and writes its caches into the cache backend), then
runs one batched decode step over the active slots and retires the ones
that finished.  Requests may arrive over time (``Request.arrival`` in
decode steps) -- the streaming-arrivals serving mode -- and more requests
than slots simply queue.

Admission is **memory-aware**: ``pop_admissible`` takes a ``can_admit``
predicate (the cache backend's admission contract -- "do I have pages for
this prompt plus a reservation?").  Admission is strictly FCFS: a
memory-blocked head of queue blocks later requests rather than being
skipped, so big requests cannot starve.  When the pool runs dry
mid-decode the engine **preempts** a running request back to the FRONT of
the queue (:meth:`Scheduler.preempt`); its generated-so-far tokens and
sampling stream travel with it, and re-admission re-prefills
``prompt + generated`` -- exactly the computation the decode loop would
have run, so preemption never changes a request's token stream.

Keeping this free of any jax/model state makes admission, arrival gating,
preemption and slot reuse unit-testable in isolation.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import numpy as np

from repro.serve.sampling import SamplingParams


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request."""

    uid: int
    prompt: np.ndarray                 # (S0,) int32 token ids
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    arrival: int = 0                   # decode step at which it arrives


@dataclasses.dataclass
class SlotState:
    """Per-slot decode state of an admitted request."""

    request: Request
    slot: int
    pos: int                           # next cache write position
    remaining: int                     # tokens still to sample
    last_token: int
    out: list
    rng: np.random.Generator           # host-fallback sampling stream
    truncated: bool = False
    order: int = 0                     # admission sequence (preemption
    #                                    picks the youngest victim)
    handle: object = None              # CacheHandle of the cache backend


@dataclasses.dataclass
class PendingEntry:
    """A queued request; ``resume`` carries the state of a preempted one."""

    request: Request
    resume: Optional[SlotState] = None

    @property
    def arrival(self) -> int:
        return 0 if self.resume is not None else self.request.arrival

    def tokens(self) -> np.ndarray:
        """What prefill runs on admission: the prompt, extended by the
        already-generated tokens for a preempted request (recompute-style
        resume)."""
        prompt = np.asarray(self.request.prompt, np.int32)
        if self.resume is None:
            return prompt
        return np.concatenate(
            [prompt, np.asarray(self.resume.out, np.int32)])


class Scheduler:
    """Admission + slot lifecycle for a ``max_batch``-slot decode pool.

    ``tracer`` (a :class:`repro.obs.RequestTracer` or None) receives the
    queue-side lifecycle events -- ``enqueued`` / ``preempted`` /
    ``finished``; the engine records the residency-side ones (admitted,
    prefilled, tokens) because only it knows prefill and cache timing.
    """

    def __init__(self, max_batch: int, max_len: int, tracer=None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_len < 2:
            raise ValueError(f"max_len must be >= 2, got {max_len}")
        self.max_batch = max_batch
        self.max_len = max_len
        self.tracer = tracer
        self.slots: list[Optional[SlotState]] = [None] * max_batch
        self.pending: collections.deque[PendingEntry] = collections.deque()
        self.finished: dict[int, SlotState] = {}
        self.preemptions = 0
        self.preempt_counts: dict[int, int] = {}   # uid -> times preempted

    # ------------------------------------------------------------- submit
    def submit(self, request: Request, *, front: bool = False,
               trace_extra: Optional[dict] = None):
        """Queue a request.  ``front=True`` enqueues at the FRONT of the
        queue -- the fleet's failover path uses it so requests recovered
        from a crashed replica keep their FCFS seniority on the
        survivor.  ``trace_extra`` keys are merged into the ``enqueued``
        lifecycle event (the fleet surfaces retry backoff delays and
        failover causes this way)."""
        prompt = np.asarray(request.prompt)
        if prompt.ndim != 1 or prompt.size < 1:
            raise ValueError(f"request {request.uid}: prompt must be a "
                             f"non-empty 1-D token array, got shape "
                             f"{prompt.shape}")
        need = prompt.size + request.sampling.max_tokens
        if need > self.max_len:
            raise ValueError(
                f"request {request.uid}: prompt ({prompt.size}) + "
                f"max_tokens ({request.sampling.max_tokens}) exceeds "
                f"max_len ({self.max_len})")
        if request.uid in self.finished or any(
                s is not None and s.request.uid == request.uid
                for s in self.slots) or any(
                e.request.uid == request.uid for e in self.pending):
            raise ValueError(f"duplicate request uid {request.uid}")
        entry = PendingEntry(request)
        if front:
            self.pending.appendleft(entry)
        else:
            self.pending.append(entry)
        if self.tracer is not None:
            self.tracer.event(request.uid, "enqueued",
                              n=int(prompt.size),
                              arrival=int(request.arrival),
                              **(trace_extra or {}))

    # ---------------------------------------------------------- admission
    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def pop_admissible(self, now: int, can_admit=None):
        """Next ``(entry, slot)`` admissible at decode step ``now``, or
        None.  FIFO among arrived requests; ``can_admit(entry)`` is the
        cache backend's memory gate -- a blocked head of queue blocks the
        queue (strict FCFS, no skip-ahead starvation)."""
        slot = self.free_slot()
        if slot is None:
            return None
        for i, entry in enumerate(self.pending):
            if entry.arrival > now:
                continue
            if can_admit is not None and not can_admit(entry):
                return None            # memory-blocked head: wait
            del self.pending[i]
            return entry, slot
        return None

    def activate(self, slot: int, state: SlotState):
        assert self.slots[slot] is None, f"slot {slot} is busy"
        self.slots[slot] = state

    def complete(self, slot: int):
        state = self.slots[slot]
        assert state is not None, f"slot {slot} is empty"
        self.finished[state.request.uid] = state
        self.slots[slot] = None
        if self.tracer is not None:
            # the engine frees the cache handle before completing, so
            # pages_held is truthfully 0 here
            self.tracer.event(state.request.uid, "finished",
                              n=len(state.out), pages_held=0, slot=slot,
                              truncated=bool(state.truncated))

    def preempt(self, slot: int) -> SlotState:
        """Evict a running request back to the FRONT of the queue.  Among
        successive preemptions the older request ends up ahead (each
        younger victim was pushed first), preserving FCFS on resume."""
        state = self.slots[slot]
        assert state is not None, f"slot {slot} is empty"
        self.slots[slot] = None
        self.pending.appendleft(PendingEntry(state.request, resume=state))
        self.preemptions += 1
        uid = state.request.uid
        self.preempt_counts[uid] = self.preempt_counts.get(uid, 0) + 1
        if self.tracer is not None:
            # the engine frees the victim's pages before preempting
            self.tracer.event(uid, "preempted",
                              n=len(state.out), pages_held=0, slot=slot)
        return state

    def cancel(self, uid: int, kind: str = "cancelled"):
        """Remove a queued or in-flight request.

        Returns ``("pending", entry)`` if it was waiting in the queue,
        ``("active", state)`` if it occupied a decode slot (the caller
        -- the engine -- must have freed its cache handle already), or
        None if the uid is not live.  Emits a ``kind`` lifecycle event
        (``cancelled``/``timeout``, or the fault terminals ``crashed``/
        ``quarantined`` used by the fleet's failover path)."""
        if kind not in ("cancelled", "timeout", "crashed", "quarantined"):
            raise ValueError(f"cancel kind must be 'cancelled', "
                             f"'timeout', 'crashed' or 'quarantined', "
                             f"got {kind!r}")
        for i, entry in enumerate(self.pending):
            if entry.request.uid == uid:
                del self.pending[i]
                out = entry.resume.out if entry.resume is not None else []
                if self.tracer is not None:
                    self.tracer.event(uid, kind, n=len(out), pages_held=0)
                return "pending", entry
        for slot, state in enumerate(self.slots):
            if state is not None and state.request.uid == uid:
                self.slots[slot] = None
                if self.tracer is not None:
                    self.tracer.event(uid, kind, n=len(state.out),
                                      pages_held=0, slot=slot)
                return "active", state
        return None

    def live_uids(self) -> list[int]:
        """Every live uid in FCFS seniority order: active slots by
        admission order first, then the pending queue front-to-back.
        The fleet's crash-recovery path walks this order so re-enqueues
        onto a survivor preserve seniority."""
        actives = sorted(self.active, key=lambda s: s.order)
        return ([s.request.uid for s in actives]
                + [e.request.uid for e in self.pending])

    # ------------------------------------------------------------ queries
    @property
    def active(self) -> list[SlotState]:
        return [s for s in self.slots if s is not None]

    @property
    def has_work(self) -> bool:
        return bool(self.pending) or any(s is not None for s in self.slots)

    @property
    def next_arrival(self) -> Optional[int]:
        if not self.pending:
            return None
        return min(e.arrival for e in self.pending)

    def load(self) -> dict:
        """Queue/slot occupancy snapshot for routers and autoscalers.

        ``*_tokens`` counts tokens still to generate, the unit the
        fleet's queue-wait predictor works in."""
        queued_tokens = 0
        for e in self.pending:
            if e.resume is not None:
                queued_tokens += int(e.resume.remaining)
            else:
                queued_tokens += int(e.request.sampling.max_tokens)
        return {
            "queued": len(self.pending),
            "active": len(self.active),
            "queued_tokens": queued_tokens,
            "active_tokens": sum(int(s.remaining) for s in self.active),
        }
