"""Continuous-batching scheduler: pure bookkeeping, model-agnostic.

The scheduler owns the request queue and the fixed pool of decode slots.
The :class:`~repro.serve.engine.InferenceServer` drives it: every decode
step it first admits pending requests into free slots (the engine prefills
each admitted request and writes its caches into the slot), then runs one
batched decode step over the active slots and retires the ones that
finished.  Requests may arrive over time (``Request.arrival`` in decode
steps) -- the streaming-arrivals serving mode -- and more requests than
slots simply queue.

Keeping this free of any jax/model state makes admission, arrival gating
and slot reuse unit-testable in isolation.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import numpy as np

from repro.serve.sampling import SamplingParams


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request."""

    uid: int
    prompt: np.ndarray                 # (S0,) int32 token ids
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    arrival: int = 0                   # decode step at which it arrives


@dataclasses.dataclass
class SlotState:
    """Per-slot decode state of an admitted request."""

    request: Request
    slot: int
    pos: int                           # next cache write position
    remaining: int                     # tokens still to sample
    last_token: int
    out: list
    rng: np.random.Generator
    truncated: bool = False


class Scheduler:
    """Admission + slot lifecycle for a ``max_batch``-slot decode pool."""

    def __init__(self, max_batch: int, max_len: int):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_len < 2:
            raise ValueError(f"max_len must be >= 2, got {max_len}")
        self.max_batch = max_batch
        self.max_len = max_len
        self.slots: list[Optional[SlotState]] = [None] * max_batch
        self.pending: collections.deque[Request] = collections.deque()
        self.finished: dict[int, SlotState] = {}

    # ------------------------------------------------------------- submit
    def submit(self, request: Request):
        prompt = np.asarray(request.prompt)
        if prompt.ndim != 1 or prompt.size < 1:
            raise ValueError(f"request {request.uid}: prompt must be a "
                             f"non-empty 1-D token array, got shape "
                             f"{prompt.shape}")
        need = prompt.size + request.sampling.max_tokens
        if need > self.max_len:
            raise ValueError(
                f"request {request.uid}: prompt ({prompt.size}) + "
                f"max_tokens ({request.sampling.max_tokens}) exceeds "
                f"max_len ({self.max_len})")
        if request.uid in self.finished or any(
                s is not None and s.request.uid == request.uid
                for s in self.slots) or any(
                r.uid == request.uid for r in self.pending):
            raise ValueError(f"duplicate request uid {request.uid}")
        self.pending.append(request)

    # ---------------------------------------------------------- admission
    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def pop_admissible(self, now: int):
        """Next (request, slot) admissible at decode step ``now`` (FIFO
        among arrived requests), or None."""
        slot = self.free_slot()
        if slot is None:
            return None
        for i, req in enumerate(self.pending):
            if req.arrival <= now:
                del self.pending[i]
                return req, slot
        return None

    def activate(self, slot: int, state: SlotState):
        assert self.slots[slot] is None, f"slot {slot} is busy"
        self.slots[slot] = state

    def complete(self, slot: int):
        state = self.slots[slot]
        assert state is not None, f"slot {slot} is empty"
        self.finished[state.request.uid] = state
        self.slots[slot] = None

    # ------------------------------------------------------------ queries
    @property
    def active(self) -> list[SlotState]:
        return [s for s in self.slots if s is not None]

    @property
    def has_work(self) -> bool:
        return bool(self.pending) or any(s is not None for s in self.slots)

    @property
    def next_arrival(self) -> Optional[int]:
        if not self.pending:
            return None
        return min(r.arrival for r in self.pending)
