"""Logical-axis sharding: one place that maps model-semantic axes to mesh
axes (flax-linen-style logical partitioning, without flax).

Model code annotates tensors with logical axis names; the active rule set
(installed by the launcher / dry-run) resolves them to PartitionSpecs. With
no mesh installed (CPU unit tests) everything is a no-op, so the same model
code runs everywhere.

Default rules (see DESIGN.md Sec. 5):
  batch   -> ('pod', 'data')   pure DP across pods (one cross-pod collective)
  seq     -> 'model'           sequence parallelism at block boundaries
                               (activations saved by remat are 1/TP-sharded)
  heads/kv_heads/mlp/experts/vocab/ssm_inner -> 'model'   tensor parallelism
  embed   -> 'data'            FSDP: weights gathered per-layer inside scan
  layers  -> None
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),
    "seq": None,
    "act_seq": "model",          # sequence-parallel residual stream
    "embed": None,               # activations' d_model axis
    "w_embed": "data",           # weights' d_model axis (FSDP)
    "heads": "model",
    "heads_flat": "model",       # fused (H*hd) projection output axis
    "kv_heads": "model",
    "q_hd": None,
    "mlp": "model",
    "experts": "model",
    "vocab": "model",
    "ssm_inner": "model",        # mamba d_inner / heads axis
    "ssm_state": None,
    "layers": None,
    "kv_seq": "data",            # long-context KV cache: shard sequence
    "capacity": None,
}


def set_rules(rules: Optional[dict], mesh: Optional[Mesh]):
    _state.rules = rules
    _state.mesh = mesh


def get_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def get_rules() -> Optional[dict]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Optional[dict] = None):
    """Install (mesh, rules) for model code executed in this block."""
    rules = dict(DEFAULT_RULES, **(rules or {}))
    # drop axes the mesh doesn't have (e.g. 'pod' on the single-pod mesh)
    axes = set(mesh.axis_names)

    def filt(v):
        if isinstance(v, tuple):
            kept = tuple(a for a in v if a in axes)
            return kept if kept else None
        return v if v in axes else None

    rules = {k: filt(v) for k, v in rules.items()}
    prev = (get_rules(), get_mesh())
    set_rules(rules, mesh)
    try:
        with mesh:
            yield rules
    finally:
        set_rules(*prev)


def spec(*logical_axes) -> P:
    """Resolve logical axis names to a PartitionSpec under current rules."""
    rules = get_rules()
    if rules is None:
        return P()
    return P(*[rules.get(a) if a is not None else None
               for a in logical_axes])


def constrain(x: jax.Array, *logical_axes) -> jax.Array:
    """with_sharding_constraint under the active rules (no-op w/o mesh)."""
    mesh = get_mesh()
    if mesh is None or len(mesh.devices.flat) == 1:
        return x
    assert x.ndim == len(logical_axes), (x.shape, logical_axes)
    return jax.lax.with_sharding_constraint(x, spec(*logical_axes))


def sharding_for(*logical_axes) -> Optional[NamedSharding]:
    mesh = get_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec(*logical_axes))


def divisible(dim: int, *logical_axes_entry) -> bool:
    """Check a dim is divisible by the mesh extent of its mapped axes."""
    mesh = get_mesh()
    rules = get_rules()
    if mesh is None or rules is None:
        return True
    total = 1
    for a in logical_axes_entry:
        m = rules.get(a)
        axes = (m,) if isinstance(m, str) else (m or ())
        for ax in axes:
            total *= mesh.shape[ax]
    return dim % total == 0
