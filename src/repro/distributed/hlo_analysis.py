"""Static analysis of compiled (SPMD-partitioned, per-device) HLO text.

This is the dry-run "profiler": with no TPU attached, the optimized HLO is
the ground truth for what one device computes and what it moves over the
interconnect. Unlike ``compiled.cost_analysis()`` (which visits each while
body once), this analyzer multiplies loop bodies by their trip counts,
which it recovers from the ``s32[] constant(N)`` bound in each while's
condition computation -- exactly how jax.lax.scan lowers.

Reported, per device:
  * flops            -- 2*M*N*K for every dot (+ trip-count weighting)
  * bytes            -- operand+result bytes of substantive ops (an
                        HBM-traffic proxy, same convention as XLA's
                        HloCostAnalysis "bytes accessed")
  * collective bytes -- result bytes of all-reduce/all-gather/
                        reduce-scatter/all-to-all/collective-permute,
                        weighted by a ring-traffic factor
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")
# bytes moved over links per byte of result (simple ring model)
_TRAFFIC_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0,
                   "reduce-scatter": 1.0, "all-to-all": 1.0,
                   "collective-permute": 1.0}

_SKIP_BYTES_KINDS = {"parameter", "constant", "get-tuple-element", "tuple",
                     "bitcast", "after-all", "partition-id", "replica-id",
                     # control-flow wrappers: their bodies are counted via
                     # the call graph; counting the carried tuple would
                     # double-bill every loop-resident buffer
                     "while", "conditional", "call"}

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALL_ATTR_RE = re.compile(
    r"\b(body|condition|to_apply|calls|true_computation|"
    r"false_computation)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{")
_S32_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _array_dims(type_str: str):
    """All arrays in a (possibly tuple) type: [(dtype, [dims]), ...]."""
    out = []
    for m in _ARRAY_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dd = [int(d) for d in dims.split(",") if d] if dims else []
        out.append((dt, dd))
    return out


def _type_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _array_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    type_str: str
    operands: list
    line: str


def _balanced(s: str, start: int) -> int:
    """Index just past the paren group opening at s[start] == '('."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def _parse_computations(text: str):
    comps: dict[str, list[Op]] = {}
    entry = None
    current = None
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr is not None and "=" not in line.split("(")[0]:
            current = hdr.group(2)
            comps[current] = []
            if hdr.group(1):
                entry = current
            continue
        if current is None:
            continue
        m = _DEF_HEAD_RE.match(line)
        if m is None:
            continue
        name = m.group(1)
        rest = line[m.end():]
        # --- type: either a (tuple, ...) (may contain /*index=k*/ comments
        # with '=') or a plain token like f32[1,2]{1,0} ---
        if rest.startswith("("):
            tend = _balanced(rest, 0)
        else:
            tend = rest.find(" ")
            if tend < 0:
                continue
        type_str = rest[:tend]
        tail = rest[tend:].lstrip()
        km = re.match(r"([\w\-]+)\(", tail)
        if km is None:
            continue
        kind = km.group(1)
        oend = _balanced(tail, km.end() - 1)
        operands = _OPERAND_RE.findall(tail[km.end():oend])
        comps[current].append(Op(name, kind, type_str, operands, line))
    return comps, entry


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    dot_bytes: float = 0.0   # operand/result bytes of dots only (lower
                             # bound on HBM traffic: compulsory MXU feeds)
    coll_bytes: dict = dataclasses.field(default_factory=dict)
    coll_counts: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Totals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.dot_bytes += other.dot_bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * mult

    @property
    def collective_traffic_bytes(self) -> float:
        return sum(v * _TRAFFIC_FACTOR.get(k.replace("-start", ""), 1.0)
                   for k, v in self.coll_bytes.items())


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps, self.entry = _parse_computations(text)
        self._memo: dict[str, Totals] = {}

    # -------------------------------------------------- per-computation
    def _trip_count(self, cond_name: str) -> int:
        ops = self.comps.get(cond_name, [])
        consts = []
        for op in ops:
            consts += [int(x) for x in _S32_CONST_RE.findall(op.line)]
        return max(consts) if consts else 1

    def _symbols(self, comp: str) -> dict:
        return {op.name: op.type_str for op in self.comps.get(comp, [])}

    def _dot_flops(self, op: Op, symbols: dict) -> float:
        arrays = _array_dims(op.type_str)
        if not arrays:
            return 0.0
        _, rdims = arrays[0]
        out_elems = 1
        for d in rdims:
            out_elems *= d
        # contraction size from the lhs operand's shape
        c = 1
        m = _LHS_CDIMS_RE.search(op.line)
        if m and op.operands:
            lhs_type = symbols.get(op.operands[0], "")
            la = _array_dims(lhs_type)
            if la:
                _, ldims = la[0]
                for idx in m.group(1).split(","):
                    if idx and int(idx) < len(ldims):
                        c *= ldims[int(idx)]
        return 2.0 * out_elems * c

    def _direct(self, comp: str) -> Totals:
        t = Totals()
        symbols = self._symbols(comp)
        for op in self.comps.get(comp, []):
            kind = op.kind.replace("-start", "")
            if op.kind == "dot":
                t.flops += self._dot_flops(op, symbols)
                b = _type_bytes(op.type_str)
                for o in op.operands:
                    if o in symbols:
                        b += _type_bytes(symbols[o])
                t.dot_bytes += b
            if kind in COLLECTIVE_KINDS and not op.kind.endswith("-done"):
                b = _type_bytes(op.type_str)
                t.coll_bytes[kind] = t.coll_bytes.get(kind, 0.0) + b
                t.coll_counts[kind] = t.coll_counts.get(kind, 0) + 1
            if op.kind not in _SKIP_BYTES_KINDS:
                b = _type_bytes(op.type_str)
                for o in op.operands:
                    if o in symbols:
                        b += _type_bytes(symbols[o])
                t.bytes += b
        return t

    def _calls(self, comp: str):
        """[(callee, mult)] -- while bodies weighted by trip count."""
        out = []
        for op in self.comps.get(comp, []):
            refs = _CALL_ATTR_RE.findall(op.line)
            if op.kind == "while":
                body = cond = None
                for attr, name in refs:
                    if attr == "body":
                        body = name
                    elif attr == "condition":
                        cond = name
                trip = self._trip_count(cond) if cond else 1
                if body:
                    out.append((body, trip, False))
                if cond:
                    out.append((cond, trip, False))
            else:
                fused = op.kind == "fusion"
                for _attr, name in refs:
                    out.append((name, 1, fused))
                for m in _BRANCHES_RE.finditer(op.line):
                    for nm in m.group(1).split(","):
                        out.append((nm.strip().lstrip("%"), 1, fused))
        return out

    # ------------------------------------------------------- transitive
    def total(self, comp: str | None = None, _depth: int = 0,
              fused: bool = False) -> Totals:
        """fused=True: the computation body is fused -- its internal ops are
        register-resident, so only FLOPs (dots) count, not bytes."""
        key = (comp, fused)
        comp = comp or self.entry
        key = (comp, fused)
        if key in self._memo:
            return self._memo[key]
        t = Totals()
        if comp not in self.comps or _depth > 60:
            return t
        self._memo[key] = t  # break cycles
        direct = self._direct(comp)
        if fused:
            direct = Totals(flops=direct.flops, bytes=0.0,
                            dot_bytes=direct.dot_bytes,
                            coll_bytes=direct.coll_bytes,
                            coll_counts=direct.coll_counts)
        t.add(direct)
        for callee, mult, callee_fused in self._calls(comp):
            if callee == comp:
                continue
            t.add(self.total(callee, _depth + 1, fused or callee_fused),
                  mult)
        return t


def analyze(text: str) -> Totals:
    return HloAnalyzer(text).total()


# ---------------------------------------------------------------------------
# roofline terms (TPU v5e constants from the assignment)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    n_devices: int
    dot_bytes_per_device: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def memory_s_lower(self) -> float:
        """Compulsory-traffic bound: only MXU operand/result bytes."""
        return self.dot_bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "dot_bytes_per_device": self.dot_bytes_per_device,
            "memory_s_lower": self.memory_s_lower,
            "collective_bytes_per_device": self.collective_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "bound_step_s": self.step_s,
            "n_devices": self.n_devices,
        }
