"""Deterministic fault declarations for the serving fleet.

A chaos run is a *schedule*: a list of :class:`FaultSpec` records, each
pinned to the fleet's virtual clock (milliseconds).  Schedules come from
:func:`parse_chaos` -- a compact CLI grammar where every field left
unspecified is drawn from a seeded generator, so ``--chaos crash+slow
--chaos-seed 7`` names one exact fault sequence forever -- or are built
directly in tests.

Fault taxonomy (see ``src/repro/chaos/README.md`` for the injection-point
contract):

``crash``
    The target replica's engine session dies at ``t_ms`` (queue, decode
    slots and cache pages are lost).  ``until_ms`` is the recovery time:
    the replica reopens a fresh session and must pass a warm-up probe
    before the router re-admits it.
``slow``
    The target replica's modeled decode-step cost is multiplied by
    ``factor`` over ``[t_ms, until_ms]`` -- a purely virtual-clock
    fault, detected by the health watchdog as degradation.
``pool_pressure``
    ``pages`` pages are withheld from the target replica's page pool
    over ``[t_ms, until_ms]`` (host-side bookkeeping in the cache
    backend), forcing preemptions / blocked admissions.
``nan_plan``
    The target replica's bound parameters are NaN-poisoned at ``t_ms``
    (a corrupted quantized plan group); the engine's sampling-boundary
    NaN guard trips on the next step and the fleet quarantines the
    replica.  ``until_ms`` restores the original parameters (the
    warm-up probe then passes).
``store_corrupt``
    The named :class:`~repro.sweep.store.PlanStore` entry is overwritten
    with garbage at ``t_ms`` (``target`` is the entry name).  Exercises
    the store's quarantine-and-recompute resume path; no replica
    involvement.

Faults are injected at HOST BOUNDARIES only -- the engine session API,
the cache backend's bookkeeping, the router's candidate set, the plan
store's files -- never inside jitted code.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

FAULT_KINDS = ("crash", "slow", "pool_pressure", "nan_plan",
               "store_corrupt")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One declared fault, pinned to the virtual clock."""

    kind: str
    target: str = ""                  # tier name (or store entry name)
    t_ms: float = 0.0                 # injection time
    until_ms: Optional[float] = None  # recovery / restore time
    factor: float = 4.0               # slow: step_ms multiplier
    pages: int = 1                    # pool_pressure: pages withheld

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {FAULT_KINDS})")
        if self.t_ms < 0:
            raise ValueError(f"t_ms must be >= 0, got {self.t_ms}")
        if self.until_ms is not None and self.until_ms <= self.t_ms:
            raise ValueError(f"until_ms ({self.until_ms}) must be > "
                             f"t_ms ({self.t_ms})")
        if self.kind == "slow" and self.factor <= 1.0:
            raise ValueError(f"slow factor must be > 1, "
                             f"got {self.factor}")
        if self.kind == "pool_pressure" and self.pages < 1:
            raise ValueError(f"pool_pressure needs pages >= 1, "
                             f"got {self.pages}")

    def describe(self) -> str:
        span = (f"@{self.t_ms:g}" if self.until_ms is None
                else f"@{self.t_ms:g}-{self.until_ms:g}")
        extra = ""
        if self.kind == "slow":
            extra = f" x{self.factor:g}"
        elif self.kind == "pool_pressure":
            extra = f" p{self.pages}"
        return f"{self.kind}{span} -> {self.target or '?'}{extra}"


def parse_chaos(spec: str, *, targets, seed: int = 0,
                horizon_ms: float = 2000.0) -> list[FaultSpec]:
    """Parse a chaos spec string into a deterministic fault schedule.

    ``spec`` is fault tokens joined by ``+`` (or commas), each::

        kind[@t0[-t1]][:modifier]...

    where modifiers are ``x<float>`` (slow factor), ``p<int>``
    (pool-pressure pages) or a bare target name.  Every field left out
    is drawn from ``np.random.default_rng(seed)`` IN TOKEN ORDER, so
    ``(spec, targets, seed, horizon_ms)`` names one exact schedule:

    - target: uniform over ``targets`` (tier names, in fleet order)
    - t0: uniform in ``[0.2, 0.5] * horizon_ms``
    - t1: ``t0 +`` uniform in ``[0.25, 0.45] * horizon_ms``

    Examples: ``crash+slow``, ``crash@300:w8``,
    ``slow@200-900:x6:float``, ``pool_pressure:p4``.
    """
    targets = list(targets)
    if not targets:
        raise ValueError("parse_chaos needs at least one target tier")
    rng = np.random.default_rng(int(seed))
    out = []
    tokens = [t.strip() for t in spec.replace(",", "+").split("+")
              if t.strip()]
    if not tokens:
        raise ValueError(f"empty chaos spec {spec!r}")
    for tok in tokens:
        fields = tok.split(":")
        head = fields[0]
        t0 = t1 = None
        if "@" in head:
            head, _, when = head.partition("@")
            a, dash, b = when.partition("-")
            t0 = float(a)
            t1 = float(b) if dash else None
        kind = head.strip()
        if kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {kind!r} in chaos "
                             f"token {tok!r}")
        target = None
        factor, pages = 4.0, 1
        for f in fields[1:]:
            f = f.strip()
            if not f:
                continue
            if f[0] == "x" and _is_num(f[1:]):
                factor = float(f[1:])
            elif f[0] == "p" and f[1:].isdigit():
                pages = int(f[1:])
            else:
                target = f
        # seeded draws happen in a FIXED order per token (target, t0,
        # t1) regardless of which were given, so adding an explicit
        # field never shifts the other tokens' draws
        drawn_target = targets[int(rng.integers(len(targets)))]
        drawn_t0 = float(rng.uniform(0.2, 0.5) * horizon_ms)
        drawn_dt = float(rng.uniform(0.25, 0.45) * horizon_ms)
        if target is None:
            target = drawn_target
        elif target not in targets:
            raise ValueError(f"unknown target {target!r} in chaos "
                             f"token {tok!r} (targets: {targets})")
        if t0 is None:
            t0 = drawn_t0
        if t1 is None and kind != "store_corrupt":
            t1 = t0 + drawn_dt
        out.append(FaultSpec(kind=kind, target=target, t_ms=t0,
                             until_ms=t1, factor=factor, pages=pages))
    return out


def _is_num(s: str) -> bool:
    try:
        float(s)
        return True
    except ValueError:
        return False
