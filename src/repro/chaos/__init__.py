"""repro.chaos: deterministic fault injection for the serving fleet.

Faults are declared as a seeded schedule of :class:`FaultSpec` records
pinned to the fleet's virtual clock and applied at host boundaries only
(engine session API, cache backend, router, plan store) -- never inside
jitted code.  See ``src/repro/chaos/README.md`` for the taxonomy, the
injection-point contract and the determinism rules, and
``repro.fleet.health`` for the failure-detection side.
"""
from repro.chaos.faults import FAULT_KINDS, FaultSpec, parse_chaos
from repro.chaos.inject import (ChaosInjector, corrupt_store_entry,
                                poison_params)

__all__ = [
    "FAULT_KINDS", "FaultSpec", "parse_chaos",
    "ChaosInjector", "corrupt_store_entry", "poison_params",
]
