"""Fault injection driven by the fleet's virtual clock.

:class:`ChaosInjector` turns a fault schedule into a stream of
``(phase, FaultSpec)`` events the fleet consumes inside its event loop:
``inject`` at ``t_ms`` and ``restore`` at ``until_ms``.  The injector
never touches a replica itself -- the fleet applies each event at the
matching host boundary (engine session API, cache backend, router
candidate set), so no fault can reach inside jitted code.

The two injection helpers that ARE host-boundary mutations live here:
:func:`poison_params` (the ``nan_plan`` fault -- swaps NaN-filled
parameter leaves into a server's bound tree, returning an undo closure)
and :func:`corrupt_store_entry` (the ``store_corrupt`` fault -- writes
garbage over a PlanStore entry file).  Neither imports jax: poisoned
leaves are plain numpy arrays, which jit consumes like any other leaf.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro.chaos.faults import FaultSpec


class ChaosInjector:
    """Replays a fault schedule against a virtual clock.

    ``due(now)`` returns every not-yet-delivered ``(phase, spec)``
    event with ``t <= now`` (each exactly once, in schedule order);
    ``next_time()`` is the earliest undelivered event time, which the
    fleet folds into its next-event computation so the clock jumps TO
    fault times instead of over them.
    """

    def __init__(self, schedule):
        self.schedule = list(schedule)
        events = []
        for i, f in enumerate(self.schedule):
            events.append((float(f.t_ms), i, "inject", f))
            if f.until_ms is not None:
                events.append((float(f.until_ms), i, "restore", f))
        self._events = sorted(events, key=lambda e: (e[0], e[1],
                                                     e[2] != "inject"))
        self.delivered: list = []     # (t, phase, spec) in delivery order

    def due(self, now: float, eps: float = 1e-9) -> list:
        out = []
        while self._events and self._events[0][0] <= now + eps:
            t, _, phase, spec = self._events.pop(0)
            self.delivered.append((t, phase, spec))
            out.append((phase, spec))
        return out

    def next_time(self):
        return self._events[0][0] if self._events else None

    @property
    def exhausted(self) -> bool:
        return not self._events


# ---------------------------------------------------------------------------
# host-boundary mutations
# ---------------------------------------------------------------------------

def _nan_like(leaf):
    return np.full(np.shape(leaf), np.nan, dtype=leaf.dtype)


def _is_float_leaf(leaf) -> bool:
    dt = str(getattr(leaf, "dtype", ""))
    return dt.startswith("float") or dt == "bfloat16"


def _poison_node(node):
    """Depth-first: NaN the first packed-linear scale set (quantized
    tier) or the first float matrix leaf (float tier).  Returns
    ``(new_node, hit)``."""
    # a PackedLinear (duck-typed so this module stays jax-free): NaN
    # every precision group's dequant scales
    if hasattr(node, "groups") and hasattr(node, "out_index"):
        if not node.groups:
            return node, False            # fully pruned: keep looking
        groups = tuple((b, wq, _nan_like(sw))
                       for b, wq, sw in node.groups)
        return dataclasses.replace(node, groups=groups), True
    if isinstance(node, dict):
        out = {}
        hit = False
        for k in node:
            if hit:
                out[k] = node[k]
            else:
                out[k], hit = _poison_node(node[k])
        return out, hit
    if isinstance(node, (tuple, list)):
        out = []
        hit = False
        for v in node:
            if hit:
                out.append(v)
            else:
                nv, hit = _poison_node(v)
                out.append(nv)
        return type(node)(out) if isinstance(node, tuple) else out, hit
    if _is_float_leaf(node) and getattr(node, "ndim", 0) >= 2:
        return _nan_like(node), True
    return node, False


def poison_params(server):
    """NaN-poison one projection of a server's bound parameter tree --
    the ``nan_plan`` fault.  Purely host-side: the poisoned tree is
    swapped in between steps (same shapes/dtypes, so no recompilation)
    and the engine's sampling-boundary NaN guard trips on the next
    decode.  Returns an ``undo()`` closure restoring the original
    tree."""
    old = server.params
    blocks, hit = _poison_node(old["blocks"])
    if not hit:
        raise RuntimeError("poison_params found no poisonable leaf in "
                           "params['blocks']")
    new = dict(old)
    new["blocks"] = blocks
    server.params = new

    def undo():
        server.params = old
    return undo


def corrupt_store_entry(store, name: str) -> str:
    """Overwrite a PlanStore entry file with garbage bytes -- the
    ``store_corrupt`` fault.  Returns the path written.  The store's
    read path surfaces it as
    :class:`~repro.sweep.store.StoreCorruptError`, which the sweep's
    resume path quarantines and recomputes."""
    path = store._entry_path(name)
    if not os.path.exists(path):
        raise FileNotFoundError(f"no entry {name!r} to corrupt "
                                f"({path})")
    with open(path, "w") as f:
        f.write("{\"entry_version\": 1, \"name\": \"")   # truncated JSON
    return path
