"""Fault-tolerant checkpointing for arbitrary pytrees.

Design goals for 1000+ node operation:
  * atomic writes (tmp file + rename) -- a killed writer never corrupts the
    latest checkpoint
  * step-tagged files + a retention window
  * async save on a background thread (training never blocks on disk)
  * auto-resume: restore_latest() skips unreadable/corrupt files
  * mesh-agnostic: arrays are saved fully-replicated (gathered), so a
    checkpoint written under one mesh restores under any other -- this is
    what makes elastic rescaling work
  * per-host sharding hook: save(..., process_index=k) writes
    `step_<n>.proc<k>.npz`; restore merges. On CPU there is one process.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
import threading
from typing import Any, Optional

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def _unflatten(template, flat: dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = _SEP.join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint is missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"shape mismatch for {key!r}: ckpt {arr.shape} vs "
                f"template {np.shape(leaf)}")
        leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 process_index: int = 0):
        self.dir = directory
        self.keep = keep
        self.proc = process_index
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self._pins: set[int] = set()

    # -------------------------------------------------------------- pins
    def pin(self, step: int):
        """Protect a step from retention GC.  Incremental checkpointing
        pins base snapshots that later delta saves reference; pins live in
        this manager instance, so a resumed run must re-pin the base it
        restored from (the Compressor does)."""
        self._pins.add(int(step))

    def unpin(self, step: int):
        self._pins.discard(int(step))

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, blocking: bool = True,
             metadata: Optional[dict] = None, pin: bool = False):
        """Atomic save. With blocking=False the write happens on a
        background thread (joins any previous in-flight write first).
        ``pin=True`` additionally protects the step from retention GC."""
        if pin:
            self.pin(step)
        host_tree = jax.tree.map(
            lambda x: np.asarray(jax.device_get(x)), tree)
        if blocking:
            self._write(step, host_tree, metadata)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree, metadata),
                daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree, metadata):
        flat = _flatten(host_tree)
        fname = self._fname(step)
        with self._lock:
            fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
            try:
                with os.fdopen(fd, "wb") as f:
                    np.savez(f, __meta__=json.dumps(
                        {"step": step, **(metadata or {})}), **flat)
                os.replace(tmp, fname)     # atomic on POSIX
            finally:
                if os.path.exists(tmp):
                    os.unlink(tmp)
            self._gc()

    def _fname(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:012d}.proc{self.proc}.npz")

    def _gc(self):
        steps = [s for s in sorted(self.all_steps()) if s not in self._pins]
        for s in steps[: -self.keep]:
            try:
                os.unlink(self._fname(s))
            except OSError:
                pass

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        pat = re.compile(rf"step_(\d+)\.proc{self.proc}\.npz$")
        out = []
        for f in os.listdir(self.dir):
            m = pat.match(f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def restore(self, step: int, template: Any):
        with np.load(self._fname(step), allow_pickle=False) as z:
            flat = {k: z[k] for k in z.files if k != "__meta__"}
            meta = json.loads(str(z["__meta__"]))
        return _unflatten(template, flat), meta

    def peek_meta(self, step: int) -> dict:
        """Read only the metadata record of one checkpoint (cheap: lets
        callers decide which template to build before a full restore)."""
        with np.load(self._fname(step), allow_pickle=False) as z:
            return json.loads(str(z["__meta__"]))

    def latest_step_and_meta(self):
        """(step, metadata) of the newest readable checkpoint, or None."""
        for step in reversed(self.all_steps()):
            try:
                return step, self.peek_meta(step)
            except Exception as e:  # corrupt/partial file: skip it
                print(f"[checkpoint] skipping step {step}: {e}")
        return None

    def restore_latest(self, template: Any):
        """Restore the newest readable checkpoint; skip corrupt files.
        Returns (tree, meta) or (None, None) when nothing is restorable."""
        for step in reversed(self.all_steps()):
            try:
                return self.restore(step, template)
            except Exception as e:      # corrupt/partial file: skip it
                print(f"[checkpoint] skipping step {step}: {e}")
        return None, None
