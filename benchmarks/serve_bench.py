"""Serving throughput benchmark: batched continuous-batching decode,
float vs. plan-quantized at 2/4/8-bit (and a mixed) precision.

Emits ``BENCH_serve.json`` (the serving-benchmark trajectory format; each
entry is one serving variant with its measured decode throughput) and
prints the orchestrator's ``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.serve_bench [--arch ...] \
        [--out BENCH_serve.json]

Defaults are sized for a 1-core CPU (the quantized path runs the Pallas
kernel in interpret mode there; on TPU the same code hits the MXU int8
kernel, which is where the quantized-vs-float gap becomes a win rather
than an overhead).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import lm
from repro.serve import engine
from repro.serve.sampling import SamplingParams

SCHEMA_VERSION = 1


def bench_variant(name, cfg, params, plan, prompts, sp, max_len, max_batch):
    server = engine.InferenceServer(cfg, params, plan=plan,
                                    max_len=max_len, max_batch=max_batch)
    server.generate(prompts, sp)          # compile + warm caches
    t0 = time.time()
    out = server.generate(prompts, sp)
    wall = time.time() - t0
    tokens = int(sum(len(r) for r in out))
    row = {
        "name": name,
        "tokens": tokens,
        "wall_s": round(wall, 4),
        "tok_per_s": round(tokens / wall, 2),
        "decode_steps": server.stats["decode_steps"],
        "plan": None,
    }
    if plan is not None:
        row["plan"] = {
            "groups": len(plan.channel_bits),
            "prune_fraction": round(plan.prune_fraction(), 4),
            "meta_bits": plan.meta.get("bits"),
        }
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b-smoke")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    cfg = registry.get(args.arch)
    params = lm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           size=(args.requests, args.prompt_len)
                           ).astype(np.int32)
    sp = SamplingParams(max_tokens=args.tokens)   # greedy: deterministic

    variants = [("float", None)]
    for bits in (8, 4, 2):
        variants.append((f"quant-w{bits}",
                         engine.synthetic_plan(cfg, params, bits=bits)))
    variants.append(("quant-mixed",
                     engine.synthetic_plan(cfg, params, bits=None, seed=0)))

    results = []
    for name, plan in variants:
        row = bench_variant(name, cfg, params, plan, prompts, sp,
                            args.max_len, args.max_batch)
        results.append(row)
        print(f"serve/{name},{row['wall_s'] * 1e6:.0f},"
              f"tok_per_s={row['tok_per_s']}")

    report = {
        "benchmark": "serve",
        "schema_version": SCHEMA_VERSION,
        "backend": jax.default_backend(),
        "arch": cfg.name,
        "config": {"requests": args.requests,
                   "prompt_len": args.prompt_len,
                   "tokens": args.tokens,
                   "max_batch": args.max_batch,
                   "max_len": args.max_len},
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[serve_bench] wrote {args.out}")


if __name__ == "__main__":
    main()
