"""Serving throughput benchmark: batched continuous-batching decode,
float vs. plan-quantized at 2/4/8-bit (and a mixed) precision, dense vs.
paged cache backends.

Emits ``BENCH_serve.json`` (the serving-benchmark trajectory format; each
entry is one serving variant with its measured decode throughput and its
cache backend's peak memory) and prints the orchestrator's
``name,us_per_call,derived`` CSV lines.

The dense-vs-paged pairs run the SAME streaming mixed-prompt-length
workload and must produce identical tokens (asserted); the paged rows
additionally record peak cache bytes, which scale with live tokens
instead of the dense ``max_batch * max_len`` pin.  Every row carries the
per-step decode latency split (``gather_us_per_step`` -- assembling the
step inputs from the cache backend -- vs. ``step_us_per_step`` -- the
jitted decode itself), which is where the device-resident block tables
show up: paged gather no longer rebuilds host tables per step.

Since PR 10 every row also carries a **prefill-latency split**
(``prefill_ms_p50/p95/p99``: tracer-measured admitted->prefilled wall
per admission), and a ``prefill-bucketed-baseline`` row reconstructs the
retired pre-PR 10 admission path (prompt padded to a page-count bucket,
dense flash prefill, then the ``_scatter_pages`` round-trip of dense KV
into pool pages) on the same workload lengths -- the paged row's
``prefill_vs_bucketed`` block records the TTFT delta and asserts the
paged path is no slower.

    PYTHONPATH=src python -m benchmarks.serve_bench [--arch ...] \
        [--out BENCH_serve.json]

Defaults are sized for a 1-core CPU (the quantized path runs the Pallas
kernel in interpret mode there; on TPU the same code hits the MXU int8
kernel, which is where the quantized-vs-float gap becomes a win rather
than an overhead).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

import jax.numpy as jnp

from repro.configs import registry
from repro.launch import steps
from repro.models import lm
from repro.obs import Observability, percentiles
from repro.serve import cache as cache_mod
from repro.serve import engine
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import Request

SCHEMA_VERSION = 5


def machine_baseline(repeats=5, n=50, dim=256):
    """Fixed-work calibration row: a seeded float32 matmul chain whose
    wall time depends only on host speed.  Cross-PR ``BENCH_serve.json``
    deltas divide by this row's ``wall_s`` before being read as code
    regressions -- the PR 4->5 7088->3659 tok/s swing was machine speed
    (per ROADMAP), which this row makes quantifiable."""
    rng = np.random.default_rng(0)
    a = rng.standard_normal((dim, dim)).astype(np.float32)
    b = rng.standard_normal((dim, dim)).astype(np.float32)
    wall = float("inf")
    for _ in range(repeats):
        x = a
        t0 = time.time()
        for _ in range(n):
            x = x @ b
            x = x / np.float32(np.abs(x).max() + 1.0)   # stay finite
        wall = min(wall, time.time() - t0)
    return {"name": "machine_baseline", "cache": None,
            "matmul_chain": {"dim": dim, "n": n},
            "wall_s": round(wall, 5),
            "matmul_gflops": round(2 * n * dim**3 / wall / 1e9, 2),
            "plan": None}


def make_requests(cfg, n, prompt_lens, tokens, gap):
    """Streaming arrivals with mixed prompt lengths (the paged backend's
    target workload)."""
    rng = np.random.default_rng(0)
    sp = SamplingParams(max_tokens=tokens)        # greedy: deterministic
    return [Request(uid=i,
                    prompt=rng.integers(
                        0, cfg.vocab,
                        size=prompt_lens[i % len(prompt_lens)]
                    ).astype(np.int32),
                    sampling=sp, arrival=gap * i)
            for i in range(n)]


def _row_from(stats, name, cache, wall, out, plan):
    """Build one result row from a serve() stats snapshot.  `stats` must
    come from the SAME repeat as `wall` (the best one), or the per-step
    latency split would describe a different run than the wall time."""
    tokens = int(sum(len(r) for r in out.values()))
    mem = stats["memory"]
    row = {
        "name": name,
        "cache": cache,
        "tokens": tokens,
        "wall_s": round(wall, 4),
        "tok_per_s": round(tokens / wall, 2),
        "decode_steps": stats["decode_steps"],
        "preemptions": stats["preemptions"],
        "gather_us_per_step": stats["gather_us_per_step"],
        "step_us_per_step": stats["step_us_per_step"],
        "peak_cache_bytes": mem["peak_cache_bytes"]
        if cache == "paged" else mem["cache_bytes"],
        "plan": None,
    }
    if cache == "paged":
        row["page_size"] = mem["page_size"]
        row["n_pages"] = mem["n_pages"]
        row["peak_pages_in_use"] = mem["peak_pages_in_use"]
        row["dense_equivalent_bytes"] = mem["dense_equivalent_bytes"]
    if plan is not None:
        row["plan"] = {
            "groups": len(plan.channel_bits),
            "prune_fraction": round(plan.prune_fraction(), 4),
            "meta_bits": plan.meta.get("bits"),
        }
    return row, out


def _prefill_latencies(tracer):
    """Seconds from admission to prefill-complete, one entry per
    admission (a preempted request's re-prefill counts again)."""
    t_adm: dict = {}
    out = []
    for ev in tracer.events:
        if ev.kind == "admitted":
            t_adm[ev.uid] = ev.t
        elif ev.kind == "prefilled" and ev.uid in t_adm:
            out.append(ev.t - t_adm.pop(ev.uid))
    return out


def _add_latency_split(row, server, requests, wall, repeats=3):
    """Per-request latency split from the request tracer.

    Attaches a fresh Observability bundle to the already-warmed server
    (host-side only: no recompiles -- ``attach_obs`` never touches the
    jitted closures), re-runs the workload best-of-N, and folds the
    tracer's TTFT / per-token percentiles into the row.  The traced wall
    vs. the untraced ``wall`` is the measured obs overhead, reported as
    ``obs_overhead_pct`` per the acceptance criterion that the default
    (obs-off) path stays at baseline while the obs-on cost is known.
    """
    obs = Observability()
    server.attach_obs(obs)
    try:
        traced_wall = float("inf")
        for _ in range(repeats):
            t0 = time.time()
            server.serve(requests)
            traced_wall = min(traced_wall, time.time() - t0)
        ttft = percentiles(obs.tracer.ttfts())
        tok = percentiles(obs.tracer.token_latencies())
        pre = percentiles(_prefill_latencies(obs.tracer))
        for p in ("p50", "p95", "p99"):
            row[f"ttft_ms_{p}"] = round(ttft[p] * 1e3, 3)
            row[f"token_ms_{p}"] = round(tok[p] * 1e3, 3)
            row[f"prefill_ms_{p}"] = round(pre[p] * 1e3, 3)
        row["obs_overhead_pct"] = round(
            (traced_wall - wall) / wall * 100.0, 2)
    finally:
        server.attach_obs(None)
    return row


def bench_variant(name, cfg, params, plan, requests, max_len, max_batch,
                  repeats=3):
    """Single dense-backend variant (paged rows go through
    :func:`bench_pair`, which measures the backends interleaved)."""
    server = engine.InferenceServer(cfg, params, plan=plan,
                                    max_len=max_len, max_batch=max_batch)
    server.serve(requests)                # compile + warm caches
    wall = float("inf")                   # best-of-N: the wall times are
    for _ in range(repeats):              # tens of ms, CPU noise is not
        t0 = time.time()                  # (identical tokens every run)
        out = server.serve(requests)
        w = time.time() - t0
        if w < wall:
            wall, stats = w, server.stats
    row, out = _row_from(stats, name, "dense", wall, out, plan)
    _add_latency_split(row, server, requests, wall)
    return row, out


def bench_pair(name, cfg, params, plan, requests, max_len, max_batch,
               page_size, repeats=5):
    """Dense vs. paged on the SAME workload, measured INTERLEAVED
    (dense, paged, dense, paged, ...) with best-of-N walls, so drifting
    background load on the benchmark host hits both variants alike.
    Token streams are asserted identical.

    The paged server gets a pool of HALF the dense-equivalent capacity
    -- the memory-bounded deployment point paging exists for (dense
    cannot run below ``max_batch * max_len`` at all); the default
    workload's peak fits without preemption (recorded in the row)."""
    pages = (max_batch * max_len // page_size) // 2
    dense = engine.InferenceServer(cfg, params, plan=plan,
                                   max_len=max_len, max_batch=max_batch)
    paged = engine.InferenceServer(cfg, params, plan=plan,
                                   max_len=max_len, max_batch=max_batch,
                                   cache="paged", page_size=page_size,
                                   pages=pages)
    dense.serve(requests)
    paged.serve(requests)
    wall_d = wall_p = float("inf")
    for _ in range(repeats):
        t0 = time.time()
        out_d = dense.serve(requests)
        w = time.time() - t0
        if w < wall_d:
            wall_d, stats_d = w, dense.stats
        t0 = time.time()
        out_p = paged.serve(requests)
        w = time.time() - t0
        if w < wall_p:
            wall_p, stats_p = w, paged.stats
    for uid in out_d:
        np.testing.assert_array_equal(out_d[uid], out_p[uid])
    row_d, _ = _row_from(stats_d, name, "dense", wall_d, out_d, plan)
    row_p, _ = _row_from(stats_p, f"{name}-paged", "paged", wall_p,
                         out_p, plan)
    _add_latency_split(row_d, dense, requests, wall_d)
    _add_latency_split(row_p, paged, requests, wall_p)
    return row_d, row_p


def bucketed_prefill_baseline(cfg, params, prompt_lens, n_requests,
                              max_len, max_batch, page_size, repeats=10):
    """Per-admission prefill wall, measured identically for both paths.

    - **bucketed** reconstructs the retired pre-PR 10 admission path:
      prompt padded on the host to a page-count bucket, dense flash
      prefill at the bucket length (one compile per bucket), then the
      ``_scatter_pages`` round-trip writing the dense per-layer KV into
      pool pages via a separately dispatched jit.
    - **paged** is the live engine admission (``_run_prefill``: pad to
      a q-chunk multiple, one pool-donating jit reading the page pool
      in place, pointer-swap insert).

    Both timed loops include the per-admission host work (padding,
    operand preparation, dispatch) -- that is what an admission costs
    in TTFT.  Returns the baseline row carrying both measurements."""
    n_pages = (max_batch * max_len // page_size) // 2
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, size=max_len).astype(np.int32)

    # --- retired bucketed path, reconstructed -------------------------
    backend = cache_mod.make_backend(
        "paged", cfg, max_batch, max_len, page_size=page_size,
        n_pages=n_pages)
    pools = {ln: c["kv"] for ln, c in backend.caches.items() if "kv" in c}
    prefill = jax.jit(steps.make_prefill_step(cfg))

    def scatter(pools, dense_kv, pages):
        # leaves are (n_sb, B=1, spad, hkv, hd) dense vs.
        # (n_sb, n_pages + 1, page_size, hkv, hd) pool
        def put(pool, kv):
            n = pages.shape[0]
            return pool.at[:, pages].set(
                kv[:, 0].reshape(kv.shape[0], n, page_size,
                                 *kv.shape[3:]).astype(pool.dtype))
        return jax.tree.map(put, pools, dense_kv)

    scatter_j = jax.jit(scatter, donate_argnums=(0,))
    per_len = {}
    for s in sorted(set(prompt_lens)):
        spad = -(-s // page_size) * page_size          # page bucket
        best = float("inf")
        for i in range(repeats + 1):                   # first = compile
            t0 = time.time()
            padded = np.zeros(spad, np.int32)          # host bucket pad
            padded[:s] = toks[:s]
            logits, pc = prefill(params,
                                 {"tokens": jnp.asarray(padded)[None]})
            pages = jnp.arange(1, spad // page_size + 1,
                               dtype=jnp.int32)
            pools = scatter_j(
                pools, {ln: pc[ln]["kv"] for ln in pools}, pages)
            jax.block_until_ready((logits, pools))
            if i > 0:
                best = min(best, time.time() - t0)
        per_len[s] = best

    # --- live paged admission (the engine's _run_prefill) -------------
    srv = engine.InferenceServer(cfg, params, max_len=max_len,
                                 max_batch=max_batch, cache="paged",
                                 page_size=page_size, pages=n_pages)
    srv.begin()
    pbackend = srv.backend
    per_len_paged = {}
    for s in sorted(set(prompt_lens)):
        handle = pbackend.alloc(uid=s, slot=0, n_prompt=s)
        best = float("inf")
        for i in range(repeats + 1):
            t0 = time.time()
            logits = srv._run_prefill(pbackend, handle, toks[:s])
            jax.block_until_ready(logits)
            if i > 0:
                best = min(best, time.time() - t0)
        pbackend.free(handle)
        per_len_paged[s] = best

    # replicate per-admission walls to the workload's composition so the
    # percentiles describe the default workload's admission mix
    def mix(per):
        return percentiles([per[prompt_lens[i % len(prompt_lens)]]
                            for i in range(n_requests)])

    pre, pre_paged = mix(per_len), mix(per_len_paged)
    row = {"name": "prefill-bucketed-baseline", "cache": "paged",
           "page_size": page_size,
           "prefill_us_per_admission": {
               str(s): round(w * 1e6, 1) for s, w in per_len.items()},
           "paged_prefill_us_per_admission": {
               str(s): round(w * 1e6, 1)
               for s, w in per_len_paged.items()},
           "plan": None}
    for p in ("p50", "p95", "p99"):
        row[f"prefill_ms_{p}"] = round(pre[p] * 1e3, 3)
        row[f"paged_prefill_ms_{p}"] = round(pre_paged[p] * 1e3, 3)
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b-smoke")
    ap.add_argument("--requests", type=int, default=8)
    # decode-weighted default: this is a decode-throughput benchmark (the
    # admission path amortizes over the generated tokens, as in serving)
    ap.add_argument("--tokens", type=int, default=48)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--arrival-gap", type=int, default=2)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    cfg = registry.get(args.arch)
    params = lm.init_params(cfg, jax.random.key(0))
    prompt_lens = (6, 14, 9, 21)
    requests = make_requests(cfg, args.requests, prompt_lens, args.tokens,
                             args.arrival_gap)

    variants = [("float", None)]
    for bits in (8, 4, 2):
        variants.append((f"quant-w{bits}",
                         engine.synthetic_plan(cfg, params, bits=bits)))
    variants.append(("quant-mixed",
                     engine.synthetic_plan(cfg, params, bits=None, seed=0)))

    base = machine_baseline()
    results = [base]
    print(f"serve/machine_baseline,{base['wall_s'] * 1e6:.0f},"
          f"matmul_gflops={base['matmul_gflops']}")
    for name, plan in variants:
        # paged counterpart for the trajectory headliners only (float +
        # mixed plan): same workload, identical tokens (asserted inside
        # bench_pair), interleaved measurement, paged memory recorded
        if name in ("float", "quant-mixed"):
            row, prow = bench_pair(name, cfg, params, plan, requests,
                                   args.max_len, args.max_batch,
                                   args.page_size)
            results += [row, prow]
            if name == "float":
                brow = bucketed_prefill_baseline(
                    cfg, params, prompt_lens, args.requests,
                    args.max_len, args.max_batch, args.page_size)
                # both sides of the delta come from the baseline row's
                # direct per-admission harness (same timing discipline);
                # prow's own prefill_ms_* stays tracer-measured in situ
                prow["prefill_vs_bucketed"] = {
                    "bucketed_ms_p50": brow["prefill_ms_p50"],
                    "paged_ms_p50": brow["paged_prefill_ms_p50"],
                    "ttft_delta_ms": {
                        p: round(brow[f"paged_prefill_ms_{p}"]
                                 - brow[f"prefill_ms_{p}"], 3)
                        for p in ("p50", "p95", "p99")},
                }
                assert (brow["paged_prefill_ms_p50"]
                        <= brow["prefill_ms_p50"]), \
                    ("paged prefill slower than the bucketed baseline: "
                     f"{brow['paged_prefill_ms_p50']} > "
                     f"{brow['prefill_ms_p50']} ms")
                results.append(brow)
                print(f"serve/prefill-bucketed-baseline,"
                      f"{brow['prefill_ms_p50'] * 1e3:.0f},"
                      f"paged_prefill_ms_p50="
                      f"{brow['paged_prefill_ms_p50']}")
            print(f"serve/{name},{row['wall_s'] * 1e6:.0f},"
                  f"tok_per_s={row['tok_per_s']},"
                  f"gather_us={row['gather_us_per_step']},"
                  f"step_us={row['step_us_per_step']}")
            print(f"serve/{prow['name']},{prow['wall_s'] * 1e6:.0f},"
                  f"tok_per_s={prow['tok_per_s']},"
                  f"gather_us={prow['gather_us_per_step']},"
                  f"step_us={prow['step_us_per_step']},"
                  f"peak_cache_bytes={prow['peak_cache_bytes']},"
                  f"dense_bytes={prow['dense_equivalent_bytes']}")
            continue
        row, _ = bench_variant(name, cfg, params, plan, requests,
                               args.max_len, args.max_batch)
        results.append(row)
        print(f"serve/{name},{row['wall_s'] * 1e6:.0f},"
              f"tok_per_s={row['tok_per_s']},"
              f"gather_us={row['gather_us_per_step']},"
              f"step_us={row['step_us_per_step']}")

    report = {
        "benchmark": "serve",
        "schema_version": SCHEMA_VERSION,
        "backend": jax.default_backend(),
        "arch": cfg.name,
        "config": {"requests": args.requests,
                   "prompt_lens": list(prompt_lens),
                   "tokens": args.tokens,
                   "max_batch": args.max_batch,
                   "max_len": args.max_len,
                   "page_size": args.page_size,
                   "arrival_gap": args.arrival_gap},
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[serve_bench] wrote {args.out}")


if __name__ == "__main__":
    main()
