"""Sweep orchestration benchmark: warm-start continuation vs. cold
restarts on the same lambda schedule.

Two identical sweeps (same bench, same lambda grid, same seeds, same
per-point search budget) trace the accuracy-vs-size front of the gsc
reference network -- one with warm-start continuation (each point
initializes weights and gate logits from its predecessor's finished
state and skips the warmup phase), one restarting every point from
scratch.  The headline acceptance number is the warm sweep reaching an
iso-quality front in fewer total search steps; the script asserts both
halves (fewer steps AND no front-quality loss beyond a small
tolerance).

Also emits the paper-style iso-accuracy size-reduction report against
fixed 8-bit and 2-bit baselines (the abstract's 47.50% / 69.54%
framing, at smoke scale) and the host-speed ``machine_baseline``
calibration row shared with BENCH_serve / BENCH_fleet.

    PYTHONPATH=src python -m benchmarks.sweep_bench [--out BENCH_sweep.json]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

import jax

from repro import sweep as sweep_mod
from benchmarks.serve_bench import machine_baseline

SCHEMA_VERSION = 1


def run_sweep(spec, root):
    store = sweep_mod.PlanStore(os.path.join(root, spec.name, "store"))
    runner = sweep_mod.SweepRunner(
        spec, store, os.path.join(root, spec.name, "work"))
    summary = runner.run()
    front = store.front(store.query(kind="point", sweep=spec.name),
                        cost_key=spec.cost_model)
    return runner, store, summary, front


def front_rows(front, cost_model):
    return [{"name": e["name"], "lam": e["lineage"]["lam"],
             "score": round(e["metrics"]["score"], 6),
             "cost": round(e["costs"][cost_model], 3),
             "plan": e["plan"]} for e in front]


def best_score_at_or_below(front, cost, cost_tol):
    """Front quality probe: best score among points no costlier than
    ``cost * (1 + cost_tol)`` (front rows are cost-ascending)."""
    lim = cost * (1.0 + cost_tol) + 1e-9
    scores = [e["metrics"]["score"] for e in front
              if e["costs"]["size"] <= lim]
    return max(scores) if scores else None


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="gsc")
    ap.add_argument("--lams", default="2,8,24")
    ap.add_argument("--warmup-steps", type=int, default=40)
    ap.add_argument("--search-steps", type=int, default=40)
    ap.add_argument("--finetune-steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--width", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--warm-search-steps", type=int, default=None,
                    help="search budget of warm-started points "
                         "(default: the full --search-steps; the warm "
                         "savings then come from the skipped warmup)")
    ap.add_argument("--score-tol", type=float, default=0.02,
                    help="max accuracy the warm front may give up at "
                         "iso cost and still count as iso-quality")
    ap.add_argument("--cost-tol", type=float, default=0.05,
                    help="relative cost slack when matching warm front "
                         "points to cold front costs")
    ap.add_argument("--workdir", default=None,
                    help="keep sweep artifacts here instead of a "
                         "temporary directory")
    ap.add_argument("--out", default="BENCH_sweep.json")
    args = ap.parse_args(argv)

    base = machine_baseline()
    print(f"sweep/machine_baseline,wall_s={base['wall_s']},"
          f"gflops={base['matmul_gflops']}")

    lams = tuple(float(x) for x in args.lams.split(",") if x)
    common = dict(track="cnn", bench=args.bench, lams=lams,
                  warmup_steps=args.warmup_steps,
                  search_steps=args.search_steps,
                  finetune_steps=args.finetune_steps,
                  batch=args.batch, width=args.width, seed=args.seed)
    root = args.workdir or tempfile.mkdtemp(prefix="sweep_bench_")

    warm_spec = sweep_mod.SweepSpec(
        name="warm", warm_start=True,
        warm_search_steps=args.warm_search_steps or args.search_steps,
        **common)
    cold_spec = sweep_mod.SweepSpec(name="cold", warm_start=False,
                                    **common)
    runner_w, store_w, sum_w, front_w = run_sweep(warm_spec, root)
    _, _, sum_c, front_c = run_sweep(cold_spec, root)

    for tag, s in (("warm", sum_w), ("cold", sum_c)):
        print(f"sweep/{tag},points={len(s['points'])},"
              f"steps={s['steps_executed']},saved={s['steps_saved']}")

    # headline half 1: warm continuation spends strictly fewer total
    # search steps over the same lambda schedule
    assert sum_w["steps_executed"] < sum_c["steps_executed"], (
        f"warm sweep ran {sum_w['steps_executed']} steps, cold ran "
        f"{sum_c['steps_executed']}; warm must be cheaper")

    # headline half 2: iso quality -- at every cold front point's cost,
    # the warm front offers a score within --score-tol
    quality = []
    worst_gap = 0.0
    for e in front_c:
        cost = e["costs"]["size"]
        cold_s = e["metrics"]["score"]
        warm_s = best_score_at_or_below(front_w, cost, args.cost_tol)
        gap = cold_s - warm_s if warm_s is not None else float("inf")
        worst_gap = max(worst_gap, gap)
        quality.append({"cost": round(cost, 3),
                        "cold_score": round(cold_s, 6),
                        "warm_score": None if warm_s is None
                        else round(warm_s, 6),
                        "gap": None if warm_s is None
                        else round(gap, 6)})
    assert worst_gap <= args.score_tol, (
        f"warm front gives up {worst_gap:.4f} accuracy at iso cost "
        f"(tolerance {args.score_tol})")
    print(f"sweep/headline,warm_steps={sum_w['steps_executed']},"
          f"cold_steps={sum_c['steps_executed']},"
          f"worst_iso_gap={round(worst_gap, 6)}")

    # paper-style framing: iso-accuracy size reduction of the warm
    # front vs. fixed 8-bit / 2-bit references (abstract: 47.50% /
    # 69.54% on the full benchmarks; smoke scale here)
    for bits in (8, 2):
        runner_w.baseline(bits)
    iso = runner_w.iso_report(baseline_bits=(8, 2))
    for label, row in iso.items():
        print(f"sweep/iso,{label},reduction_pct={row['reduction_pct']},"
              f"baseline_score={round(row['baseline_score'], 4)}")

    report = {
        "benchmark": "sweep",
        "schema_version": SCHEMA_VERSION,
        "backend": jax.default_backend(),
        "machine_baseline": base,
        "config": {"bench": args.bench, "lams": list(lams),
                   "warmup_steps": args.warmup_steps,
                   "search_steps": args.search_steps,
                   "finetune_steps": args.finetune_steps,
                   "warm_search_steps": warm_spec.warm_search(),
                   "batch": args.batch, "width": args.width,
                   "seed": args.seed, "score_tol": args.score_tol,
                   "cost_tol": args.cost_tol},
        "warm": {"steps_executed": sum_w["steps_executed"],
                 "steps_saved": sum_w["steps_saved"],
                 "front": front_rows(front_w, "size")},
        "cold": {"steps_executed": sum_c["steps_executed"],
                 "steps_saved": sum_c["steps_saved"],
                 "front": front_rows(front_c, "size")},
        "iso_quality": quality,
        "iso_accuracy_report": iso,
        "headline": {
            "warm_steps": sum_w["steps_executed"],
            "cold_steps": sum_c["steps_executed"],
            "steps_saved_pct": round(
                100.0 * (1 - sum_w["steps_executed"]
                         / sum_c["steps_executed"]), 2),
            "worst_iso_quality_gap": round(worst_gap, 6),
        },
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[sweep_bench] wrote {args.out}")


if __name__ == "__main__":
    main()
