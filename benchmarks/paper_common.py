"""Shared helpers for the paper-table benchmarks.

All benchmarks run the REAL 3-phase recipe -- now through the composable
``repro.api`` surface (phase objects + Compressor) -- on synthetic stand-in
datasets (offline container) with CLI-scalable step budgets; defaults are
sized for a 1-core CPU. Budgets scale to the paper's 500/200/50-epoch
recipes via --scale.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import pipeline, sampling
from repro.data import synthetic
from repro.models import cnn

BENCHES = {
    "cifar10": (cnn.resnet9, synthetic.CIFAR10_LIKE),
    "gsc": (cnn.dscnn, synthetic.GSC_LIKE),
    "tinyimagenet": (cnn.resnet18, synthetic.TINYIMAGENET_LIKE),
}


def small_graph(bench: str, width: int = 8):
    builder, spec = BENCHES[bench]
    if bench == "tinyimagenet":
        return builder(), spec          # resnet18 has fixed widths
    return builder(width=width), spec


def base_config(steps: int = 80, lam: float = 1e-4, **kw
                ) -> pipeline.SearchConfig:
    return pipeline.SearchConfig(
        warmup_steps=steps, search_steps=steps,
        finetune_steps=max(steps // 2, 10), batch=32, lam=lam, **kw)


def run_cfg(g, spec, cfg: pipeline.SearchConfig, init_folded=None,
            gamma_init=None, hooks=()) -> api.CompressionResult:
    """Run one SearchConfig through the composable Compressor API."""
    comp = api.Compressor(g, spec, pw=cfg.pw, px=cfg.px, batch=cfg.batch,
                          seed=cfg.seed)
    phases = api.phases_from_config(cfg, gamma_init=gamma_init,
                                    include_warmup=init_folded is None)
    return comp.run(phases, hooks=hooks, init_folded=init_folded)


def fixed_precision_baseline(g, spec, bits: int, steps: int):
    """Train a w<bits>a8 fixed-precision reference (paper baselines)."""
    pw = (0, 2, 4, 8) if bits in (2, 4, 8) else (0, bits)
    idx = pw.index(bits)
    gamma_init = {}
    geoms = cnn.cost_geoms(g)
    for gm in geoms:
        onehot = jnp.full((gm.cout, len(pw)), -40.0).at[:, idx].set(40.0)
        gamma_init[gm.gamma] = onehot
    cfg = base_config(steps=steps, lam=0.0, pw=pw)
    return run_cfg(g, spec, cfg, gamma_init=gamma_init)


def run_sequential_pit_mixprec(g, spec, steps: int, lam_pit: float,
                               lam_mix: float, n_pit_models: int = 2):
    """The paper's baseline flow: PIT channel pruning (float), pick a seed,
    then MixPrec channel-wise MPS on the pruned net. Returns (result,
    total_seconds) -- total includes training the PIT front (N models).

    With phase objects this is literally two phase compositions: a full
    3-phase run with pw=(0, 32), then a warmup-less run seeded from it.
    """
    t0 = time.time()
    pit_results = []
    for i, lam in enumerate([lam_pit * f for f in
                             np.linspace(0.5, 2.0, n_pit_models)]):
        cfg1 = pipeline.SearchConfig(
            warmup_steps=steps, search_steps=steps,
            finetune_steps=max(steps // 2, 10), batch=32, lam=lam,
            pw=(0, 32), cost_model="size", seed=i)
        pit_results.append(run_cfg(g, spec, cfg1))
    # pick the PIT seed: best accuracy
    seed_res = max(pit_results, key=lambda r: r.acc_final)
    pruned = seed_res.plan.channel_bits

    # stage 2: MixPrec on the pruned net -- pruned channels pinned to 0-bit,
    # kept channels cannot be pruned further (0-bit logit pinned low)
    pw2 = (0, 2, 4, 8)
    gamma_init = {}
    for grp, bits in pruned.items():
        c = len(bits)
        base = sampling.init_selection_logits(pw2, (c,))
        base = jnp.where(jnp.asarray(bits)[:, None] == 0,
                         jnp.full((c, 4), -40.0).at[:, 0].set(40.0),
                         base.at[:, 0].set(-40.0))
        gamma_init[grp] = base
    cfg2 = pipeline.SearchConfig(
        warmup_steps=0, search_steps=steps,
        finetune_steps=max(steps // 2, 10), batch=32, lam=lam_mix,
        pw=pw2, cost_model="size")
    res = run_cfg(g, spec, cfg2, init_folded=seed_res.net,
                  gamma_init=gamma_init)
    return res, time.time() - t0


def csv_row(name: str, wall_s: float, derived: str) -> str:
    return f"{name},{wall_s * 1e6:.0f},{derived}"
