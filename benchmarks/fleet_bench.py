"""Fleet routing benchmark: policy comparison under an overload trace.

One four-tier fleet (float / w8 / mixed / w2 plans from the same
params) serves the SAME open-loop Poisson overload trace under each
routing policy -- ``static:float`` (the single-tier baseline that
ignores the Pareto front), ``round_robin``, ``least_loaded`` and
``pareto_degrade`` -- plus a burst trace for the deadline-pressure
worst case.  Latency is the fleet's deterministic virtual clock, so
rows are machine-independent; token content is real (each replica runs
its actual quantized decode).

A crash-and-recover scenario (``repro.chaos``: one replica's session
killed mid-run at a pinned virtual time) compares failover on vs off on
the same trace: with failover the struck replica's in-flight requests
are recovered recompute-style onto survivors, without it they die with
the ``crashed`` terminal.

Emits ``BENCH_fleet.json``; the headline acceptance numbers are
``pareto_degrade`` beating ``static:float`` on deadline attainment
under overload (the paper's Pareto front doing work at serving time),
and failover strictly beating no-failover under the crash.  The script
asserts both.

    PYTHONPATH=src python -m benchmarks.fleet_bench [--arch ...] \
        [--out BENCH_fleet.json]
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import registry
from repro.models import lm
from repro import fleet as fleet_mod
from repro.chaos import ChaosInjector, FaultSpec
from repro.launch.fleet import build_fleet
from benchmarks.serve_bench import machine_baseline

SCHEMA_VERSION = 3

POLICIES = ("static:float", "round_robin", "least_loaded",
            "pareto_degrade")


def run_policy(flt, policy, trace_fn):
    """One policy over a freshly generated trace (FleetRequests are
    mutable -- retry bookkeeping -- so every run gets its own copies)."""
    flt.set_policy(policy)
    records = flt.run(trace_fn())
    report = fleet_mod.slo_report(flt, records)
    tiers_used = {name: t["requests"]
                  for name, t in report["per_tier"].items()
                  if t["requests"]}
    return {
        "policy": policy,
        "requests": report["requests"],
        "status": report["status"],
        "deadline_attainment": report["deadline_attainment"],
        "degraded": report["degraded"],
        "retries": report["retries"],
        "ttft_ms": report["ttft_ms"],
        "token_latency_ms": report["token_latency_ms"],
        "tiers_used": tiers_used,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b-smoke")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=250.0,
                    help="overload: arrivals far above the float tier's "
                         "drain rate")
    ap.add_argument("--deadline-ms", type=float, default=180.0)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--base-step-ms", type=float, default=8.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_fleet.json")
    args = ap.parse_args(argv)

    cfg = registry.get(args.arch)
    params = lm.init_params(cfg, jax.random.key(0))
    tier_specs = ["float", "w8", "mixed", "w2"]
    flt = build_fleet(cfg, params, tier_specs, policy="round_robin",
                      max_len=args.max_len, max_batch=args.max_batch,
                      cache="paged", page_size=8, pages=None,
                      base_step_ms=args.base_step_ms)
    tiers = [{"name": rep.tier.name,
              "quality_bits": round(rep.tier.quality, 3),
              "step_ms": round(rep.tier.step_ms, 3)}
             for rep in flt.replicas]

    def poisson():
        return fleet_mod.poisson_trace(
            args.requests, rate_rps=args.rate, vocab=cfg.vocab,
            prompt_len=args.prompt_len, max_tokens=args.tokens,
            deadline_ms=args.deadline_ms, seed=args.seed)

    def burst():
        # one synchronized mega-burst: the queue-wait predictor's
        # adversarial case (everything arrives before anything drains)
        return fleet_mod.burst_trace(
            1, args.requests, burst_every_ms=1.0,
            vocab=cfg.vocab, prompt_len=args.prompt_len,
            max_tokens=args.tokens, deadline_ms=args.deadline_ms,
            seed=args.seed)

    # calibration first: fleet latency is virtual-clock (machine
    # independent), but wall-clock runtime of this script is not -- the
    # fixed-work row lets cross-PR runtime deltas be divided by host
    # speed, same convention as BENCH_serve.json.
    base = machine_baseline()
    print(f"fleet/machine_baseline,wall_s={base['wall_s']},"
          f"gflops={base['matmul_gflops']}")
    results = [base]
    for policy in POLICIES:
        row = run_policy(flt, policy, poisson)
        row["trace"] = "poisson"
        results.append(row)
        att = row["deadline_attainment"]
        print(f"fleet/{policy},poisson,"
              f"attainment={att if att is None else round(att, 4)},"
              f"timeouts={row['status']['timeout']},"
              f"shed={row['status']['shed']},"
              f"degraded={row['degraded']},tiers={row['tiers_used']}")
    for policy in ("static:float", "pareto_degrade"):
        row = run_policy(flt, policy, burst)
        row["trace"] = "burst"
        results.append(row)
        att = row["deadline_attainment"]
        print(f"fleet/{policy},burst,"
              f"attainment={att if att is None else round(att, 4)},"
              f"timeouts={row['status']['timeout']},"
              f"degraded={row['degraded']}")

    # crash-and-recover: kill the float replica's session mid-run on
    # the virtual clock; same trace with failover on vs off.  Recovered
    # requests replay their sampling streams byte-identically on
    # survivors; without failover they die with the crashed terminal.
    crash = lambda: ChaosInjector([FaultSpec(      # noqa: E731
        kind="crash", target="float", t_ms=60.0, until_ms=600.0)])
    for failover in (True, False):
        flt.failover = failover
        flt.chaos = crash()
        row = run_policy(flt, "round_robin", poisson)
        flt.chaos = None
        flt.failover = True
        row["trace"] = "crash"
        row["policy"] = ("crash_failover" if failover
                         else "crash_no_failover")
        row["crashed"] = row["status"].get("crashed", 0)
        results.append(row)
        att = row["deadline_attainment"]
        print(f"fleet/{row['policy']},crash,"
              f"attainment={att if att is None else round(att, 4)},"
              f"crashed={row['crashed']},"
              f"timeouts={row['status']['timeout']}")

    by = {(r["policy"], r["trace"]): r for r in results
          if "policy" in r}
    static_att = by[("static:float", "poisson")]["deadline_attainment"]
    pareto_att = by[("pareto_degrade", "poisson")]["deadline_attainment"]
    # the acceptance criterion: the Pareto-aware policy must beat the
    # single-tier baseline on deadline attainment under overload
    assert pareto_att > static_att, (
        f"pareto_degrade attainment {pareto_att} must beat "
        f"static:float {static_att} under overload")
    fo_att = by[("crash_failover", "crash")]["deadline_attainment"]
    nofo_att = by[("crash_no_failover", "crash")]["deadline_attainment"]
    # robustness acceptance: recovering a crashed replica's requests
    # must strictly beat letting them die
    assert fo_att > nofo_att, (
        f"crash failover attainment {fo_att} must beat no-failover "
        f"{nofo_att}")

    report = {
        "benchmark": "fleet",
        "schema_version": SCHEMA_VERSION,
        "backend": jax.default_backend(),
        "arch": cfg.name,
        "virtual_time": True,
        "config": {"requests": args.requests, "rate_rps": args.rate,
                   "deadline_ms": args.deadline_ms,
                   "tokens": args.tokens,
                   "prompt_len": args.prompt_len,
                   "max_batch": args.max_batch,
                   "max_len": args.max_len,
                   "base_step_ms": args.base_step_ms,
                   "seed": args.seed},
        "tiers": tiers,
        "results": results,
        "headline": {"static_float_attainment": static_att,
                     "pareto_degrade_attainment": pareto_att,
                     "crash_failover_attainment": fo_att,
                     "crash_no_failover_attainment": nofo_att},
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[fleet_bench] wrote {args.out}")


if __name__ == "__main__":
    main()
