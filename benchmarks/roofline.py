"""Roofline report: reads artifacts/dryrun/*.json and emits the
EXPERIMENTS.md Sec-Roofline table (single-pod baselines for all cells),
including MODEL_FLOPS = 6*N(_active)*D and the useful-compute ratio.

    PYTHONPATH=src python -m benchmarks.roofline [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

import numpy as np

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.models import lm

try:
    import jax
    import jax.tree_util as jtu
except Exception:  # pragma: no cover
    jax = None


def param_counts(arch: str) -> tuple[float, float]:
    """(N_total, N_active) -- active discounts MoE experts to k/E."""
    cfg = registry.ARCHS[arch]
    tree = lm.abstract_params(cfg)
    total = active = 0.0
    for path, leaf in jtu.tree_flatten_with_path(tree)[0]:
        keys = [getattr(p, "key", "") for p in path]
        if "gamma" in keys:
            continue
        n = float(np.prod(leaf.shape))
        total += n
        frac = 1.0
        # expert weights (leaf 'w' under ffn/w_{gate,up,down}, not the
        # dense_residual 'shared' FFN): stacked (L, E, d, f)
        if "ffn" in keys and "shared" not in keys and cfg.is_moe \
                and len(keys) >= 2 \
                and keys[-2] in ("w_gate", "w_up", "w_down") \
                and len(leaf.shape) >= 3:
            frac = cfg.experts_per_token / cfg.n_experts
        active += n * frac
    return total, active


def model_flops(arch: str, shape_name: str) -> float:
    """First-order useful FLOPs of the step (per whole cluster)."""
    cfg = registry.ARCHS[arch]
    shape = SHAPES[shape_name]
    _, n_active = param_counts(arch)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def load_records(out_dir="artifacts/dryrun", mesh="16x16"):
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        r = json.load(open(f))
        if r.get("mesh") != mesh or r.get("search"):
            continue
        recs.append(r)
    return recs


def build_table(out_dir="artifacts/dryrun"):
    rows = []
    for r in load_records(out_dir):
        arch, shape = r["arch"], r["shape"]
        if "skipped" in r:
            rows.append({"arch": arch, "shape": shape,
                         "skipped": r["skipped"]})
            continue
        if not r.get("ok"):
            rows.append({"arch": arch, "shape": shape,
                         "error": r.get("error", "?")})
            continue
        roof = r["roofline"]
        mf = model_flops(arch, shape)
        hlo_total = roof["flops_per_device"] * roof["n_devices"]
        useful = mf / hlo_total if hlo_total else 0.0
        # roofline fraction: useful-FLOPs-limited time / bound step time
        ideal_s = mf / roof["n_devices"] / 197e12
        frac = ideal_s / roof["bound_step_s"] if roof["bound_step_s"] else 0
        rows.append({
            "arch": arch, "shape": shape,
            "compute_s": roof["compute_s"],
            "memory_s": roof["memory_s"],
            "memory_s_lower": roof.get("memory_s_lower", 0.0),
            "collective_s": roof["collective_s"],
            "dominant": roof["dominant"],
            "model_flops": mf,
            "useful_ratio": useful,
            "roofline_fraction": frac,
            "temp_gb": r["memory_analysis"].get("temp_bytes", 0) / 2**30,
        })
    return rows


_FIX = {"compute": "what would help: larger per-device batch or fewer "
        "redundant FLOPs (remat policy)",
        "memory": "what would help: better fusion / bf16 intermediates / "
        "kv+activation layout",
        "collective": "what would help: overlap FSDP gathers with compute, "
        "shard differently, or compress gradients"}


def to_markdown(rows) -> str:
    out = ["| arch | shape | compute s | memory s (lower) | collective s |"
           " bound | MODEL_FLOPS | useful ratio | roofline frac |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | -- | -- | -- | "
                       f"skipped | -- | -- | -- |")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | -- | -- | -- | "
                       f"ERROR | -- | -- | -- |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} ({r['memory_s_lower']:.4f}) | "
            f"{r['collective_s']:.4f} | {r['dominant']} | "
            f"{r['model_flops']:.3e} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = build_table(args.out)
    if args.markdown:
        print(to_markdown(rows))
        return
    print("arch,shape,compute_s,memory_s,collective_s,dominant,"
          "model_flops,useful_ratio,roofline_fraction")
    for r in rows:
        if "skipped" in r or "error" in r:
            print(f"{r['arch']},{r['shape']},,,,"
                  f"{'skipped' if 'skipped' in r else 'error'},,,")
            continue
        print(f"{r['arch']},{r['shape']},{r['compute_s']:.5f},"
              f"{r['memory_s']:.5f},{r['collective_s']:.5f},"
              f"{r['dominant']},{r['model_flops']:.4e},"
              f"{r['useful_ratio']:.3f},{r['roofline_fraction']:.4f}")


if __name__ == "__main__":
    main()
