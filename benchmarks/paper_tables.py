"""One benchmark per paper table/figure (run via ``python -m
benchmarks.run`` or individually: ``python -m benchmarks.paper_tables
--which fig4 --steps 80``).

  fig4   -- sampling methods (SM/AM/HGSM) x lambda Pareto (accuracy vs size)
  fig5   -- Ours vs MixPrec vs EdMIPS-style layerwise vs PIT+MixPrec
  table2 -- joint-vs-sequential search-time speedup
  table3 -- deployment: MPIC/NE16 cycles+latency(+energy) for Pareto models
  fig6   -- cost-model cross-evaluation (MPIC-trained model on NE16 & v.v.)
  fig9   -- activation MPS (P_X = {2,4,8}) vs fixed a8, bitops cost

All runs go through the composable ``repro.api`` surface; deployment
numbers come from the cost-model registry's ``discrete`` face and each
run's :class:`~repro.api.plan.CompressionPlan`.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks import paper_common as pc
from repro import api
from repro.core import costs, sampling
from repro.models import cnn

ART = "artifacts/paper"


def _emit(rows, name):
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=2, default=float)


def fig4_sampling(steps: int, bench: str = "cifar10"):
    g, spec = pc.small_graph(bench)
    rows = []
    for method in sampling.SAMPLERS:
        for lam in (2.0, 8.0, 20.0):
            t0 = time.time()
            cfg = pc.base_config(steps=steps, lam=lam, sampler=method)
            res = pc.run_cfg(g, spec, cfg)
            rows.append({"method": method, "lam": lam,
                         "acc": res.acc_final,
                         "size_kb": res.size_bytes / 1024,
                         "prune_frac": res.prune_fraction,
                         "wall_s": time.time() - t0})
            print(pc.csv_row(f"fig4/{method}/lam{lam:g}", rows[-1]["wall_s"],
                             f"acc={res.acc_final:.3f};"
                             f"kB={rows[-1]['size_kb']:.2f}"))
    _emit(rows, "fig4")
    return rows


def fig5_sota(steps: int, bench: str = "gsc"):
    g, spec = pc.small_graph(bench)
    rows = []

    def record(name, res, wall):
        rows.append({"method": name, "acc": res.acc_final,
                     "size_kb": res.size_bytes / 1024,
                     "prune_frac": res.prune_fraction, "wall_s": wall})
        print(pc.csv_row(f"fig5/{name}", wall,
                         f"acc={res.acc_final:.3f};"
                         f"kB={rows[-1]['size_kb']:.2f}"))

    for lam in (8.0, 20.0):
        t0 = time.time()
        res = pc.run_cfg(g, spec, pc.base_config(steps=steps, lam=lam))
        record(f"ours/lam{lam:g}", res, time.time() - t0)
        # MixPrec [8]: channel-wise MPS without the 0-bit option
        t0 = time.time()
        res = pc.run_cfg(g, spec,
                         pc.base_config(steps=steps, lam=lam, pw=(2, 4, 8)))
        record(f"mixprec/lam{lam:g}", res, time.time() - t0)
        # EdMIPS-style: layer-wise MPS, no pruning
        t0 = time.time()
        res = pc.run_cfg(g, spec,
                         pc.base_config(steps=steps, lam=lam, pw=(2, 4, 8),
                                        layerwise=True))
        record(f"edmips/lam{lam:g}", res, time.time() - t0)
        # PIT-only: pruning in float (0 or 32 bit)
        t0 = time.time()
        res = pc.run_cfg(g, spec,
                         pc.base_config(steps=steps, lam=lam, pw=(0, 32)))
        record(f"pit/lam{lam:g}", res, time.time() - t0)
    # sequential PIT -> MixPrec
    res, wall = pc.run_sequential_pit_mixprec(
        g, spec, steps, lam_pit=8.0, lam_mix=8.0)
    record("pit+mixprec", res, wall)
    _emit(rows, "fig5")
    return rows


def table2_speedup(steps: int, bench: str = "gsc"):
    g, spec = pc.small_graph(bench)
    t0 = time.time()
    pc.run_cfg(g, spec, pc.base_config(steps=steps, lam=8.0))
    ours_s = time.time() - t0
    _, seq_s = pc.run_sequential_pit_mixprec(
        g, spec, steps, lam_pit=8.0, lam_mix=8.0, n_pit_models=2)
    speedup = seq_s / ours_s
    print(pc.csv_row("table2/speedup", ours_s,
                     f"seq_s={seq_s:.1f};ours_s={ours_s:.1f};"
                     f"speedup={speedup:.2f}x"))
    _emit({"ours_s": ours_s, "sequential_s": seq_s,
           "speedup": speedup, "paper_reported": "2.7x-3.9x"}, "table2")
    return speedup


def _deploy_eval(g, plan: api.CompressionPlan):
    """Discrete MPIC + NE16 cycles for a plan, via the cost registry."""
    geoms = cnn.cost_geoms(g)
    kept = {grp: int(np.sum(np.asarray(b) > 0))
            for grp, b in plan.channel_bits.items()}
    mpic_model = api.get_cost_model("mpic")
    ne16_model = api.get_cost_model("ne16")
    mpic = ne16 = 0.0
    for gm in geoms:
        bits = np.asarray(plan.channel_bits[gm.gamma])
        cin_eff = kept.get(gm.in_gamma, gm.cin) if gm.in_gamma else gm.cin
        mpic += mpic_model.discrete(gm, bits, cin_eff)
        ne16 += ne16_model.discrete(gm, bits, cin_eff)
    return {"mpic_cycles": mpic,
            "mpic_latency_ms": mpic / costs.MPIC_FREQ_HZ * 1e3,
            "mpic_energy_uj": mpic / costs.MPIC_FREQ_HZ
            * costs.MPIC_POWER_W * 1e6,
            "ne16_cycles": ne16,
            "ne16_latency_ms": ne16 / costs.NE16_FREQ_HZ * 1e3}


def table3_fig6_deployment(steps: int, bench: str = "cifar10"):
    """Train with the MPIC and the NE16 regularizer, evaluate each model on
    BOTH targets (the paper's cross-cost-model experiment), plus fixed
    baselines."""
    g, spec = pc.small_graph(bench)
    rows = []
    for cost_model in ("mpic", "ne16"):
        for lam_scale, label in ((2.0, "high"), (25.0, "low")):
            lam = 1.0 * lam_scale   # normalized regularizers: same scale
            t0 = time.time()
            cfg = pc.base_config(steps=steps, lam=lam,
                                 cost_model=cost_model,
                                 ne16_refine=(cost_model == "ne16"))
            res = pc.run_cfg(g, spec, cfg)
            row = {"trained_for": cost_model, "point": label,
                   "acc": res.acc_final,
                   "size_kb": res.size_bytes / 1024,
                   **_deploy_eval(g, res.plan),
                   "wall_s": time.time() - t0}
            rows.append(row)
            print(pc.csv_row(
                f"table3/{cost_model}/{label}", row["wall_s"],
                f"acc={row['acc']:.3f};mpic_ms={row['mpic_latency_ms']:.2f};"
                f"ne16_ms={row['ne16_latency_ms']:.3f}"))
    for bits in (8, 4, 2):
        t0 = time.time()
        res = pc.fixed_precision_baseline(g, spec, bits, steps)
        row = {"trained_for": f"fixed-w{bits}a8", "point": "baseline",
               "acc": res.acc_final, "size_kb": res.size_bytes / 1024,
               **_deploy_eval(g, res.plan),
               "wall_s": time.time() - t0}
        rows.append(row)
        print(pc.csv_row(f"table3/w{bits}a8", row["wall_s"],
                         f"acc={row['acc']:.3f};"
                         f"mpic_ms={row['mpic_latency_ms']:.2f}"))
    _emit(rows, "table3_fig6")
    return rows


def fig9_activation_mps(steps: int, bench: str = "cifar10"):
    g, spec = pc.small_graph(bench)
    rows = []
    for px, label in (((8,), "a8"), ((2, 4, 8), "aMPS")):
        for lam in (2.0, 12.0):
            t0 = time.time()
            cfg = pc.base_config(steps=steps, lam=lam, px=px,
                                 cost_model="bitops")
            res = pc.run_cfg(g, spec, cfg)
            rows.append({"acts": label, "lam": lam,
                         "acc": res.acc_final,
                         "size_kb": res.size_bytes / 1024,
                         "wall_s": time.time() - t0})
            print(pc.csv_row(f"fig9/{label}/lam{lam:g}",
                             rows[-1]["wall_s"],
                             f"acc={res.acc_final:.3f}"))
    _emit(rows, "fig9")
    return rows


ALL = {"fig4": fig4_sampling, "fig5": fig5_sota, "table2": table2_speedup,
       "table3": table3_fig6_deployment, "fig9": fig9_activation_mps}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--which", default="fig4", choices=list(ALL))
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--bench", default=None)
    args = ap.parse_args()
    kw = {"steps": args.steps}
    if args.bench:
        kw["bench"] = args.bench
    ALL[args.which](**kw)


if __name__ == "__main__":
    main()
