"""Benchmark orchestrator: one entry per paper table/figure + kernel
micro-benches + the roofline report. Prints ``name,us_per_call,derived``
CSV lines.

    PYTHONPATH=src python -m benchmarks.run [--steps 60] [--skip fig5,...]

Step budgets default to 1-core-CPU-friendly values; pass --steps to scale
toward the paper's full 500/200/50-epoch recipe.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--skip", default="")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    skip = set(args.skip.split(",")) if args.skip else set()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import kernel_bench, paper_tables

    print("name,us_per_call,derived")
    t0 = time.time()

    def want(name):
        if only is not None:
            return name in only
        return name not in skip

    if want("kernels"):
        kernel_bench.main()
    for name, fn in paper_tables.ALL.items():
        if want(name):
            fn(steps=args.steps)
    if want("roofline"):
        try:
            from benchmarks import roofline
            rows = roofline.build_table()
            n_ok = sum(1 for r in rows if "compute_s" in r)
            worst = [r for r in rows if r.get("roofline_fraction")]
            worst = sorted(worst, key=lambda r: r["roofline_fraction"])
            d = (f"cells={n_ok};worst={worst[0]['arch']}/"
                 f"{worst[0]['shape']}" if worst else f"cells={n_ok}")
            print(f"roofline/baselines,{(time.time()-t0)*1e6:.0f},{d}")
        except Exception as e:
            print(f"roofline/baselines,0,unavailable:{e}")
    print(f"total,{(time.time() - t0) * 1e6:.0f},done", file=sys.stderr)


if __name__ == "__main__":
    main()
