"""Kernel micro-benchmarks: wall time of the jnp oracle path on CPU plus
HBM-traffic accounting for the fused Pallas path (the structural win: the
fused kernel reads W once instead of once per precision).

NOTE: on this CPU container the Pallas kernels execute in interpret mode
(Python), so wall-clock µs of the kernel path is not meaningful; the
reported `derived` column carries the traffic model that holds on TPU.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.mps_combine import ref as mref
from repro.kernels.quant_matmul import ops as qops, ref as qref
from repro.kernels.ssd_scan import ref as sref


def _time(fn, *args, n=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / n


def bench_mps_combine():
    m, k = 1024, 4096
    w = jax.random.normal(jax.random.key(0), (m, k))
    probs = jax.nn.softmax(jax.random.normal(jax.random.key(1), (m, 4)), -1)
    jitted = jax.jit(lambda w, p: mref.mps_combine_ref(w, p, (0, 2, 4, 8)))
    t = _time(jitted, w, probs)
    # traffic model: naive = read W once per non-zero precision + write
    # each quantized variant + read them for the combine; fused = 1R + 1W
    naive_bytes = (3 + 3 * 2 + 1) * m * k * 4
    fused_bytes = 2 * m * k * 4
    print(f"kernels/mps_combine,{t*1e6:.0f},"
          f"traffic_reduction={naive_bytes/fused_bytes:.1f}x")


def bench_quant_matmul():
    m, n, k = 256, 1024, 1024
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    xq, sx = qref.quantize_activations(x)
    for bits in (8, 4, 2):
        lim = 2 ** (bits - 1)
        wq = rng.integers(-lim + 1, lim, size=(n, k)).astype(np.int8)
        sw = jnp.ones((n,), jnp.float32)
        jitted = jax.jit(lambda a, b, c, d: qref.quant_matmul_ref(a, b, c,
                                                                  d))
        t = _time(jitted, xq, jnp.asarray(wq), sw, sx)
        w_bytes_packed = n * k * bits // 8
        print(f"kernels/quant_matmul_w{bits},{t*1e6:.0f},"
              f"weight_bytes={w_bytes_packed};"
              f"vs_bf16={2*n*k/w_bytes_packed:.1f}x_smaller")


def bench_ssd_scan():
    c, h, p, n = 16, 128, 64, 128
    dec = jax.random.uniform(jax.random.key(0), (c, h), minval=0.5,
                             maxval=1.0)
    s_in = jax.random.normal(jax.random.key(1), (c, h, p, n))
    s0 = jnp.zeros((h, p, n))
    jitted = jax.jit(sref.ssd_scan_ref)
    t = _time(jitted, dec, s_in, s0)
    state_bytes = h * p * n * 4
    print(f"kernels/ssd_scan,{t*1e6:.0f},"
          f"vmem_resident_state={state_bytes/1024:.0f}kB;"
          f"hbm_roundtrips_saved={c}")


def main():
    bench_mps_combine()
    bench_quant_matmul()
    bench_ssd_scan()


if __name__ == "__main__":
    main()
