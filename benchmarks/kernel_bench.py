"""Kernel micro-benchmarks: wall time of the jnp oracle path on CPU plus
HBM-traffic accounting for the fused Pallas path (the structural win: the
fused kernel reads W once instead of once per precision; the paged-
attention kernel reads live pages instead of the dense table width).

Emits ``BENCH_kernels.json`` (one row per kernel with the measured
oracle-path wall time and the derived traffic model) and prints the
orchestrator's ``name,us_per_call,derived`` CSV lines.

NOTE: on this CPU container the Pallas kernels execute in interpret mode
(Python), so wall-clock µs of the kernel path is not meaningful; the
reported `derived` column carries the traffic model that holds on TPU.

    PYTHONPATH=src python -m benchmarks.kernel_bench [--out BENCH_kernels.json]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.mps_combine import ref as mref
from repro.kernels.paged_attention import ref as pref
from repro.kernels.quant_matmul import ops as qops, ref as qref
from repro.kernels.ssd_scan import ref as sref

SCHEMA_VERSION = 1


def _time(fn, *args, n=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / n


def _row(name, t_s, derived):
    print(f"kernels/{name},{t_s * 1e6:.0f},{derived}")
    return {"name": name, "us_per_call": round(t_s * 1e6, 1),
            "derived": derived}


def bench_mps_combine():
    m, k = 1024, 4096
    w = jax.random.normal(jax.random.key(0), (m, k))
    probs = jax.nn.softmax(jax.random.normal(jax.random.key(1), (m, 4)), -1)
    jitted = jax.jit(lambda w, p: mref.mps_combine_ref(w, p, (0, 2, 4, 8)))
    t = _time(jitted, w, probs)
    # traffic model: naive = read W once per non-zero precision + write
    # each quantized variant + read them for the combine; fused = 1R + 1W
    naive_bytes = (3 + 3 * 2 + 1) * m * k * 4
    fused_bytes = 2 * m * k * 4
    return [_row("mps_combine", t,
                 f"traffic_reduction={naive_bytes / fused_bytes:.1f}x")]


def bench_quant_matmul():
    m, n, k = 256, 1024, 1024
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    xq, sx = qref.quantize_activations(x)
    rows = []
    for bits in (8, 4, 2):
        lim = 2 ** (bits - 1)
        wq = rng.integers(-lim + 1, lim, size=(n, k)).astype(np.int8)
        sw = jnp.ones((n,), jnp.float32)
        jitted = jax.jit(lambda a, b, c, d: qref.quant_matmul_ref(a, b, c,
                                                                  d))
        t = _time(jitted, xq, jnp.asarray(wq), sw, sx)
        w_bytes_packed = n * k * bits // 8
        rows.append(_row(
            f"quant_matmul_w{bits}", t,
            f"weight_bytes={w_bytes_packed};"
            f"vs_bf16={2 * n * k / w_bytes_packed:.1f}x_smaller"))
    return rows


def bench_ssd_scan():
    c, h, p, n = 16, 128, 64, 128
    dec = jax.random.uniform(jax.random.key(0), (c, h), minval=0.5,
                             maxval=1.0)
    s_in = jax.random.normal(jax.random.key(1), (c, h, p, n))
    s0 = jnp.zeros((h, p, n))
    jitted = jax.jit(sref.ssd_scan_ref)
    t = _time(jitted, dec, s_in, s0)
    state_bytes = h * p * n * 4
    return [_row("ssd_scan", t,
                 f"vmem_resident_state={state_bytes / 1024:.0f}kB;"
                 f"hbm_roundtrips_saved={c}")]


def bench_paged_attention():
    """Decode attention over the page pool at a realistic serving fill:
    slots hold mixed live lengths, the table width covers max_len.  The
    structural win on TPU: the kernel streams only the LIVE pages of
    each slot's block table (never-written pages hit the pl.when skip
    and the null-page DMA dedup), while the pre-kernel gather path read
    -- and materialized -- the full dense (B, max_len) width per step.
    """
    b, h, hkv, hd = 8, 8, 2, 64
    ps, n_pb = 16, 16                      # max_len 256
    lens = [(i * 37) % (ps * n_pb) + 1 for i in range(b)]  # mixed fill
    rng = np.random.default_rng(0)
    n_pages = sum(-(-s // ps) for s in lens)
    pool_k = jnp.asarray(rng.normal(
        size=(n_pages + 1, ps, hkv, hd)).astype(np.float32))
    pool_v = jnp.asarray(rng.normal(
        size=(n_pages + 1, ps, hkv, hd)).astype(np.float32))
    tables = np.zeros((b, n_pb), np.int32)
    nxt = 1
    for bi, s in enumerate(lens):
        for p in range(-(-s // ps)):
            tables[bi, p] = nxt
            nxt += 1
    tables = jnp.asarray(tables)
    pos = jnp.asarray([s - 1 for s in lens], jnp.int32)
    q = jnp.asarray(rng.normal(size=(b, h, hd)).astype(np.float32))
    jitted = jax.jit(pref.paged_attention_view)
    t = _time(jitted, q, pool_k, pool_v, tables, pos)
    kv_bytes = 2 * ps * hkv * hd * 4                  # K+V, f32 here
    dense_read = b * n_pb * kv_bytes                  # full table width
    live_read = sum(-(-s // ps) for s in lens) * kv_bytes
    return [_row(
        "paged_attention", t,
        f"live_page_bytes={live_read};dense_width_bytes={dense_read};"
        f"hbm_read_reduction={dense_read / live_read:.1f}x")]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args(argv)
    rows = []
    rows += bench_mps_combine()
    rows += bench_quant_matmul()
    rows += bench_ssd_scan()
    rows += bench_paged_attention()
    report = {"benchmark": "kernels", "schema_version": SCHEMA_VERSION,
              "backend": jax.default_backend(), "results": rows}
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[kernel_bench] wrote {args.out}")


if __name__ == "__main__":
    main()
