"""Sweep subsystem tests: the content-addressed PlanStore (round-trips,
dedup, every failure path), front maintenance + adaptive bisection math,
SweepSpec validation/identity, warm-start cnn sweeps with obs artifacts
through the validator, kill/resume byte-identity of the store (the
acceptance criterion), corrupt-entry quarantine + recompute resume
(the ``store_corrupt`` fault), lm-track sweeps feeding the fleet via
``store:`` tiers, and plan provenance round-trips."""
import json
import os

import jax
import numpy as np
import pytest

from repro import api
from repro import fleet as fleet_mod
from repro import obs
from repro import sweep
from repro.chaos import inject as chaos_inject
from repro.configs import registry as configs_registry
from repro.launch.fleet import build_fleet, build_tier, build_tiers
from repro.models import lm
from repro.obs import validate as obs_validate
from repro.obs.tracing import RequestTracer
from repro.serve import engine
from repro.sweep import front as front_mod

SCHEMA = os.path.join(os.path.dirname(__file__), "obs_schema.json")


@pytest.fixture(scope="module")
def llama():
    cfg = configs_registry.get("llama3.2-1b-smoke")
    return cfg, lm.init_params(cfg, jax.random.key(0))


def cnn_spec(**kw):
    base = dict(name="t", track="cnn", bench="gsc", lams=(2.0, 12.0),
                adaptive_points=1, warmup_steps=4, search_steps=4,
                finetune_steps=2, batch=8, width=4, eval_batches=2,
                checkpoint_every=2)
    base.update(kw)
    return sweep.SweepSpec(**base)


def lm_spec(**kw):
    base = dict(name="lt", track="lm", bench="llama3.2-1b-smoke",
                lams=(0.5, 4.0), warmup_steps=1, search_steps=4,
                finetune_steps=0, batch=4, seq=16, eval_batches=2,
                checkpoint_every=1)
    base.update(kw)
    return sweep.SweepSpec(**base)


def run_sweep(spec, root, hooks=(), max_points=None, with_obs=False):
    ob = obs.Observability() if with_obs else None
    store = sweep.PlanStore(os.path.join(root, "store"))
    runner = sweep.SweepRunner(
        spec, store, os.path.join(root, "work"), verbose=False,
        registry=ob.registry if ob else None,
        tracer=ob.tracer if ob else None)
    summary = runner.run(max_points=max_points, hooks=hooks)
    return runner, store, summary, ob


def store_fingerprint(store):
    """Everything the byte-identity acceptance criterion compares: the
    exact bytes of every entry JSON, the set of plan hashes, and the
    front (entry names in cost order)."""
    entries = {}
    for name in store.names():
        with open(store._entry_path(name), "rb") as f:
            entries[name] = f.read()
    plans = sorted(e["plan"] for e in store.entries())
    front = [e["name"] for e in store.front()]
    return entries, plans, front


@pytest.fixture(scope="module")
def cnn_ref(tmp_path_factory):
    """Uninterrupted reference cnn sweep (warm-start, obs on)."""
    root = tmp_path_factory.mktemp("cnn_ref")
    return run_sweep(cnn_spec(), str(root), with_obs=True)


@pytest.fixture(scope="module")
def lm_ref(tmp_path_factory):
    """Uninterrupted reference lm sweep."""
    root = tmp_path_factory.mktemp("lm_ref")
    return run_sweep(lm_spec(), str(root))


# ---------------------------------------------------------------------------
# PlanStore
# ---------------------------------------------------------------------------

class TestPlanStore:
    @pytest.fixture()
    def plans(self, llama):
        cfg, params = llama
        return (engine.synthetic_plan(cfg, params, bits=8),
                engine.synthetic_plan(cfg, params, bits=None, seed=3))

    def test_round_trip_and_dedup(self, tmp_path, plans):
        p8, pmix = plans
        store = sweep.PlanStore(str(tmp_path))
        e = store.put(p8, "a", metrics={"score": 0.5},
                      costs={"size": 100.0}, lineage={"lam": 1.0})
        assert e["plan"] == sweep.plan_hash(p8)
        assert store.load("a").equals(p8)
        # meta is provenance, not content: the same assignment under a
        # different name shares one plan file
        store.put(p8, "b", metrics={"score": 0.4}, costs={"size": 100.0})
        assert len(os.listdir(store.plans_dir)) == 2  # one .npz + .json
        store.put(pmix, "c", metrics={"score": 0.3},
                  costs={"size": 60.0})
        assert store.names() == ["a", "b", "c"]
        assert store.has("a") and not store.has("zz")
        assert store.verify() == []

    def test_query_and_front(self, tmp_path, plans):
        p8, pmix = plans
        store = sweep.PlanStore(str(tmp_path))
        store.put(p8, "hi", metrics={"score": 0.9},
                  costs={"size": 100.0}, lineage={"kind": "point",
                                                  "lam": 1.0})
        store.put(pmix, "lo", metrics={"score": 0.6},
                  costs={"size": 50.0}, lineage={"kind": "point",
                                                 "lam": 8.0})
        store.put(p8, "ref", metrics={"score": 0.8},
                  costs={"size": 100.0}, lineage={"kind": "baseline"})
        assert [e["name"] for e in store.query(kind="point")] \
            == ["hi", "lo"]
        assert [e["name"] for e in store.query(lam=8.0)] == ["lo"]
        assert store.query(kind="nope") == []
        fr = store.front(store.query(kind="point"))
        assert [e["name"] for e in fr] == ["lo", "hi"]  # cost ascending

    def test_invalid_name(self, tmp_path, plans):
        store = sweep.PlanStore(str(tmp_path))
        with pytest.raises(sweep.StoreError, match="invalid entry name"):
            store.put(plans[0], "a/b")
        with pytest.raises(sweep.StoreError, match="no entry"):
            store.entry("missing")
        with pytest.raises(sweep.StoreError, match="no plan"):
            store.get("feedbeef")

    def test_missing_npz_beside_json(self, tmp_path, plans):
        store = sweep.PlanStore(str(tmp_path))
        e = store.put(plans[0], "a", costs={"size": 1.0})
        os.unlink(os.path.join(store.plans_dir, e["plan"] + ".npz"))
        with pytest.raises(sweep.StoreError,
                           match=r"missing its \.npz"):
            store.load("a")
        assert any("missing its .npz" in p for p in store.verify())

    def test_truncated_npz(self, tmp_path, plans):
        store = sweep.PlanStore(str(tmp_path))
        e = store.put(plans[0], "a", costs={"size": 1.0})
        path = os.path.join(store.plans_dir, e["plan"] + ".npz")
        with open(path, "r+b") as f:
            f.truncate(os.path.getsize(path) // 2)
        with pytest.raises(sweep.StoreError,
                           match="corrupt or truncated"):
            store.load("a")

    def test_corrupt_entry_json(self, tmp_path, plans):
        store = sweep.PlanStore(str(tmp_path))
        store.put(plans[0], "a", costs={"size": 1.0})
        with open(store._entry_path("a"), "w") as f:
            f.write("{not json")
        with pytest.raises(sweep.StoreError, match="corrupt"):
            store.entry("a")
        # valid JSON but missing required fields is also corrupt
        with open(store._entry_path("a"), "w") as f:
            json.dump({"name": "a"}, f)
        with pytest.raises(sweep.StoreError, match="missing field"):
            store.entry("a")

    def test_content_hash_mismatch(self, tmp_path, plans):
        p8, pmix = plans
        store = sweep.PlanStore(str(tmp_path))
        e8 = store.put(p8, "a", costs={"size": 1.0})
        em = store.put(pmix, "b", costs={"size": 1.0})
        # swap b's arrays in under a's hash: content no longer matches
        for ext in (".npz", ".json"):
            os.replace(os.path.join(store.plans_dir, em["plan"] + ext),
                       os.path.join(store.plans_dir, e8["plan"] + ext))
        with pytest.raises(sweep.StoreError,
                           match="content-hash check"):
            store.load("a")

    def test_corrupt_error_typing(self, tmp_path, plans):
        """Corruption is a distinct subclass so resume paths can
        quarantine it without masking usage errors (missing entries,
        bad names), which stay plain StoreError."""
        assert issubclass(sweep.StoreCorruptError, sweep.StoreError)
        store = sweep.PlanStore(str(tmp_path))
        store.put(plans[0], "a", costs={"size": 1.0})
        with open(store._entry_path("a"), "w") as f:
            f.write("{not json")
        with pytest.raises(sweep.StoreCorruptError):
            store.entry("a")
        # a merely missing entry is NOT corruption
        with pytest.raises(sweep.StoreError) as ei:
            store.entry("zz")
        assert not isinstance(ei.value, sweep.StoreCorruptError)

    def test_verify_repair_quarantines(self, tmp_path, plans):
        store = sweep.PlanStore(str(tmp_path))
        store.put(plans[0], "good", costs={"size": 1.0})
        store.put(plans[1], "bad", costs={"size": 2.0})
        chaos_inject.corrupt_store_entry(store, "bad")
        # repair=False (default) reports but leaves the store as-is
        problems = store.verify()
        assert len(problems) == 1 and "bad" in problems[0]
        assert store.names() == ["bad", "good"]
        problems = store.verify(repair=True)
        assert len(problems) == 1 and "quarantined" in problems[0]
        qpath = os.path.join(store.entries_dir, "bad.quarantined.json")
        assert os.path.exists(qpath)            # bytes kept for forensics
        assert store.names() == ["good"]        # name gone from the store
        assert not store.has("bad")
        assert store.verify() == []             # clean after repair

    def test_entry_bytes_deterministic(self, tmp_path, plans):
        """put() twice -> byte-identical entry file (no timestamps,
        sorted keys): the foundation of the resume byte-identity."""
        store = sweep.PlanStore(str(tmp_path))
        kw = dict(metrics={"score": 0.5}, costs={"size": 9.0},
                  lineage={"lam": 2.0, "parent": None})
        store.put(plans[0], "a", **kw)
        with open(store._entry_path("a"), "rb") as f:
            first = f.read()
        store.put(plans[0], "a", **kw)
        with open(store._entry_path("a"), "rb") as f:
            assert f.read() == first


# ---------------------------------------------------------------------------
# front math
# ---------------------------------------------------------------------------

class TestFront:
    PTS = [{"score": 0.9, "cost": 100.0, "lam": 1.0},
           {"score": 0.8, "cost": 60.0, "lam": 4.0},
           {"score": 0.7, "cost": 90.0, "lam": 2.0},   # dominated
           {"score": 0.5, "cost": 20.0, "lam": 16.0}]

    def test_dominates(self):
        a, b = self.PTS[1], self.PTS[2]
        assert front_mod.dominates(a, b)
        assert not front_mod.dominates(b, a)
        assert not front_mod.dominates(a, a)

    def test_pareto_front(self):
        fr = front_mod.pareto_front(self.PTS)
        assert [p["lam"] for p in fr] == [16.0, 4.0, 1.0]
        # exact duplicates collapse
        fr2 = front_mod.pareto_front(self.PTS + [dict(self.PTS[0])])
        assert len(fr2) == 3

    def test_largest_gap_and_next_lambda(self):
        fr = front_mod.pareto_front(self.PTS)
        i, gap = front_mod.largest_gap(fr)
        assert 0 <= i < len(fr) - 1 and gap > 0
        lam = front_mod.next_lambda(fr)
        la, lb = fr[i]["lam"], fr[i + 1]["lam"]
        assert lam == pytest.approx((la * lb) ** 0.5)
        assert front_mod.next_lambda(fr[:1]) is None
        # a collapsed front (identical lambdas) yields nothing new
        same = [{"score": 0.5, "cost": 10.0, "lam": 2.0},
                {"score": 0.9, "cost": 90.0, "lam": 2.0}]
        assert front_mod.next_lambda(same) is None

    def test_iso_accuracy(self):
        fr = front_mod.pareto_front(self.PTS)
        # baseline at acc 0.75 / 100 bytes: cheapest front point at
        # >= 0.75 is cost 60 -> 40% reduction
        red = front_mod.iso_accuracy_reduction(fr, 0.75, 100.0)
        assert red == pytest.approx(0.40)
        assert front_mod.iso_accuracy_reduction(fr, 0.99, 100.0) is None
        rep = front_mod.iso_accuracy_report(fr, {"w8": (0.75, 100.0)})
        assert rep["w8"]["reduction_pct"] == pytest.approx(40.0)


# ---------------------------------------------------------------------------
# SweepSpec
# ---------------------------------------------------------------------------

class TestSweepSpec:
    def test_validation(self):
        with pytest.raises(ValueError, match="track"):
            sweep.SweepSpec(track="rnn")
        with pytest.raises(ValueError, match="lams"):
            sweep.SweepSpec(lams=())
        with pytest.raises(ValueError, match="search_steps"):
            sweep.SweepSpec(search_steps=0)
        with pytest.raises(ValueError, match="cost_model"):
            sweep.SweepSpec(track="lm", cost_model="ne16")

    def test_identity(self):
        a = cnn_spec()
        b = sweep.SweepSpec.from_json(a.to_json())
        assert a == b and a.spec_hash() == b.spec_hash()
        assert a.spec_hash() != cnn_spec(search_steps=5).spec_hash()
        assert cnn_spec(warm_search_steps=None).warm_search() == 2
        assert cnn_spec(warm_search_steps=3).warm_search() == 3


# ---------------------------------------------------------------------------
# cnn sweeps: warm start, resume, byte-identity, baselines, obs
# ---------------------------------------------------------------------------

class TestCnnSweep:
    def test_summary_and_lineage(self, cnn_ref):
        runner, store, summary, _ = cnn_ref
        assert summary["executed"] >= 2 and summary["loaded"] == 0
        assert summary["complete"]
        assert summary["steps_saved"] > 0          # warm starts paid off
        entries = store.query(kind="point", sweep="t")
        assert len(entries) == summary["executed"]
        by_name = {e["name"]: e for e in entries}
        p0, p1 = by_name["t.pt00"], by_name["t.pt01"]
        assert not p0["lineage"]["warm"] and p0["lineage"]["parent"] is None
        assert p1["lineage"]["warm"]
        assert p1["lineage"]["parent"] == p0["plan"]   # continuation chain
        assert p1["lineage"]["saved"] == 4 + 2         # warmup + search/2
        assert store.verify() == []
        assert len(store.front()) >= 1

    def test_obs_artifacts(self, cnn_ref, tmp_path):
        _, _, summary, ob = cnn_ref
        mpath, tpath = str(tmp_path / "s.prom"), str(tmp_path / "s.jsonl")
        obs.write_prometheus(ob.registry, mpath)
        obs.write_trace(ob.tracer, tpath)
        assert obs_validate.validate_files(mpath, tpath, SCHEMA) == []
        text = open(mpath).read()
        assert 'sweep_points_completed_total{source="run"}' in text
        assert "sweep_warm_starts_total" in text
        assert "sweep_search_steps_total" in text
        assert "sweep_front_size" in text

    def test_store_resume_is_free_and_identical(self, cnn_ref, tmp_path):
        runner, store, summary, _ = cnn_ref
        before = store_fingerprint(store)
        runner2 = sweep.SweepRunner(
            runner.spec, store,
            os.path.join(str(tmp_path), "other_work"), verbose=False)
        s2 = runner2.run()
        assert s2["executed"] == 0
        assert s2["loaded"] == summary["executed"]
        assert s2["points"] == summary["points"]
        assert store_fingerprint(store) == before

    def test_spec_mismatch_guard(self, cnn_ref, tmp_path):
        _, store, _, _ = cnn_ref
        other = sweep.SweepRunner(
            cnn_spec(search_steps=5), store,
            os.path.join(str(tmp_path), "w"), verbose=False)
        with pytest.raises(sweep.StoreError,
                           match="different SweepSpec"):
            other.run()

    def test_kill_resume_byte_identical(self, cnn_ref, tmp_path):
        """The acceptance criterion: kill mid-point, resume, and the
        final store is byte-identical (entry bytes, plan hashes, front)
        to the uninterrupted run's."""
        _, ref_store, _, _ = cnn_ref

        class Boom(api.Hook):
            def __init__(self):
                self.finetunes, self.armed = 0, True

            def on_phase_start(self, phase, state):
                if phase.name == "finetune":
                    self.finetunes += 1

            def on_step(self, phase, state, step, metrics, train_state):
                if self.armed and phase.name == "finetune" \
                        and self.finetunes == 2:
                    self.armed = False
                    raise RuntimeError("boom")

        root = str(tmp_path / "killed")
        with pytest.raises(RuntimeError, match="boom"):
            run_sweep(cnn_spec(), root, hooks=(Boom(),))
        killed = sweep.PlanStore(os.path.join(root, "store"))
        assert killed.names() == ["t.pt00"]        # pt01 died in flight
        # resume against the same store+workdir: pt00 loads, pt01
        # restarts from its checkpoint mid-point
        _, store, s2, _ = run_sweep(cnn_spec(), root)
        assert s2["loaded"] == 1 and s2["executed"] >= 1
        assert store_fingerprint(store) == store_fingerprint(ref_store)

    def test_corrupt_entry_resume_byte_identical(self, cnn_ref,
                                                 tmp_path):
        """The robustness criterion: corrupt a completed point's entry
        (the ``store_corrupt`` fault), resume, and the runner
        quarantines the bad bytes and recomputes the point -- ending
        byte-identical (entry bytes, plan hashes, front) to the
        uninterrupted run's store."""
        _, ref_store, _, _ = cnn_ref
        root = str(tmp_path / "corrupted")
        run_sweep(cnn_spec(), root)
        store = sweep.PlanStore(os.path.join(root, "store"))
        victim = store.names()[0]
        chaos_inject.corrupt_store_entry(store, victim)
        with pytest.raises(sweep.StoreCorruptError):
            store.entry(victim)
        # resume must NOT die on the corrupt entry: quarantine + redo
        _, store, s2, _ = run_sweep(cnn_spec(), root)
        assert s2["executed"] >= 1              # the victim recomputed
        assert os.path.exists(os.path.join(
            store.entries_dir, f"{victim}.quarantined.json"))
        assert store.verify() == []
        assert store_fingerprint(store) == store_fingerprint(ref_store)

    def test_max_points_budget(self, tmp_path):
        root = str(tmp_path)
        _, store, s1, _ = run_sweep(cnn_spec(adaptive_points=0), root,
                                    max_points=1)
        assert s1["executed"] == 1 and not s1["complete"]
        assert store.names() == ["t.pt00"]
        _, store, s2, _ = run_sweep(cnn_spec(adaptive_points=0), root)
        assert s2["loaded"] == 1 and s2["executed"] == 1
        assert s2["complete"]

    def test_baselines_and_iso_report(self, cnn_ref):
        runner, store, _, _ = cnn_ref
        for bits in (8, 2):
            runner.baseline(bits)
        e8 = store.entry("t.w8ref")
        assert e8["lineage"]["kind"] == "baseline"
        assert e8["lineage"]["bits"] == 8
        # a fixed 8-bit reference quantizes nothing away: its plan is
        # all-8-bit, so it must cost more than the 2-bit one
        assert e8["costs"]["size"] > store.entry("t.w2ref")["costs"]["size"]
        rep = runner.iso_report(baseline_bits=(8, 2))
        for label in ("w8", "w2"):
            assert {"baseline_score", "baseline_cost",
                    "reduction", "reduction_pct"} <= set(rep[label])

    def test_missing_handoff_message(self, tmp_path):
        runner = sweep.SweepRunner(
            cnn_spec(), sweep.PlanStore(str(tmp_path / "s")),
            str(tmp_path / "w"), verbose=False)
        with pytest.raises(sweep.StoreError, match="warm start"):
            runner._load_handoff(0, {"x": np.zeros(1)})


# ---------------------------------------------------------------------------
# lm track: sweeps the fleet can serve
# ---------------------------------------------------------------------------

class TestLmSweep:
    def test_summary_and_plans_bind(self, lm_ref, llama):
        _, store, summary, _ = lm_ref
        cfg, params = llama
        assert summary["executed"] == 2 and summary["complete"]
        for e in store.query(kind="point"):
            plan = store.get(e["plan"])
            # strict bind: the plan covers exactly the arch's servable
            # weight groups, and apply_plan accepts it
            assert set(plan.channel_bits) \
                == set(lm.serve_weight_groups(cfg, params))
            engine.apply_plan(cfg, params, plan)
            assert e["costs"]["size"] > 0
        assert store.verify() == []

    def test_kill_resume_byte_identical(self, lm_ref, tmp_path):
        _, ref_store, _, _ = lm_ref

        class Boom(api.Hook):
            def __init__(self):
                self.armed = True

            def on_step(self, phase, state, step, metrics, train_state):
                if self.armed and phase.name == "lm_search" and step == 2:
                    self.armed = False
                    raise RuntimeError("boom")

        root = str(tmp_path)
        with pytest.raises(RuntimeError, match="boom"):
            run_sweep(lm_spec(), root, hooks=(Boom(),))
        # pt00 died at step 2 with a step-1 checkpoint behind it
        _, store, s2, _ = run_sweep(lm_spec(), root)
        assert s2["executed"] == 2 and s2["loaded"] == 0
        assert store_fingerprint(store) == store_fingerprint(ref_store)

    def test_fleet_store_tiers(self, lm_ref, llama):
        _, store, _, _ = lm_ref
        cfg, params = llama
        tiers = build_tiers(f"store:{store.root}", cfg, params, 8.0)
        front = store.front(store.query(kind="point"))
        assert [t.name for t in tiers] == [e["name"] for e in front]
        for t in tiers:
            assert 0 < t.quality <= 16.0 and t.step_ms <= 8.0
        # single-entry form
        name = front[0]["name"]
        only = build_tier(f"store:{store.root}/{name}", cfg, params, 8.0)
        assert only.name == name and only.quality == tiers[0].quality
        if len(tiers) > 1:
            with pytest.raises(ValueError, match="expands to"):
                build_tier(f"store:{store.root}", cfg, params, 8.0)
        # and the fleet serves them end to end
        flt = build_fleet(cfg, params, ["float", f"store:{store.root}"],
                          policy="round_robin", max_len=32, max_batch=2,
                          cache="paged", page_size=8, pages=None,
                          base_step_ms=8.0)
        assert len(flt.replicas) == 1 + len(tiers)
        trace = fleet_mod.poisson_trace(
            3, rate_rps=100.0, vocab=cfg.vocab, prompt_len=4,
            max_tokens=3, deadline_ms=None, seed=0)
        records = flt.run(trace)
        assert all(r.status == "finished" for r in records.values())

    def test_store_tier_errors(self, llama, tmp_path):
        cfg, params = llama
        with pytest.raises(sweep.StoreError, match="not a PlanStore"):
            build_tiers(f"store:{tmp_path}/nope", cfg, params, 8.0)
        empty = sweep.PlanStore(str(tmp_path / "empty"))
        os.makedirs(empty.entries_dir)
        with pytest.raises(sweep.StoreError, match="no entries"):
            build_tiers(f"store:{empty.root}", cfg, params, 8.0)

    def test_provenance_round_trip(self, lm_ref):
        """save -> store -> load -> tier_from_plan keeps the quality
        signal consistent with the stored plan's mean bits."""
        _, store, _, _ = lm_ref
        for e in store.query(kind="point"):
            plan = store.get(e["plan"])
            tier = fleet_mod.tier_from_plan(e["name"], plan,
                                            base_step_ms=8.0)
            assert tier.quality == pytest.approx(
                fleet_mod.plan_mean_bits(plan))
            assert tier.plan.equals(plan)
            # lineage survives: the entry still knows its lambda and
            # parent after the full round trip
            assert "lam" in e["lineage"] and "parent" in e["lineage"]


# ---------------------------------------------------------------------------
# sweep trace grammar
# ---------------------------------------------------------------------------

class TestSweepTraceGrammar:
    @pytest.mark.parametrize("kinds", [
        ["point_enqueued"],
        ["point_enqueued", "point_loaded"],
        ["point_enqueued", "point_started"],
        ["point_enqueued", "point_started", "point_finished"],
    ])
    def test_valid(self, kinds):
        assert RequestTracer.check_lifecycle(kinds) is None

    @pytest.mark.parametrize("kinds", [
        ["point_started"],
        ["point_enqueued", "point_finished"],
        ["point_enqueued", "point_loaded", "point_started"],
        ["point_enqueued", "point_started", "point_finished",
         "point_started"],
        ["point_enqueued", "admitted"],
        ["enqueued", "point_started"],
    ])
    def test_invalid(self, kinds):
        assert RequestTracer.check_lifecycle(kinds) is not None
