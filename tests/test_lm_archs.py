"""Per-architecture smoke tests (reduced configs, CPU): forward + one train
step (shapes + no NaNs), decode vs full-forward consistency, and the MPS
search mode on the LM."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import SHAPES, cell_applicable
from repro.core import mps
from repro.models import lm
from repro.optim import optimizers

ARCHS = list(registry.ARCHS)


def _batch(cfg, b=2, s=64, key=0):
    toks = jax.random.randint(jax.random.key(key), (b, s + 1), 0, cfg.vocab)
    batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
    if cfg.frontend != "none":
        batch = {"embeddings": 0.1 * jax.random.normal(
            jax.random.key(key + 1), (b, s, cfg.d_model), jnp.bfloat16),
            "targets": toks[:, 1:]}
    if cfg.is_encdec:
        batch["enc_embeddings"] = 0.1 * jax.random.normal(
            jax.random.key(key + 2), (b, 32, cfg.d_model))
        batch.setdefault("tokens", toks[:, :-1])
    return batch


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_and_train_step(self, arch):
        cfg = registry.reduced(registry.ARCHS[arch])
        params = lm.init_params(cfg, jax.random.key(0))
        batch = _batch(cfg)
        logits, _ = lm.forward(cfg, params, batch, mode="train")
        assert logits.shape == (2, 64, lm.padded_vocab(cfg))
        assert not bool(jnp.any(jnp.isnan(
            logits.astype(jnp.float32))))
        # one full train step reduces nothing but must run + stay finite
        opt = optimizers.make_optimizer("adam", 1e-3)
        state = opt.init(params)
        loss, grads = jax.value_and_grad(
            lambda p: lm.loss_fn(cfg, p, batch))(params)
        new_params, _ = opt.update(grads, state, params, 0)
        assert np.isfinite(float(loss))
        assert all(bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))
                   for x in jax.tree.leaves(new_params))

    def test_decode_step_runs(self, arch):
        cfg = registry.reduced(registry.ARCHS[arch])
        params = lm.init_params(cfg, jax.random.key(0))
        caches = lm.init_caches(cfg, 2, 64, enc_len=32)
        tok = {"tokens": jnp.ones((2, 1), jnp.int32) * 3}
        if cfg.frontend != "none":
            tok = {"embeddings": jnp.ones((2, 1, cfg.d_model),
                                          jnp.bfloat16) * 0.1}
        logits, new_caches = lm.decode_step(cfg, params, tok, caches,
                                            jnp.asarray(5))
        assert logits.shape[0:2] == (2, 1)
        assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))

    def test_applicability_matrix(self, arch):
        cfg = registry.ARCHS[arch]
        ok_train, _ = cell_applicable(cfg, SHAPES["train_4k"])
        assert ok_train
        ok_long, reason = cell_applicable(cfg, SHAPES["long_500k"])
        assert ok_long == cfg.sub_quadratic
        if not ok_long:
            assert "sub-quadratic" in reason


class TestDecodeConsistency:
    """Strong correctness check: token-by-token decode with caches must
    reproduce the full-sequence forward logits."""

    @pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-780m",
                                      "gemma2-2b", "qwen3-32b",
                                      "jamba-1.5-large-398b"])
    def test_decode_matches_forward(self, arch):
        cfg = registry.reduced(registry.ARCHS[arch])
        params = lm.init_params(cfg, jax.random.key(0))
        b, s = 2, 32
        toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab)
        full_logits, _ = lm.forward(cfg, params, {"tokens": toks},
                                    mode="train")
        caches = lm.init_caches(cfg, b, s)
        outs = []
        for i in range(s):
            logits_i, caches = lm.decode_step(
                cfg, params, {"tokens": toks[:, i:i + 1]}, caches,
                jnp.asarray(i))
            outs.append(logits_i[:, 0])
        dec_logits = jnp.stack(outs, axis=1)
        f = np.asarray(full_logits.astype(jnp.float32))
        d = np.asarray(dec_logits.astype(jnp.float32))
        # bf16 activations + different reduction orders: compare loosely
        # but element-wise over the whole sequence
        np.testing.assert_allclose(d, f, atol=0.15, rtol=0.05)


class TestLMSearchMode:
    def test_gamma_grads_and_cost(self):
        cfg = registry.reduced(registry.ARCHS["llama3.2-1b"])
        params = lm.init_params(cfg, jax.random.key(0), mps_on=True)
        batch = _batch(cfg)
        ctx = mps.SearchCtx(tau=1.0)
        loss = lm.loss_fn(cfg, params, batch, ctx=ctx, lam=1e-6)
        assert np.isfinite(float(loss))
        grads = jax.grad(lambda p: lm.loss_fn(cfg, p, batch, ctx=ctx,
                                              lam=1e-6))(params)
        gamma_leaves = [
            x for path, x in
            jax.tree_util.tree_flatten_with_path(grads)[0]
            if any(getattr(p, "key", None) == "gamma" for p in path)]
        assert len(gamma_leaves) == lm.mps_param_count(cfg)
        assert all(bool(jnp.any(g != 0)) for g in gamma_leaves)

    def test_size_cost_monotone_in_selected_bits(self):
        cfg = registry.reduced(registry.ARCHS["llama3.2-1b"])
        params = lm.init_params(cfg, jax.random.key(0), mps_on=True)
        ctx = mps.SearchCtx(tau=0.01)   # ~hard selection

        def force(params, idx):
            def visit(node):
                if isinstance(node, dict):
                    if "gamma" in node:
                        g = jnp.full_like(node["gamma"], -40.0)
                        node["gamma"] = g.at[..., idx].set(40.0)
                    for v in node.values():
                        visit(v)
            import copy
            p2 = jax.tree.map(lambda x: x, params)
            visit(p2)
            return p2

        c8 = float(lm.mps_size_cost(cfg, force(params, 3), ctx))
        c2 = float(lm.mps_size_cost(cfg, force(params, 1), ctx))
        c0 = float(lm.mps_size_cost(cfg, force(params, 0), ctx))
        assert c8 > c2 > c0
        assert c0 < 0.01 * c8


class TestPatterns:
    def test_jamba_pattern_1_to_7_with_alternating_moe(self):
        cfg = registry.ARCHS["jamba-1.5-large-398b"]
        pat = lm.block_pattern(cfg)
        assert len(pat) == 8
        assert sum(1 for p in pat if p.mixer == "attn") == 1
        assert sum(1 for p in pat if p.mixer == "mamba") == 7
        assert sum(1 for p in pat if p.ffn == "moe") == 4
        assert cfg.n_layers % len(pat) == 0

    def test_gemma2_alternates_local_global(self):
        pat = lm.block_pattern(registry.ARCHS["gemma2-2b"])
        assert [p.mixer for p in pat] == ["attn_local", "attn"]

    def test_llama4_chunked_every_4th_full(self):
        pat = lm.block_pattern(registry.ARCHS["llama4-scout-17b-a16e"])
        assert [p.mixer for p in pat] == ["attn_chunked"] * 3 + ["attn"]
        assert all(p.ffn == "moe" for p in pat)

    def test_vocab_padding(self):
        cfg = registry.ARCHS["mamba2-780m"]
        assert lm.padded_vocab(cfg) % 256 == 0
        assert lm.padded_vocab(cfg) >= cfg.vocab

    def test_param_counts_near_nominal(self):
        """Sanity: constructed parameter totals are near the named sizes."""
        expect = {"llama3.2-1b": (1.0e9, 1.6e9),
                  "mamba2-780m": (0.6e9, 1.0e9),
                  "qwen3-32b": (28e9, 36e9),
                  "jamba-1.5-large-398b": (330e9, 460e9),
                  "qwen2-vl-72b": (65e9, 80e9)}
        for name, (lo, hi) in expect.items():
            cfg = registry.ARCHS[name]
            tree = lm.abstract_params(cfg)
            n = sum(int(np.prod(x.shape))
                    for path, x in
                    jax.tree_util.tree_flatten_with_path(tree)[0]
                    if not any(getattr(p, "key", None) == "gamma"
                               for p in path))
            assert lo < n < hi, (name, n / 1e9)
