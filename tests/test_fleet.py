"""Fleet subsystem tests: tier modeling, routing policies (round-robin
parity, least-loaded balance, Pareto degrade + recovery), deadline
admission with timeout-retry, preemption inside a fleet replica, the
open-loop load generators, the SLO report, merged obs artifacts through
the validator, and the pareto-vs-static overload headline the bench
asserts."""
import json

import jax
import numpy as np
import pytest

from repro import fleet as fleet_mod
from repro import obs
from repro.configs import registry
from repro.launch.fleet import build_fleet, build_tier
from repro.models import lm
from repro.obs import validate as obs_validate
from repro.serve import engine
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import Request


@pytest.fixture(scope="module")
def llama():
    cfg = registry.get("llama3.2-1b-smoke")
    return cfg, lm.init_params(cfg, jax.random.key(0))


@pytest.fixture(scope="module")
def two_tier(llama):
    """One float + one mixed-plan replica, reused across tests via
    set_policy() (each run() opens fresh sessions on both engines)."""
    cfg, params = llama
    return build_fleet(cfg, params, ["float", "demo"],
                       policy="round_robin", max_len=64, max_batch=2,
                       cache="paged", page_size=8, pages=None,
                       base_step_ms=8.0)


def _trace(cfg, n, *, max_tokens=6, deadline_ms=None, rate=200.0,
           seed=0, **kw):
    return fleet_mod.poisson_trace(
        n, rate_rps=rate, vocab=cfg.vocab, prompt_len=6,
        max_tokens=max_tokens, deadline_ms=deadline_ms, seed=seed, **kw)


def _solo(rep, request):
    """The parity oracle: the landing replica's own engine serving the
    request alone (token streams are batch/backend-invariant, so this is
    the byte-identical reference for any fleet routing)."""
    return rep.server.serve([request])[request.uid]


# ---------------------------------------------------------------------------
# tiers
# ---------------------------------------------------------------------------

class TestTiers:
    def test_float_tier_is_16_bits_at_base_cost(self):
        tier = fleet_mod.tier_from_plan("float", None, base_step_ms=8.0)
        assert tier.quality == 16.0
        assert tier.step_ms == pytest.approx(8.0)

    def test_quantized_tiers_are_cheaper_and_ordered(self, llama):
        cfg, params = llama
        t8 = build_tier("w8", cfg, params, 8.0)
        t2 = build_tier("w2", cfg, params, 8.0)
        assert t8.quality == pytest.approx(8.0)
        assert t2.quality == pytest.approx(2.0)
        # cost model: fixed floor + bits-linear traffic term
        assert 8.0 > t8.step_ms > t2.step_ms > 0.25 * 8.0
        assert t8.step_ms == pytest.approx(8.0 * (0.25 + 0.75 * 0.5))

    def test_mean_bits_counts_pruned_channels(self, llama):
        cfg, params = llama
        plan = engine.synthetic_plan(cfg, params, bits=None, seed=0)
        bits = fleet_mod.plan_mean_bits(plan)
        assert 0.0 < bits < 16.0       # mixed plan: some 0-bit channels

    def test_duplicate_tier_names_rejected(self, llama):
        cfg, params = llama
        with pytest.raises(ValueError):
            build_fleet(cfg, params, ["float", "float"],
                        policy="round_robin", max_len=32, max_batch=1,
                        cache="dense", page_size=8, pages=None,
                        base_step_ms=8.0)


# ---------------------------------------------------------------------------
# routers
# ---------------------------------------------------------------------------

class TestRouters:
    def test_make_router_rejects_unknown(self, two_tier):
        with pytest.raises(ValueError):
            fleet_mod.make_router("fastest_first", two_tier)
        with pytest.raises(KeyError):
            fleet_mod.make_router("static:nope", two_tier)

    def test_round_robin_parity_and_full_drain(self, two_tier, llama):
        cfg, _ = llama
        two_tier.set_policy("round_robin")
        trace = _trace(cfg, 6)
        records = two_tier.run(trace)
        assert len(records) == 6
        assert all(r.status == "finished" for r in records.values())
        # cyclic assignment across both tiers
        tiers = [records[fr.uid].replica for fr in trace]
        assert tiers == ["float", "demo"] * 3
        # token parity: the fleet stream is byte-identical to a solo
        # serve of the same request on the landing replica's engine
        for fr in trace:
            rec = records[fr.uid]
            rep = two_tier.replica_by_name(rec.replica)
            np.testing.assert_array_equal(rec.tokens,
                                          _solo(rep, fr.request))

    def test_least_loaded_parity_and_balance(self, two_tier, llama):
        cfg, _ = llama
        two_tier.set_policy("least_loaded")
        # a synchronized burst: load-aware routing must spread it
        trace = fleet_mod.burst_trace(1, 8, burst_every_ms=1.0,
                                      vocab=cfg.vocab, prompt_len=6,
                                      max_tokens=6)
        records = two_tier.run(trace)
        assert all(r.status == "finished" for r in records.values())
        by_tier = {name: sum(r.replica == name
                             for r in records.values())
                   for name in ("float", "demo")}
        assert by_tier["float"] == by_tier["demo"] == 4
        for fr in trace:
            rec = records[fr.uid]
            rep = two_tier.replica_by_name(rec.replica)
            np.testing.assert_array_equal(rec.tokens,
                                          _solo(rep, fr.request))

    def test_pareto_degrade_under_load_then_recovery(self, two_tier,
                                                     llama):
        cfg, _ = llama
        two_tier.set_policy("pareto_degrade")
        # low load, generous deadline: full quality, nothing degraded
        records = two_tier.run(_trace(cfg, 2, rate=5.0,
                                      deadline_ms=500.0))
        assert all(r.replica == "float" and not r.degraded
                   for r in records.values())
        # a tight-deadline burst: the float tier's predicted queue wait
        # blows the deadline for later arrivals, which slide down the
        # Pareto front instead of missing
        burst = fleet_mod.burst_trace(1, 8, burst_every_ms=1.0,
                                      vocab=cfg.vocab, prompt_len=6,
                                      max_tokens=6, deadline_ms=120.0)
        records = two_tier.run(burst)
        used = {r.replica for r in records.values() if r.replica}
        assert "demo" in used          # degrade engaged
        assert any(r.degraded for r in records.values())
        # recovery: with the backlog drained, deadline-carrying requests
        # ride the top tier again
        records = two_tier.run(_trace(cfg, 2, rate=5.0,
                                      deadline_ms=500.0, seed=3))
        assert all(r.replica == "float" and not r.degraded
                   for r in records.values())

    def test_pareto_sheds_when_hopeless(self, two_tier, llama):
        cfg, _ = llama
        two_tier.set_policy("pareto_degrade")
        # even the cheapest tier needs ~6 steps for 6 tokens: a 1 ms
        # deadline is infeasible everywhere -> shed at routing
        records = two_tier.run(_trace(cfg, 2, deadline_ms=1.0))
        assert all(r.status == "shed" for r in records.values())
        assert all(r.tokens is None for r in records.values())
        snap = two_tier.metrics_snapshot()["metrics"]
        (serie,) = snap["fleet_shed_total"]["series"]
        assert serie["value"] >= 2.0


# ---------------------------------------------------------------------------
# deadlines, retries, preemption
# ---------------------------------------------------------------------------

class TestDeadlines:
    def test_timeout_retry_lands_elsewhere_with_parity(self, two_tier,
                                                       llama):
        """max_batch=2 x 2 replicas, 5 simultaneous arrivals: the 5th
        queues behind a full fleet, times out in queue (deadline 40 ms
        < the ~48 ms drain), and its retry must re-route, finish, and
        stream byte-identically -- while the SLO verdict still judges
        the ORIGINAL promise (a late retry is a miss, not a met)."""
        cfg, _ = llama
        two_tier.set_policy("round_robin")
        sp = SamplingParams(max_tokens=6)
        rng = np.random.default_rng(7)
        mk = lambda uid: Request(
            uid=uid, sampling=sp,
            prompt=rng.integers(0, cfg.vocab, size=6).astype(np.int32))
        trace = [fleet_mod.FleetRequest(request=mk(uid))
                 for uid in range(4)]
        victim = fleet_mod.FleetRequest(request=mk(4), deadline_ms=40.0,
                                        retry_budget=1)
        records = two_tier.run(trace + [victim])
        rec = records[4]
        assert rec.status == "finished"
        assert rec.fr.retries_used == 1
        assert [a.cause for a in rec.attempts] == ["arrival",
                                                   "retry:timeout"]
        # attempt deadlines refresh on retry, the SLA does not
        assert rec.sla_deadline_abs == pytest.approx(40.0)
        assert rec.finish_ms > rec.sla_deadline_abs
        assert not rec.deadline_met
        rep = two_tier.replica_by_name(rec.replica)
        np.testing.assert_array_equal(rec.tokens, _solo(rep, rec.fr.request))
        # the timeout cancellation is visible in the shared registry
        snap = two_tier.metrics_snapshot()["metrics"]
        assert any(s["value"] >= 1.0
                   for s in snap["fleet_timeouts_total"]["series"])

    def test_exhausted_retry_budget_is_terminal(self, two_tier, llama):
        cfg, _ = llama
        two_tier.set_policy("round_robin")
        # deadline shorter than any possible service time: every attempt
        # times out, the budget runs dry, the request ends 'timeout'
        trace = _trace(cfg, 3, deadline_ms=10.0, retry_budget=1)
        records = two_tier.run(trace)
        assert all(r.status == "timeout" for r in records.values())
        assert all(r.fr.retries_used == 1 for r in records.values())
        assert all(not r.deadline_met for r in records.values())

    def test_preemption_inside_a_replica_keeps_parity(self, llama):
        """A page pool too small for the whole batch forces preemption
        inside the fleet replica; with budget to spare the request rides
        it out and the stream still matches the solo oracle."""
        cfg, params = llama
        flt = build_fleet(cfg, params, ["float"], policy="round_robin",
                          max_len=32, max_batch=2, cache="paged",
                          page_size=4, pages=7, base_step_ms=8.0)
        sp = SamplingParams(max_tokens=8)
        rng = np.random.default_rng(3)
        trace = [fleet_mod.FleetRequest(
            request=Request(uid=i, sampling=sp,
                            prompt=rng.integers(0, cfg.vocab, size=6)
                            .astype(np.int32)),
            preempt_budget=10)
            for i in range(2)]
        records = flt.run(trace)
        rep = flt.replicas[0]
        assert rep.server.stats["preemptions"] > 0
        assert all(r.status == "finished" for r in records.values())
        for rec in records.values():
            np.testing.assert_array_equal(rec.tokens,
                                          _solo(rep, rec.fr.request))

    def test_preempt_budget_eviction_retries(self, llama):
        """preempt_budget=0: the first preemption evicts (cancelled +
        freed pages) and the retry budget re-dispatches."""
        cfg, params = llama
        flt = build_fleet(cfg, params, ["float"], policy="round_robin",
                          max_len=32, max_batch=2, cache="paged",
                          page_size=4, pages=7, base_step_ms=8.0)
        sp = SamplingParams(max_tokens=8)
        rng = np.random.default_rng(3)
        trace = [fleet_mod.FleetRequest(
            request=Request(uid=i, sampling=sp,
                            prompt=rng.integers(0, cfg.vocab, size=6)
                            .astype(np.int32)),
            preempt_budget=0, retry_budget=2)
            for i in range(2)]
        records = flt.run(trace)
        assert all(r.status == "finished" for r in records.values())
        assert sum(r.fr.retries_used for r in records.values()) >= 1
        causes = [a.cause for r in records.values() for a in r.attempts]
        assert "retry:preempt" in causes
        snap = flt.metrics_snapshot()["metrics"]
        assert any(s["value"] >= 1.0
                   for s in snap["fleet_cancelled_total"]["series"])


# ---------------------------------------------------------------------------
# load generation + SLO report
# ---------------------------------------------------------------------------

class TestLoadgen:
    def test_poisson_trace_deterministic_and_open_loop(self):
        a = fleet_mod.poisson_trace(6, rate_rps=100.0, vocab=64, seed=5)
        b = fleet_mod.poisson_trace(6, rate_rps=100.0, vocab=64, seed=5)
        assert [fr.arrival_ms for fr in a] == [fr.arrival_ms for fr in b]
        for fa, fb in zip(a, b):
            np.testing.assert_array_equal(fa.request.prompt,
                                          fb.request.prompt)
        c = fleet_mod.poisson_trace(6, rate_rps=100.0, vocab=64, seed=6)
        assert [fr.arrival_ms for fr in a] != [fr.arrival_ms for fr in c]
        arr = [fr.arrival_ms for fr in a]
        assert arr == sorted(arr) and arr[0] > 0.0
        with pytest.raises(ValueError):
            fleet_mod.poisson_trace(3, rate_rps=0.0, vocab=64)

    def test_burst_trace_shape(self):
        t = fleet_mod.burst_trace(3, 4, burst_every_ms=50.0, vocab=64)
        assert len(t) == 12
        assert [fr.arrival_ms for fr in t] == sum(
            [[50.0 * b] * 4 for b in range(3)], [])
        assert len({fr.uid for fr in t}) == 12

    def test_slo_report_counts(self, two_tier, llama):
        cfg, _ = llama
        two_tier.set_policy("round_robin")
        records = two_tier.run(_trace(cfg, 4, deadline_ms=1000.0))
        rep = fleet_mod.slo_report(two_tier, records)
        assert rep["requests"] == 4
        assert rep["status"]["finished"] == 4
        assert rep["deadline_attainment"] == 1.0
        assert rep["ttft_ms"]["p50"] is not None
        per = rep["per_tier"]
        assert per["float"]["requests"] + per["demo"]["requests"] == 4
        assert per["float"]["deadline_attainment"] == 1.0

    def test_duplicate_uids_rejected(self, two_tier, llama):
        cfg, _ = llama
        t = _trace(cfg, 2)
        t2 = _trace(cfg, 2)            # same uids
        with pytest.raises(ValueError):
            two_tier.run(t + t2)


# ---------------------------------------------------------------------------
# observability through the fleet
# ---------------------------------------------------------------------------

class TestFleetObs:
    def test_merged_trace_and_metrics_validate(self, two_tier, llama,
                                               tmp_path):
        cfg, _ = llama
        two_tier.set_policy("round_robin")
        records = two_tier.run(_trace(cfg, 5, deadline_ms=1000.0))
        assert all(r.status == "finished" for r in records.values())
        evs = two_tier.trace_events()
        # globally ordered, replica-tagged, one complete lifecycle per
        # uid within its replica's event stream
        assert all(e1["t"] <= e2["t"] for e1, e2 in zip(evs, evs[1:]))
        assert {e["replica"] for e in evs} == {"float", "demo"}
        for uid in {e["uid"] for e in evs}:
            kinds = [e["kind"] for e in evs if e["uid"] == uid]
            assert obs.RequestTracer.check_lifecycle(kinds) is None
        mpath, tpath = tmp_path / "f.prom", tmp_path / "f.jsonl"
        obs.write_prometheus(two_tier.registry, str(mpath))
        two_tier.write_trace(str(tpath))
        assert obs_validate.validate_files(
            str(mpath), str(tpath), "tests/obs_schema.json") == []
        # per-replica queue series live in the one shared registry
        snap = two_tier.registry.snapshot()
        reps = {s["labels"]["replica"]
                for s in snap["serve_queue_depth"]["series"]}
        assert reps == {"float", "demo"}

    def test_timeout_terminal_in_trace(self, two_tier, llama):
        cfg, _ = llama
        two_tier.set_policy("round_robin")
        two_tier.run(_trace(cfg, 2, deadline_ms=10.0, retry_budget=0))
        kinds = {e["kind"] for e in two_tier.trace_events()}
        assert "timeout" in kinds and "finished" not in kinds


# ---------------------------------------------------------------------------
# the bench's headline: pareto_degrade beats static single-tier
# ---------------------------------------------------------------------------

class TestParetoHeadline:
    def test_pareto_beats_static_float_under_overload(self, two_tier,
                                                      llama):
        cfg, _ = llama
        mk = lambda: fleet_mod.burst_trace(
            1, 10, burst_every_ms=1.0, vocab=cfg.vocab, prompt_len=6,
            max_tokens=6, deadline_ms=120.0, seed=1)
        atts = {}
        for policy in ("static:float", "pareto_degrade"):
            two_tier.set_policy(policy)
            report = fleet_mod.slo_report(two_tier, two_tier.run(mk()))
            atts[policy] = report["deadline_attainment"]
        assert atts["pareto_degrade"] > atts["static:float"]
