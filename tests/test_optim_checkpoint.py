"""Optimizers, schedules, gradient utilities, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager
from repro.optim import grad as gradlib
from repro.optim import optimizers, schedules


def _quadratic_problem():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3), "b": jnp.zeros(())}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2) + (p["b"] - 0.5) ** 2
    return params, loss


class TestOptimizers:
    @pytest.mark.parametrize("name", ["sgd", "adam", "adam_int8"])
    def test_converges_on_quadratic(self, name):
        params, loss = _quadratic_problem()
        opt = optimizers.make_optimizer(name, 0.05)
        state = opt.init(params)
        for step in range(400):
            g = jax.grad(loss)(params)
            params, state = opt.update(g, state, params, step)
        assert float(loss(params)) < 1e-2, name

    def test_adam_int8_tracks_adam(self):
        params, loss = _quadratic_problem()
        p1, p2 = params, params
        o1 = optimizers.adam(0.05)
        o2 = optimizers.adam_int8(0.05)
        s1, s2 = o1.init(p1), o2.init(p2)
        for step in range(50):
            p1, s1 = o1.update(jax.grad(loss)(p1), s1, p1, step)
            p2, s2 = o2.update(jax.grad(loss)(p2), s2, p2, step)
        # int8 moment noise: expect trajectory agreement within ~10%
        np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                                   atol=0.15)

    def test_int8_state_memory_layout(self):
        params = {"w": jnp.zeros((8, 64))}
        st = optimizers.adam_int8(1e-3).init(params)
        assert st["w"]["mq"].dtype == jnp.int8
        assert st["w"]["mq"].shape == (8, 64)
        assert st["w"]["ms"].shape == (8,)      # per-row scales

    def test_multi_optimizer_routes(self):
        params = {"net": {"w": jnp.ones(4)}, "mps": {"g": jnp.ones(4)}}

        def part(path, _leaf):
            return "mps" if any(getattr(p, "key", None) == "mps"
                                for p in path) else "net"

        opt = optimizers.multi_optimizer(part, {
            "net": optimizers.sgd(1.0),
            "mps": optimizers.sgd(0.0)})   # frozen selection params
        state = opt.init(params)
        grads = jax.tree.map(jnp.ones_like, params)
        new_p, _ = opt.update(grads, state, params, 0)
        assert float(new_p["net"]["w"][0]) == 0.0     # moved by lr=1
        assert float(new_p["mps"]["g"][0]) == 1.0     # frozen

    def test_state_logical_axes_structure(self):
        logical = {"w": ("embed", "mlp")}
        sl = optimizers.state_logical_axes("adam_int8", logical)
        assert sl["w"]["mq"] == ("embed", "mlp")
        assert sl["w"]["ms"] == ("embed",)
        sl2 = optimizers.state_logical_axes("adam", logical)
        assert sl2["m"]["w"] == ("embed", "mlp")


class TestSchedules:
    def test_wsd_shape(self):
        fn = schedules.wsd(1.0, 1000, warmup_frac=0.1, decay_frac=0.2)
        assert float(fn(0)) < 0.02
        assert np.isclose(float(fn(500)), 1.0)
        assert float(fn(999)) < 0.05

    def test_step_decay_paper_gsc(self):
        fn = schedules.step_decay(1.0, (50, 100, 150), (0.5, 0.5, 0.4))
        assert np.isclose(float(fn(49)), 1.0)
        assert np.isclose(float(fn(50)), 0.5)
        assert np.isclose(float(fn(100)), 0.25)
        assert np.isclose(float(fn(150)), 0.1)

    def test_cosine_endpoints(self):
        fn = schedules.cosine(2.0, 100, warmup_steps=10)
        assert float(fn(10)) == pytest.approx(2.0, rel=1e-3)
        assert float(fn(100)) < 1e-2


class TestGradUtils:
    def test_clip_by_global_norm(self):
        tree = {"a": jnp.ones(100) * 10}
        clipped, norm = gradlib.clip_by_global_norm(tree, 1.0)
        assert float(norm) == pytest.approx(100.0)
        assert float(gradlib.global_norm(clipped)) == pytest.approx(
            1.0, rel=1e-5)

    def test_ef_compression_error_feedback(self):
        """With error feedback, repeated compression of a constant gradient
        has vanishing average error (the residual is carried)."""
        g = {"w": jnp.asarray([1e-3, 0.5, -0.7, 1e-5])}
        err = gradlib.init_error_tree(g)
        totals = jnp.zeros(4)
        n = 50
        for _ in range(n):
            comp, err = gradlib.ef_compress_tree(g, err)
            dq = gradlib.decompress_int8(*comp["w"])
            totals = totals + dq
        avg = totals / n
        # quantum is ~0.0055; values far below it need ~1/value steps to
        # flush through EF -- tolerate one quantum / n of residual bias
        np.testing.assert_allclose(np.asarray(avg), np.asarray(g["w"]),
                                   rtol=0.05, atol=0.7 / 127 / n + 1e-7)


class TestCheckpoint:
    def _tree(self, v=0.0):
        return {"layer": {"w": jnp.full((4, 3), v), "b": jnp.zeros(3)},
                "step_arrays": [jnp.ones(2), jnp.zeros(())]}

    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = self._tree(7.0)
        mgr.save(3, tree)
        out, meta = mgr.restore(3, self._tree())
        assert meta["step"] == 3
        np.testing.assert_allclose(np.asarray(out["layer"]["w"]), 7.0)

    def test_restore_latest_skips_corrupt(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, self._tree(1.0))
        mgr.save(2, self._tree(2.0))
        # corrupt the newest file
        steps = mgr.all_steps()
        with open(mgr._fname(steps[-1]), "wb") as f:
            f.write(b"garbage")
        out, meta = mgr.restore_latest(self._tree())
        assert meta["step"] == 1
        np.testing.assert_allclose(np.asarray(out["layer"]["w"]), 1.0)

    def test_retention_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in range(5):
            mgr.save(s, self._tree(float(s)))
        assert mgr.all_steps() == [3, 4]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(10, self._tree(5.0), blocking=False)
        mgr.wait()
        out, meta = mgr.restore_latest(self._tree())
        assert meta["step"] == 10

    def test_shape_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, self._tree())
        bad = {"layer": {"w": jnp.zeros((5, 5)), "b": jnp.zeros(3)},
               "step_arrays": [jnp.ones(2), jnp.zeros(())]}
        with pytest.raises(Exception):
            mgr.restore(1, bad)

    def test_mesh_agnostic_restore(self, tmp_path):
        """Elastic rescale: a checkpoint saved under one device layout
        restores under another (arrays are host-gathered numpy)."""
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, self._tree(3.0))
        # restore is plain numpy -> placing onto any mesh is the caller's
        # device_put; just verify host restore is exact
        out, _ = mgr.restore_latest(self._tree())
        np.testing.assert_allclose(np.asarray(out["layer"]["w"]), 3.0)
