"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp
oracle, plus gradient checks for the differentiable ones."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mps_combine import kernel as mk, ops as mops, ref as mref
from repro.kernels.quant_matmul import kernel as qk, ops as qops, ref as qref
from repro.kernels.ssd_scan import kernel as sk, ops as sops, ref as sref

import proptest as pt


def _assert_quant_close(out, ref, w):
    """Compare two fake-quant implementations: identical math, but
    division vs reciprocal-multiply can flip round() at exact .5 grid
    boundaries. Allow <0.1% of elements to differ by at most one 2-bit
    grid step (the coarsest grid in the sweep)."""
    out, ref = np.asarray(out), np.asarray(ref)
    absmax = np.max(np.abs(np.asarray(w)), axis=1, keepdims=True)
    grid_step = absmax  # 2-bit grid: absmax / 1
    diff = np.abs(out - ref)
    bad = diff > 1e-5
    assert bad.mean() < 1e-3, f"{bad.mean():.2%} elements differ"
    assert np.all(diff <= grid_step + 1e-5)


class TestMpsCombine:
    @pytest.mark.parametrize("m,k", [(8, 128), (70, 300), (256, 512),
                                     (33, 1000), (128, 129)])
    @pytest.mark.parametrize("precisions", [(0, 2, 4, 8), (0, 8), (2, 4, 8)])
    def test_matches_oracle(self, m, k, precisions):
        kw = jax.random.key(m * k)
        w = jax.random.normal(kw, (m, k))
        probs = jax.nn.softmax(
            jax.random.normal(jax.random.key(1), (m, len(precisions))), -1)
        out = mops.mps_combine(w, probs, precisions)
        ref = mref.mps_combine_ref(w, probs, precisions)
        _assert_quant_close(out, ref, w)

    @pt.given(seed=pt.integers(0, 10**6))
    def test_property_random(self, seed):
        rng = np.random.default_rng(seed)
        m = int(rng.integers(4, 90))
        k = int(rng.integers(4, 400))
        w = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        probs = jax.nn.softmax(jnp.asarray(
            rng.normal(size=(m, 4)).astype(np.float32)), -1)
        out = mops.mps_combine(w, probs, (0, 2, 4, 8))
        ref = mref.mps_combine_ref(w, probs, (0, 2, 4, 8))
        _assert_quant_close(out, ref, w)

    def test_custom_vjp_matches_ste_autodiff(self):
        """Kernel backward must match autodiff through the STE-correct
        pure-jnp path (core.mps.effective_weight). NOTE: ref.py is a
        forward-only oracle (plain round, no STE) -- differentiating it
        gives degenerate zero/absmax-leak gradients by design."""
        from repro.core import mps as mps_mod
        w = jax.random.normal(jax.random.key(0), (24, 96))
        gamma = jax.random.normal(jax.random.key(1), (24, 4))

        def loss(w, g, use_kernel):
            ctx = mps_mod.SearchCtx(use_kernel=use_kernel)
            return jnp.sum(jnp.tanh(mps_mod.effective_weight(
                w, g, (0, 2, 4, 8), ctx)))

        gk = jax.grad(loss, (0, 1))(w, gamma, True)
        gr = jax.grad(loss, (0, 1))(w, gamma, False)
        # each row's absmax element sits exactly on the clip boundary;
        # whether two float pipelines both see the tie is ULP luck, so
        # exclude near-boundary elements from the dW comparison
        wn = np.asarray(w)
        absmax = np.max(np.abs(wn), axis=1, keepdims=True)
        interior = np.abs(wn) < 0.999 * absmax
        dwk, dwr = np.asarray(gk[0]), np.asarray(gr[0])
        np.testing.assert_allclose(dwk[interior], dwr[interior],
                                   atol=5e-3, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(gk[1]), np.asarray(gr[1]),
                                   atol=5e-3, rtol=1e-3)


class TestQuantMatmul:
    @pytest.mark.parametrize("bits", [8, 4, 2])
    @pytest.mark.parametrize("m,n,k", [(8, 16, 64), (33, 50, 200),
                                       (128, 128, 512), (1, 256, 1024)])
    def test_matches_oracle(self, bits, m, n, k):
        rng = np.random.default_rng(bits * m + n)
        lim = 2 ** (bits - 1)
        wq = rng.integers(-lim + 1, lim, size=(n, k)).astype(np.int8)
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        xq, sx = qref.quantize_activations(x)
        sw = jnp.asarray(np.abs(rng.normal(size=n)).astype(np.float32))
        packed = jnp.asarray(qref.pack_weights(wq, bits))
        out = qops.quant_matmul(xq, packed, sw, sx, w_bits=bits)
        ref = qref.quant_matmul_ref(xq, jnp.asarray(wq), sw, sx)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_pack_roundtrip(self):
        for bits in (2, 4, 8):
            lim = 2 ** (bits - 1)
            rng = np.random.default_rng(0)
            wq = rng.integers(-lim + 1, lim, size=(5, 24)).astype(np.int8)
            packed = qref.pack_weights(wq, bits)
            unpacked = np.asarray(qk._unpack(jnp.asarray(packed), bits))
            np.testing.assert_array_equal(unpacked, wq)

    def test_quantized_linear_errors_bounded(self):
        """End-to-end w8a8 quantized linear stays close to float matmul."""
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(16, 128)).astype(np.float32))
        w = rng.normal(size=(32, 128)).astype(np.float32) * 0.1
        from repro.core import quantizers
        qi, scale = quantizers.integerize_weights(jnp.asarray(w), 8, 0)
        xq, sx = qref.quantize_activations(x)
        y = qops.quant_matmul(xq, jnp.asarray(np.asarray(qi)),
                              jnp.asarray(np.asarray(scale)[:, 0]), sx, 8)
        ref = x @ w.T
        rel = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
        assert rel < 0.02


class TestSSDScan:
    @pytest.mark.parametrize("c,h,p,n", [(4, 8, 16, 16), (6, 16, 8, 16),
                                         (1, 8, 4, 4), (10, 24, 16, 32)])
    def test_matches_oracle(self, c, h, p, n):
        k = jax.random.key(c * h)
        dec = jax.random.uniform(k, (c, h), minval=0.3, maxval=1.0)
        s_in = jax.random.normal(jax.random.key(1), (c, h, p, n))
        s0 = jax.random.normal(jax.random.key(2), (h, p, n))
        pk_, fk = sk.ssd_scan_fwd(dec, s_in, s0, interpret=True)
        pr, fr = sref.ssd_scan_ref(dec, s_in, s0)
        np.testing.assert_allclose(np.asarray(pk_), np.asarray(pr),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(fk), np.asarray(fr),
                                   rtol=1e-5, atol=1e-5)

    def test_ops_dispatch_cpu_uses_ref(self):
        dec = jnp.ones((3, 8)) * 0.5
        s_in = jnp.ones((3, 8, 4, 4))
        s0 = jnp.zeros((8, 4, 4))
        prefix, final = sops.ssd_scan(dec, s_in, s0)
        # analytic: S_c = sum_{i<c} 0.5^(c-1-i); final = S_3
        np.testing.assert_allclose(float(final[0, 0, 0]),
                                   1 + 0.5 + 0.25, rtol=1e-6)

    def test_decay_zero_blocks_history(self):
        dec = jnp.zeros((2, 8))
        s_in = jax.random.normal(jax.random.key(0), (2, 8, 4, 4))
        s0 = 100 * jnp.ones((8, 4, 4))
        prefix, final = sk.ssd_scan_fwd(dec, s_in, s0, interpret=True)
        np.testing.assert_allclose(np.asarray(final), np.asarray(s_in[1]),
                                   atol=1e-5)
