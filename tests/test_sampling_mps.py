"""Tests for the selection-parameter sampling (Eq. 3) and the MPS effective
tensors (Eq. 4/5), incl. Eq. 12 rescaling and Eq. 13 init."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mps, quantizers, sampling

import proptest as pt

PW = (0, 2, 4, 8)


class TestSampling:
    @pt.given(tau=pt.floats(0.05, 5.0))
    def test_softmax_rows_sum_to_one(self, tau):
        logits = jax.random.normal(jax.random.key(0), (13, 4))
        p = sampling.sample(logits, sampling.SOFTMAX, tau)
        assert np.allclose(jnp.sum(p, -1), 1.0, atol=1e-5)
        assert bool(jnp.all(p >= 0))

    def test_argmax_is_hard_onehot(self):
        logits = jax.random.normal(jax.random.key(1), (9, 4))
        p = sampling.sample(logits, sampling.ARGMAX, 1.0)
        assert np.allclose(jnp.max(p, -1), 1.0, atol=1e-6)
        assert np.allclose(jnp.sum(p, -1), 1.0, atol=1e-6)
        assert bool(jnp.all(jnp.argmax(p, -1) == jnp.argmax(logits, -1)))

    def test_argmax_has_soft_gradient(self):
        logits = jnp.asarray([[0.3, 0.2, 0.1, 0.0]])
        g = jax.grad(lambda l: jnp.sum(
            sampling.sample(l, sampling.ARGMAX, 1.0) *
            jnp.asarray([1.0, 2.0, 3.0, 4.0])))(logits)
        assert float(jnp.sum(jnp.abs(g))) > 0  # straight-through surrogate

    def test_gumbel_hard_and_stochastic(self):
        logits = jnp.zeros((6, 4))
        p1 = sampling.sample(logits, sampling.GUMBEL, 1.0, jax.random.key(0))
        p2 = sampling.sample(logits, sampling.GUMBEL, 1.0, jax.random.key(7))
        assert np.allclose(jnp.sum(p1, -1), 1.0, atol=1e-5)
        assert not np.allclose(p1, p2)

    def test_temperature_schedule_paper_values(self):
        # CIFAR-10: tau_e = exp(-0.045 e); equal final temp for TIN at 0.638
        tau = sampling.temperature_schedule(1.0, float(np.exp(-0.045)))
        assert np.isclose(float(tau(0)), 1.0)
        assert np.isclose(float(tau(100)), np.exp(-4.5), rtol=1e-4)

    def test_init_eq13_orders_precisions(self):
        logits = sampling.init_selection_logits(PW, (5,))
        assert logits.shape == (5, 4)
        row = np.asarray(logits[0])
        assert np.all(np.diff(row) > 0)        # 0-bit least likely
        assert np.isclose(row[-1], 1.0)        # p/max(P) for p = 8


class TestEffectiveTensors:
    def test_onehot_gamma_reduces_to_quantized(self):
        w = jax.random.normal(jax.random.key(2), (6, 20))
        for idx, bits in enumerate(PW):
            gamma = jnp.full((6, 4), -40.0).at[:, idx].set(40.0)
            ctx = mps.SearchCtx(sampling.SOFTMAX, 1.0)
            eff = mps.effective_weight(w, gamma, PW, ctx)
            ref = quantizers.quantize_weights_symmetric(w, bits, 0)
            assert np.allclose(eff, ref, atol=1e-5), bits

    def test_effective_weight_is_convex_combination(self):
        w = jax.random.normal(jax.random.key(3), (4, 16))
        gamma = jax.random.normal(jax.random.key(4), (4, 4))
        ctx = mps.SearchCtx(sampling.SOFTMAX, 1.0)
        eff = mps.effective_weight(w, gamma, PW, ctx)
        qs = quantizers.quantize_weights_multi(w, PW, 0)
        lo = jnp.min(qs, 0) - 1e-5
        hi = jnp.max(qs, 0) + 1e-5
        assert bool(jnp.all(eff >= lo) and jnp.all(eff <= hi))

    def test_kernel_path_matches_jnp_path(self):
        w = jax.random.normal(jax.random.key(5), (32, 129))
        gamma = jax.random.normal(jax.random.key(6), (32, 4))
        eff_j = mps.effective_weight(w, gamma, PW,
                                     mps.SearchCtx(use_kernel=False))
        eff_k = mps.effective_weight(w, gamma, PW,
                                     mps.SearchCtx(use_kernel=True))
        assert np.allclose(eff_j, eff_k, atol=1e-5)

    def test_rescale_eq12_preserves_magnitude(self):
        w = jax.random.normal(jax.random.key(7), (8, 32))
        gamma = sampling.init_selection_logits(PW, (8,))
        ctx = mps.SearchCtx(sampling.SOFTMAX, 1.0)
        w_r = mps.rescale_weights_for_search(w, gamma, PW, ctx)
        eff = mps.effective_weight(w_r, gamma, PW, ctx)
        # effective magnitude after rescale ~ original magnitude
        ratio = float(jnp.linalg.norm(eff) / jnp.linalg.norm(w))
        assert 0.85 < ratio < 1.15

    def test_discretize(self):
        gamma = jnp.asarray([[9.0, 0, 0, 0], [0, 0, 0, 9.0]])
        bits = mps.discretize_gamma(gamma, PW)
        assert list(np.asarray(bits)) == [0, 8]

    @pt.given(tau=pt.floats(0.1, 2.0))
    def test_expected_bits_bounds(self, tau):
        gamma = jax.random.normal(jax.random.key(8), (10, 4))
        eb = mps.expected_bits(gamma, PW, mps.SearchCtx(tau=tau))
        assert bool(jnp.all(eb >= 0)) and bool(jnp.all(eb <= 8))

    def test_activation_onehot_matches_pact(self):
        x = jax.random.normal(jax.random.key(9), (5, 7)) * 3
        alpha = jnp.asarray(2.5)
        delta = jnp.asarray([-40.0, 40.0, -40.0])
        ctx = mps.SearchCtx(sampling.SOFTMAX, 1.0)
        eff = mps.effective_activation(x, delta, alpha, (2, 4, 8), ctx)
        ref = quantizers.pact_quantize(x, alpha, 4)
        assert np.allclose(eff, ref, atol=1e-5)
