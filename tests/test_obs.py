"""Observability subsystem tests: the metrics registry (counters,
gauges, histograms, no-op-when-disabled, idempotent phase points), the
request tracer's lifecycle grammar, end-to-end server tracing across the
parity matrix (dense/paged x float/quantized x solo/batched/streaming/
preempted) with scheduler event-ordering properties, and the exporter +
validator round-trip."""
import json

import jax
import numpy as np
import pytest

from repro import obs
from repro.configs import registry as cfg_registry
from repro.models import lm
from repro.obs import validate as obs_validate
from repro.serve import engine
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import Request


@pytest.fixture(scope="module")
def llama():
    cfg = cfg_registry.get("llama3.2-1b-smoke")
    return cfg, lm.init_params(cfg, jax.random.key(0))


def _reqs(cfg, lens, sp, gap=0, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=s).astype(np.int32),
                    sampling=sp, arrival=gap * i)
            for i, s in enumerate(lens)]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_counter_gauge_labels(self):
        reg = obs.MetricsRegistry()
        c = reg.counter("x_total", "help", labels=("kind",))
        c.inc(kind="a")
        c.inc(2, kind="a")
        c.inc(kind="b")
        assert c.value(kind="a") == 3.0
        assert c.value(kind="b") == 1.0
        g = reg.gauge("y")
        g.set(7.5)
        g.set(2.5)
        assert g.value() == 2.5
        with pytest.raises(ValueError):
            c.inc(-1, kind="a")
        with pytest.raises(ValueError):
            c.inc(wrong="a")

    def test_get_or_create_and_mismatch(self):
        reg = obs.MetricsRegistry()
        c1 = reg.counter("n_total", labels=("k",))
        assert reg.counter("n_total", labels=("k",)) is c1
        with pytest.raises(ValueError):
            reg.gauge("n_total", labels=("k",))     # kind mismatch
        with pytest.raises(ValueError):
            reg.counter("n_total", labels=("j",))   # label mismatch
        reg.histogram("h")
        with pytest.raises(ValueError):
            reg.histogram("h", buckets=(1.0, 2.0))  # bucket mismatch

    def test_histogram_buckets_and_snapshot(self):
        reg = obs.MetricsRegistry()
        h = reg.histogram("lat_seconds", buckets=(0.1, 1.0, 10.0))
        for v in (0.05, 0.1, 0.5, 5.0, 100.0):
            h.observe(v)
        assert h.count() == 5
        snap = reg.snapshot()["lat_seconds"]
        (series,) = snap["series"]
        # le is inclusive: 0.1 falls in the first bucket
        assert series["buckets"] == [[0.1, 2], [1.0, 3], [10.0, 4],
                                     ["+Inf", 5]]
        assert series["count"] == 5
        assert series["sum"] == pytest.approx(105.65)

    def test_default_latency_buckets_log_spaced(self):
        b = obs.LATENCY_BUCKETS_S
        assert b[0] == pytest.approx(1e-6)
        assert b[-1] == pytest.approx(1e2)
        ratios = [b2 / b1 for b1, b2 in zip(b, b[1:])]
        assert all(r == pytest.approx(10 ** 0.25) for r in ratios)

    def test_disabled_registry_is_noop(self):
        reg = obs.MetricsRegistry(enabled=False)
        c = reg.counter("x_total")
        c.inc()
        reg.gauge("y").set(1.0)
        reg.histogram("h").observe(0.5)
        reg.emit_phase_point("p", 0, {"loss": 1.0})
        assert reg.snapshot() == {}
        # every disabled accessor returns the one shared no-op object
        assert c is reg.histogram("h")

    def test_emit_phase_point_idempotent(self):
        reg = obs.MetricsRegistry()
        reg.emit_phase_point("search", 0, {"task": 1.0, "reg": 2.0})
        reg.emit_phase_point("search", 1, {"task": 0.9, "reg": 1.9})
        # replayed steps (checkpoint resume) must not re-count
        reg.emit_phase_point("search", 0, {"task": 1.0, "reg": 2.0})
        reg.emit_phase_point("search", 1, {"task": 0.9, "reg": 1.9})
        reg.emit_phase_point("search", 2, {"task": 0.8, "reg": 1.8})
        pts = reg.counter("compress_step_points_total",
                          labels=("phase", "metric"))
        assert pts.value(phase="search", metric="task") == 3
        assert pts.value(phase="search", metric="reg") == 3
        val = reg.gauge("compress_step_value", labels=("phase", "metric"))
        assert val.value(phase="search", metric="task") == \
            pytest.approx(0.8)
        # an independent metric name at the same steps is unaffected
        reg.emit_phase_point("search", 1, {"acc_quant": 0.5})
        assert pts.value(phase="search", metric="acc_quant") == 1

    def test_prometheus_round_trip(self):
        reg = obs.MetricsRegistry()
        reg.counter("req_total", "requests served",
                    labels=("kind",)).inc(3, kind='we"ird\nname')
        reg.gauge("pages").set(4)
        reg.histogram("lat_seconds", buckets=(0.5, 5.0)).observe(1.0)
        text = obs.to_prometheus(reg)
        fams = obs_validate.parse_prometheus(text)
        assert fams["req_total"]["type"] == "counter"
        name, labels, value = fams["req_total"]["samples"][0]
        assert labels == {"kind": 'we"ird\nname'} and value == 3.0
        assert fams["lat_seconds"]["type"] == "histogram"
        # 2 buckets + +Inf + sum + count
        assert len(fams["lat_seconds"]["samples"]) == 5


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

class TestTracer:
    def test_manual_lifecycle_and_latencies(self):
        reg = obs.MetricsRegistry()
        tr = obs.RequestTracer(reg)
        tr.event(0, "enqueued", n=4)
        tr.event(0, "admitted", n=4, pages_held=2, slot=0, resumed=False)
        tr.event(0, "prefilled", n=4, pages_held=2, slot=0)
        tr.event(0, "first_token", n=1, pages_held=2, slot=0)
        tr.event(0, "decode", n=2, pages_held=3, slot=0)
        tr.event(0, "preempted", n=2, pages_held=0, slot=0)
        tr.event(0, "admitted", n=6, pages_held=3, slot=1, resumed=True)
        tr.event(0, "prefilled", n=6, pages_held=3, slot=1)
        tr.event(0, "decode", n=3, pages_held=3, slot=1)
        tr.event(0, "finished", n=3, pages_held=0, slot=1)
        assert tr.check_lifecycle(tr.lifecycle(0)) is None
        assert len(tr.ttfts()) == 1
        assert len(tr.token_latencies()) == 3   # first_token + 2 decodes
        assert tr.preemption_count() == 1
        assert tr.pages_held_hwm() == 3
        # registry saw one ttft and one latency observation per token
        assert reg.histogram("serve_ttft_seconds").count() == 1
        assert reg.histogram("serve_token_latency_seconds").count() == 3
        assert reg.counter("serve_tokens_total").value() == 3

    def test_invalid_lifecycles_rejected(self):
        check = obs.RequestTracer.check_lifecycle
        assert check([]) is not None
        assert check(["admitted"]) is not None
        assert check(["enqueued", "admitted", "first_token"]) is not None
        assert check(["enqueued", "admitted", "prefilled",
                      "first_token"]) is not None      # no finished
        assert check(["enqueued", "admitted", "prefilled", "decode",
                      "finished"]) is not None         # missing 1st token
        assert check(["enqueued", "admitted", "prefilled", "first_token",
                      "finished", "decode"]) is not None
        assert check(["enqueued", "admitted", "prefilled", "first_token",
                      "preempted", "admitted", "prefilled", "first_token",
                      "finished"]) is not None   # resume re-emits 1st tok
        assert check(["enqueued", "admitted", "prefilled", "first_token",
                      "finished"]) is None
        assert check(["enqueued", "admitted", "prefilled", "first_token",
                      "preempted", "admitted", "prefilled", "decode",
                      "finished"]) is None

    def test_episode_grammar_terminals(self):
        """timeout/cancelled terminals: may strike a queued, resident or
        preempted request, and a struck uid may be re-enqueued (the
        fleet's retry path) as a fresh episode; finished stays final."""
        check = obs.RequestTracer.check_lifecycle
        assert check(["enqueued", "cancelled"]) is None
        assert check(["enqueued", "timeout"]) is None
        assert check(["enqueued", "admitted", "prefilled", "first_token",
                      "decode", "timeout"]) is None      # mid-decode
        assert check(["enqueued", "admitted", "prefilled", "first_token",
                      "preempted", "cancelled"]) is None  # while evicted
        # retry episodes: timeout in queue, then a clean second episode
        assert check(["enqueued", "timeout",
                      "enqueued", "admitted", "prefilled", "first_token",
                      "decode", "finished"]) is None
        assert check(["enqueued", "cancelled", "enqueued",
                      "timeout"]) is None
        # finished must be the uid's last event overall
        assert check(["enqueued", "admitted", "prefilled", "first_token",
                      "finished", "enqueued", "cancelled"]) is not None
        # finished requires a residency; terminals don't chain
        assert check(["enqueued", "finished"]) is not None
        assert check(["enqueued", "admitted", "prefilled", "first_token",
                      "preempted", "finished"]) is not None
        assert check(["enqueued", "timeout", "admitted", "prefilled",
                      "first_token", "finished"]) is not None  # no re-enq
        assert check(["enqueued", "timeout", "cancelled"]) is not None

    def test_queue_depth_gauge_and_wait_histogram(self):
        reg = obs.MetricsRegistry()
        tr = obs.RequestTracer(reg, replica="r0")
        g = reg.gauge("serve_queue_depth", labels=("replica",))
        h = reg.histogram("serve_queue_wait_seconds",
                          labels=("replica",))
        tr.event(0, "enqueued", n=4)
        tr.event(1, "enqueued", n=4)
        assert g.value(replica="r0") == 2.0
        tr.event(0, "admitted", n=4, slot=0)
        assert g.value(replica="r0") == 1.0
        assert h.count(replica="r0") == 1     # enqueued -> admitted
        tr.event(1, "cancelled", n=0)         # cancellation leaves queue
        assert g.value(replica="r0") == 0.0
        # a preemption re-enters the queue; its wait is measured from
        # the preemption, not the original enqueue
        tr.event(0, "prefilled", n=4, slot=0)
        tr.event(0, "first_token", n=1, slot=0)
        tr.event(0, "preempted", n=1, slot=0)
        assert g.value(replica="r0") == 1.0
        tr.event(0, "admitted", n=5, slot=0)
        assert g.value(replica="r0") == 0.0
        assert h.count(replica="r0") == 2
        assert len(tr.queue_waits()) == 2
        assert all(w >= 0.0 for w in tr.queue_waits())

    def test_solo_servers_use_empty_replica_label(self):
        reg = obs.MetricsRegistry()
        tr = obs.RequestTracer(reg)
        tr.event(0, "enqueued", n=1)
        assert reg.gauge("serve_queue_depth",
                         labels=("replica",)).value(replica="") == 1.0

    def test_unknown_kind_raises(self):
        with pytest.raises(ValueError):
            obs.RequestTracer().event(0, "teleported")

    def test_start_resets_trace_not_metrics(self):
        reg = obs.MetricsRegistry()
        tr = obs.RequestTracer(reg)
        tr.event(0, "enqueued", n=1)
        tr.start()
        assert tr.events == []
        assert reg.counter("serve_trace_events_total",
                           labels=("kind",)).value(kind="enqueued") == 1


# ---------------------------------------------------------------------------
# server tracing: lifecycle properties across the parity matrix
# ---------------------------------------------------------------------------

def _check_trace_properties(server, requests, out):
    """The satellite's scheduler event-ordering properties, asserted on
    one traced serve run."""
    tr = server.obs.tracer
    reg = server.obs.registry
    uids = {r.uid for r in requests}
    assert set(tr.uids()) == uids

    for uid in uids:
        evs = tr.events_for(uid)
        kinds = [e.kind for e in evs]
        err = obs.RequestTracer.check_lifecycle(kinds)
        assert err is None, f"uid {uid}: {kinds}: {err}"
        # admitted strictly before the first token
        assert kinds.index("admitted") < kinds.index("first_token")
        # pages return to 0 at finish; final n is the emitted stream
        last = evs[-1]
        assert last.kind == "finished" and last.pages_held == 0
        assert last.n == len(out[uid])

    # preempted requests are re-admitted in FRONT order: replay the
    # trace against a model deque -- a preemption pushes the uid to the
    # front, and the next resumed admission must pop exactly the head
    # (no fresh admission may overtake a waiting preempted request)
    front = []
    for ev in tr.events:
        if ev.kind == "preempted":
            front.insert(0, ev.uid)
        elif ev.kind == "admitted":
            if ev.extra.get("resumed"):
                assert front and front[0] == ev.uid, \
                    f"resumed {ev.uid} admitted out of FRONT order {front}"
                front.pop(0)
            else:
                assert ev.uid not in front
                assert not front, \
                    f"fresh {ev.uid} admitted while {front} waits in front"

    # histogram counts reconcile with the engine's token totals
    generated = server.stats["generated"]
    assert len(tr.token_latencies()) == generated
    assert reg.histogram("serve_token_latency_seconds").count() == \
        generated
    assert reg.counter("serve_tokens_total").value() == generated
    assert reg.histogram("serve_ttft_seconds").count() == len(uids)
    assert tr.preemption_count() == server.stats["preemptions"]


class TestServerTracing:
    @pytest.mark.parametrize("cache,plan_on", [
        ("dense", False), ("paged", False),
        ("dense", True), ("paged", True)])
    def test_lifecycle_matrix(self, llama, cache, plan_on):
        cfg, params = llama
        plan = engine.synthetic_plan(cfg, params, bits=None, seed=0) \
            if plan_on else None
        kwargs = {} if cache == "dense" else {
            "cache": "paged", "page_size": 8, "pages": 10}
        server = engine.InferenceServer(
            cfg, params, plan=plan, max_len=48, max_batch=2,
            obs=obs.Observability(), **kwargs)
        sp = SamplingParams(temperature=0.8, top_k=12, max_tokens=5,
                            seed=11)
        for name, lens, gap in [("solo", (9,), 0),
                                ("batched", (4, 13, 7), 0),
                                ("streaming", (4, 13, 7, 9), 3)]:
            # fresh bundle per workload: registry metrics are cumulative
            # across serve() runs, and the reconciliation below compares
            # them against one run's engine stats
            server.attach_obs(obs.Observability())
            reqs = _reqs(cfg, lens, sp, gap=gap, seed=1)
            out = server.serve(reqs)
            _check_trace_properties(server, reqs, out)

    def test_preempted_lifecycle_and_front_order(self, llama):
        """The workload from test_cache's pool-exhaustion test: pages=7
        forces preemptions, and the trace must show them resumed in
        FRONT order with pages released."""
        cfg, params = llama
        server = engine.InferenceServer(
            cfg, params, max_len=32, max_batch=3, cache="paged",
            page_size=4, pages=7, obs=obs.Observability())
        sp = SamplingParams(temperature=0.6, top_k=10, max_tokens=8,
                            seed=3)
        reqs = _reqs(cfg, (4, 9, 6, 13), sp)
        out = server.serve(reqs)
        assert server.stats["preemptions"] > 0
        _check_trace_properties(server, reqs, out)
        # at least one lifecycle actually exercised the preempted arm
        assert any("preempted" in server.obs.tracer.lifecycle(u)
                   for u in server.obs.tracer.uids())
        assert server.obs.registry.counter(
            "serve_preemptions_total").value() == \
            server.stats["preemptions"]
        assert server.obs.registry.counter(
            "serve_pool_exhausted_total").value() >= \
            server.stats["preemptions"]

    def test_tokens_identical_with_and_without_obs(self, llama):
        cfg, params = llama
        sp = SamplingParams(temperature=0.7, top_k=9, max_tokens=6,
                            seed=5)
        plain = engine.InferenceServer(cfg, params, max_len=48,
                                       max_batch=2, cache="paged",
                                       page_size=8, pages=10)
        ref = plain.serve(_reqs(cfg, (4, 13, 7), sp, gap=2))
        plain.attach_obs(obs.Observability())
        traced = plain.serve(_reqs(cfg, (4, 13, 7), sp, gap=2))
        for uid in ref:
            np.testing.assert_array_equal(ref[uid], traced[uid])
        plain.attach_obs(None)
        again = plain.serve(_reqs(cfg, (4, 13, 7), sp, gap=2))
        for uid in ref:
            np.testing.assert_array_equal(ref[uid], again[uid])

    def test_metrics_snapshot_and_summary(self, llama):
        cfg, params = llama
        server = engine.InferenceServer(
            cfg, params, max_len=48, max_batch=2, cache="paged",
            page_size=8, pages=10, obs=obs.Observability())
        sp = SamplingParams(max_tokens=5)      # greedy path
        server.serve(_reqs(cfg, (4, 13, 7), sp))
        snap = server.metrics_snapshot()
        m, s = snap["metrics"], snap["summary"]
        assert s["requests"] == 3 and s["tokens"] == 15
        assert s["ttft_s"]["p50"] is not None
        assert s["token_latency_s"]["p99"] is not None
        assert sum(s["decode_width_steps"].values()) == \
            server.stats["decode_steps"]
        assert set(s["decode_compiles_per_width"]) == \
            set(s["decode_width_steps"])
        # cache gauges published from memory_report
        pages_gauge = [x for x in m["serve_cache_pages_in_use"]["series"]
                       if x["labels"] == {"backend": "paged"}]
        assert pages_gauge and pages_gauge[0]["value"] == 0
        assert m["serve_cache_peak_pages_in_use"]["series"][0]["value"] > 0
        # all-greedy workload took the greedy decode path only
        paths = {tuple(sorted(x["labels"].items()))
                 for x in m["serve_decode_steps_total"]["series"]}
        assert all(dict(p)["path"] == "greedy" for p in paths)
        # detached server returns {}
        server.attach_obs(None)
        assert server.metrics_snapshot() == {}

    def test_topk_skip_counter(self, llama):
        cfg, params = llama
        server = engine.InferenceServer(cfg, params, max_len=32,
                                        max_batch=2,
                                        obs=obs.Observability())
        # temperature>0 with top_k=0: sampled path, sort skipped
        server.serve(_reqs(cfg, (4, 6), SamplingParams(
            temperature=0.8, max_tokens=4, seed=1)))
        skipped = server.obs.registry.counter(
            "serve_topk_sort_steps_total", labels=("skipped",))
        assert skipped.value(skipped="true") > 0
        assert skipped.value(skipped="false") == 0
        # truncating top_k: sort needed
        server.serve(_reqs(cfg, (4, 6), SamplingParams(
            temperature=0.8, top_k=5, max_tokens=4, seed=1)))
        assert skipped.value(skipped="false") > 0
        rate = server.metrics_snapshot()["summary"]["topk_sort_skip_rate"]
        assert 0.0 < rate < 1.0


# ---------------------------------------------------------------------------
# exporters + validator
# ---------------------------------------------------------------------------

class TestValidateTool:
    def test_end_to_end_files(self, llama, tmp_path):
        cfg, params = llama
        server = engine.InferenceServer(
            cfg, params, max_len=32, max_batch=2, cache="paged",
            page_size=4, pages=12, obs=obs.Observability())
        server.serve(_reqs(cfg, (4, 9, 6), SamplingParams(
            temperature=0.6, top_k=8, max_tokens=4, seed=2), gap=2))
        mpath = tmp_path / "m.prom"
        tpath = tmp_path / "t.jsonl"
        spath = "tests/obs_schema.json"
        obs.write_prometheus(server.obs.registry, str(mpath))
        obs.write_trace(server.obs.tracer, str(tpath))
        assert obs_validate.validate_files(str(mpath), str(tpath),
                                           spath) == []
        assert obs_validate.main(["--metrics", str(mpath),
                                  "--trace", str(tpath),
                                  "--schema", spath]) == 0
        # corrupt one trace line -> validation fails
        lines = tpath.read_text().splitlines()
        bad = json.loads(lines[0])
        bad["kind"] = "teleported"
        lines[0] = json.dumps(bad)
        tpath.write_text("\n".join(lines) + "\n")
        errs = obs_validate.validate_files(str(mpath), str(tpath), spath)
        assert errs and any("enum" in e for e in errs)

    def test_prometheus_parser_rejects_bad_input(self):
        with pytest.raises(ValueError):
            obs_validate.parse_prometheus("orphan_metric 1\n")
        with pytest.raises(ValueError):
            obs_validate.parse_prometheus(
                "# TYPE h histogram\n"
                'h_bucket{le="1"} 5\nh_bucket{le="+Inf"} 3\n'
                "h_sum 1\nh_count 3\n")     # non-cumulative buckets
        with pytest.raises(ValueError):
            obs_validate.parse_prometheus(
                "# TYPE h histogram\n"
                'h_bucket{le="+Inf"} 3\nh_sum 1\nh_count 4\n')

    def test_schema_checker_units(self):
        schema = json.load(open("tests/obs_schema.json"))
        ok = {"uid": 0, "kind": "decode", "t": 0.5, "n": 2}
        assert obs_validate.check_schema(ok, schema) == []
        assert obs_validate.check_schema(
            {"uid": 0, "kind": "decode"}, schema)       # missing t
        assert obs_validate.check_schema(
            {"uid": 0, "kind": "decode", "t": 0.5, "zz": 1}, schema)
        assert obs_validate.check_schema(
            {"uid": True, "kind": "decode", "t": 0.5}, schema)
        assert obs_validate.check_schema(
            {"uid": -1, "kind": "decode", "t": 0.5}, schema)
