"""HLO analyzer correctness (vs known-FLOPs jitted programs), synthetic
data pipeline properties, sharding rule resolution."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import synthetic
from repro.distributed import hlo_analysis, sharding


class TestHloAnalyzer:
    def test_single_matmul_flops(self):
        m, k, n = 64, 128, 32
        f = jax.jit(lambda a, b: a @ b)
        txt = f.lower(jnp.ones((m, k)), jnp.ones((k, n))).compile().as_text()
        t = hlo_analysis.analyze(txt)
        assert np.isclose(t.flops, 2 * m * k * n, rtol=1e-6)

    def test_scan_multiplies_trip_count(self):
        """The core property cost_analysis() lacks: a lax.scan of T matmuls
        must count T times the body FLOPs."""
        m = 32
        T = 7

        def step(x, w):
            return x @ w, ()

        def fn(x, ws):
            y, _ = jax.lax.scan(step, x, ws)
            return y

        txt = jax.jit(fn).lower(
            jnp.ones((m, m)), jnp.ones((T, m, m))).compile().as_text()
        t = hlo_analysis.analyze(txt)
        assert np.isclose(t.flops, T * 2 * m ** 3, rtol=0.01), t.flops

    def test_nested_scan(self):
        m, t_in, t_out = 16, 3, 5

        def inner(x, w):
            return x @ w, ()

        def outer(x, ws):
            def body(c, _):
                y, _ = jax.lax.scan(inner, c, ws)
                return y, ()
            y, _ = jax.lax.scan(body, x, None, length=t_out)
            return y

        txt = jax.jit(outer).lower(
            jnp.ones((m, m)), jnp.ones((t_in, m, m))).compile().as_text()
        t = hlo_analysis.analyze(txt)
        assert np.isclose(t.flops, t_out * t_in * 2 * m ** 3, rtol=0.01)

    def test_trip_count_from_synthetic_hlo(self):
        hlo = """
HloModule test

%cond (p: (s32[], f32[4])) -> pred[] {
  %p = (s32[], f32[4]) parameter(0)
  %c = s32[] constant(9)
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  %x = f32[4] get-tuple-element(%p), index=1
  %ar = f32[4] all-reduce(%x), to_apply=%add
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[4]) tuple(%i, %ar)
}

ENTRY %main (a: f32[4]) -> f32[4] {
  %a = f32[4] parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[4]) tuple(%zero, %a)
  %w = (s32[], f32[4]) while(%t0), condition=%cond, body=%body
  ROOT %r = f32[4] get-tuple-element(%w), index=1
}
"""
        t = hlo_analysis.analyze(hlo)
        assert t.coll_counts.get("all-reduce") == 9
        assert np.isclose(t.coll_bytes["all-reduce"], 9 * 16)

    def test_roofline_terms_and_dominance(self):
        r = hlo_analysis.Roofline(
            flops_per_device=197e12, bytes_per_device=819e9 / 2,
            collective_bytes=50e9 * 3, n_devices=256)
        assert np.isclose(r.compute_s, 1.0)
        assert np.isclose(r.memory_s, 0.5)
        assert np.isclose(r.collective_s, 3.0)
        assert r.dominant == "collective"
        assert np.isclose(r.step_s, 3.0)


class TestSyntheticData:
    def test_batches_deterministic(self):
        x1, y1 = synthetic.class_batch(synthetic.CIFAR10_LIKE, 5, 16, 0)
        x2, y2 = synthetic.class_batch(synthetic.CIFAR10_LIKE, 5, 16, 0)
        np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
        x3, _ = synthetic.class_batch(synthetic.CIFAR10_LIKE, 6, 16, 0)
        assert not np.allclose(np.asarray(x1), np.asarray(x3))

    def test_class_structure_learnable(self):
        """Same-class samples are closer to their template than to others
        (so the dataset is actually learnable)."""
        spec = synthetic.CIFAR10_LIKE
        temps = np.asarray(synthetic._templates(spec))
        x, y = synthetic.class_batch(spec, 0, 64, 0)
        x, y = np.asarray(x), np.asarray(y)
        correct = 0
        for i in range(64):
            d = [np.linalg.norm(
                np.roll(x[i], s, axis=1) - temps[c])
                for c in range(spec.num_classes) for s in (-2, -1, 0, 1, 2)]
            d = np.asarray(d).reshape(spec.num_classes, 5).min(1)
            correct += int(np.argmin(d) == y[i])
        assert correct / 64 > 0.9

    def test_lm_batch_structure(self):
        b = synthetic.lm_batch(512, 33, 4, step=0)
        assert b["tokens"].shape == (4, 32)
        assert b["targets"].shape == (4, 32)
        # mostly follows the affine recurrence (structure=0.9)
        toks = np.asarray(b["tokens"])
        tgts = np.asarray(b["targets"])
        matches = 0
        for a in (3, 5, 7, 11):
            for bb in range(13):
                m = (tgts == (a * toks + bb) % 512).mean(axis=1)
                matches = max(matches, float(m.max()))
        assert matches > 0.7

    def test_shapes_match_paper_benchmarks(self):
        assert synthetic.CIFAR10_LIKE.shape == (32, 32, 3)
        assert synthetic.GSC_LIKE.num_classes == 12
        assert synthetic.TINYIMAGENET_LIKE.num_classes == 200


class TestShardingRules:
    def test_rules_noop_without_mesh(self):
        x = jnp.ones((4, 4))
        assert sharding.constrain(x, "batch", None) is x
        assert sharding.spec("batch", "embed") == \
            jax.sharding.PartitionSpec()

    def test_use_mesh_filters_absent_axes(self):
        mesh = jax.make_mesh((1,), ("data",), devices=jax.devices()[:1])
        with sharding.use_mesh(mesh, {}) as rules:
            # 'pod'/'model' don't exist on this mesh -> dropped
            assert rules["batch"] == ("data",)
            assert rules["heads"] is None

    def test_spec_resolution(self):
        mesh = jax.make_mesh((1,), ("data",), devices=jax.devices()[:1])
        with sharding.use_mesh(mesh, {"embed": "data"}):
            s = sharding.spec("batch", "embed", None)
            assert s == jax.sharding.PartitionSpec(("data",), "data", None)


class TestMicrobatchAccumulation:
    def test_microbatched_step_matches_full_batch(self):
        """k-microbatch gradient accumulation must equal the full-batch
        step (same mean gradient) up to accumulation-order rounding."""
        import dataclasses
        from repro.configs import registry
        from repro.launch import steps as steps_lib
        from repro.models import lm
        from repro.optim import optimizers

        base = registry.reduced(registry.ARCHS["llama3.2-1b"])
        cfg1 = dataclasses.replace(base, train_microbatches=1)
        cfg2 = dataclasses.replace(base, train_microbatches=2)
        params = lm.init_params(cfg1, jax.random.key(0))
        opt = optimizers.make_optimizer("adam", 1e-3)
        state = opt.init(params)
        batch = {"tokens": jax.random.randint(jax.random.key(1), (4, 32),
                                              0, cfg1.vocab),
                 "targets": jax.random.randint(jax.random.key(2), (4, 32),
                                               0, cfg1.vocab)}
        s1 = steps_lib.make_train_step(cfg1, opt)
        s2 = steps_lib.make_train_step(cfg2, opt)
        p1, _, l1 = s1(params, state, batch, jnp.asarray(0))
        p2, _, l2 = s2(params, state, batch, jnp.asarray(0))
        assert np.isclose(float(l1), float(l2), rtol=1e-3)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=5e-3)
