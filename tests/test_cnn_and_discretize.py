"""Reference CNNs (sizes vs the paper), BN folding, discretization,
channel reordering (Fig. 3) and NE16 refinement."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import discretize, mps, sampling
from repro.models import cnn

PW = (0, 2, 4, 8)
PX = (8,)


class TestReferenceSizes:
    """The paper's baseline sizes (Sec. 5.1/5.2): ResNet-9 = 309.44 kB FP32
    / 77.36 kB w8; DS-CNN = 88.06 kB FP32; ResNet-18 = 45.05 MB FP32."""

    def _wb_bytes(self, g):
        params = cnn.init_params(g, jax.random.key(0))
        return sum(int(np.prod(p[k].shape)) * 4
                   for p in params.values() for k in ("w", "b"))

    def test_resnet9_size_matches_paper(self):
        kb = self._wb_bytes(cnn.resnet9()) / 1024
        assert abs(kb - 309.44) / 309.44 < 0.02, kb

    def test_dscnn_size_matches_paper(self):
        kb = self._wb_bytes(cnn.dscnn()) / 1024
        assert abs(kb - 88.06) / 88.06 < 0.02, kb

    def test_resnet18_size_matches_paper(self):
        mb = self._wb_bytes(cnn.resnet18()) / 1024 / 1024
        assert abs(mb - 45.05) / 45.05 < 0.05, mb

    def test_resnet9_has_9_convs(self):
        g = cnn.resnet9()
        convs = [n for n in g.weight_nodes() if n.kind == "conv"]
        assert len(convs) == 9


class TestBNFoldingAndModes:
    def test_bn_folding_preserves_eval_output(self):
        g = cnn.resnet9(width=8)
        params = cnn.init_params(g, jax.random.key(0))
        x = jax.random.normal(jax.random.key(1), (4,) + g.in_shape)
        # move BN stats off their init values
        for _ in range(3):
            _, params = cnn.apply(g, params, x, mode="float", train=True)
        y_ref, _ = cnn.apply(g, params, x, mode="float", train=False)
        folded = cnn.fold_batchnorm(g, params)
        y_fold, _ = cnn.apply(g, folded, x, mode="float", train=False,
                              folded=True)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_fold),
                                   atol=2e-4)

    def test_gamma_sharing_groups(self):
        g = cnn.resnet9()
        mp = cnn.init_mps_params(g, PW, PX)
        # stem & s1b share; blk2 = {s2b, sc2}; blk3 = {s3b, sc3}
        assert set(mp["gamma"]) == {"stem", "s1a", "blk2", "s2a", "blk3",
                                    "s3a", "fc"}

    def test_dscnn_pw_dw_sharing(self):
        g = cnn.dscnn()
        mp = cnn.init_mps_params(g, PW, PX)
        # each dw conv shares its producer pw conv's gamma
        assert "dw0" not in mp["gamma"] and "stem" in mp["gamma"]

    def test_search_and_quant_modes_shapes(self):
        g = cnn.dscnn(width=16)
        params = cnn.init_params(g, jax.random.key(0))
        folded = cnn.fold_batchnorm(g, params)
        mp = cnn.init_mps_params(g, PW, PX)
        ctx = mps.SearchCtx(sampling.SOFTMAX, 1.0)
        x = jax.random.normal(jax.random.key(2), (2,) + g.in_shape)
        y, _ = cnn.apply(g, folded, x, mode="search", mps_params=mp,
                         ctx=ctx, folded=True)
        assert y.shape == (2, 12) and not bool(jnp.any(jnp.isnan(y)))
        assign = discretize.assign(mp, PW, PX)
        assign_j = {"gamma": {k: jnp.asarray(v)
                              for k, v in assign["gamma"].items()},
                    "delta": assign["delta"],
                    "alpha": {k: jnp.asarray(v)
                              for k, v in assign["alpha"].items()}}
        yq, _ = cnn.apply(g, folded, x, mode="quant", assignment=assign_j,
                          folded=True)
        assert yq.shape == (2, 12) and not bool(jnp.any(jnp.isnan(yq)))


class TestDiscretize:
    def _assignment(self):
        rng = np.random.default_rng(0)
        return {"gamma": {"a": rng.choice(PW, size=37),
                          "b": rng.choice(PW, size=64)},
                "delta": {"a": 8}, "alpha": {"a": 4.0}}

    def test_reorder_sorts_bits_pruned_last(self):
        a = self._assignment()
        perms = discretize.reorder_permutations(a)
        bits = np.asarray(a["gamma"]["a"])[perms["a"]]
        nz = bits[bits > 0]
        assert np.all(np.diff(nz) >= 0)          # ascending precision
        assert np.all(bits[len(nz):] == 0)       # pruned at the end

    def test_sublayer_split_covers_kept_channels(self):
        a = self._assignment()
        split = discretize.sublayer_split(a, PW)
        total = sum(stop - start for _, start, stop in split["a"])
        assert total == int(np.sum(np.asarray(a["gamma"]["a"]) > 0))

    def test_bits_histogram_sums_to_one(self):
        a = self._assignment()
        hist = discretize.bits_histogram(a, PW)
        for grp, h in hist.items():
            assert abs(sum(h.values()) - 1.0) < 1e-6

    def test_ne16_refine_monotone_and_faster(self):
        from repro.core import costs
        geom = costs.LayerGeom(name="l", kind="conv", cin=16, cout=33,
                               kx=3, ky=3, out_h=16, out_w=16, gamma="g")
        bits = np.full(33, 4)
        bits[-1] = 2                      # 1 straggler channel at 2 bits
        assign = {"gamma": {"g": bits}, "delta": {}, "alpha": {}}
        refined, changed = discretize.ne16_refine([geom], assign)
        new_bits = refined["gamma"]["g"]
        assert np.all(new_bits >= bits)   # never decreases precision
        before = costs.ne16_cycles_discrete(geom, bits, 16)
        after = costs.ne16_cycles_discrete(geom, new_bits, 16)
        assert after <= before

    def test_channel_reorder_preserves_network_function(self):
        """Fig. 3: permuting conv channels + consumer's input channels
        leaves the network function unchanged."""
        g = cnn.dscnn(width=8)
        params = cnn.init_params(g, jax.random.key(0))
        folded = cnn.fold_batchnorm(g, params)
        x = jax.random.normal(jax.random.key(1), (2,) + g.in_shape)
        y_ref, _ = cnn.apply(g, folded, x, mode="float", folded=True)
        # permute stem output channels and fix up consumers (dw0 + pw0)
        perm = np.random.default_rng(0).permutation(8)
        p2 = {k: dict(v) for k, v in folded.items()}
        p2["stem"]["w"] = folded["stem"]["w"][perm]
        p2["stem"]["b"] = folded["stem"]["b"][perm]
        p2["dw0"]["w"] = folded["dw0"]["w"][perm]     # dw follows producer
        p2["dw0"]["b"] = folded["dw0"]["b"][perm]
        p2["pw0"]["w"] = folded["pw0"]["w"][:, perm]  # consumer cin perm
        y_perm, _ = cnn.apply(g, p2, x, mode="float", folded=True)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_perm),
                                   atol=1e-5)
