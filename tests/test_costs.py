"""Tests for the cost regularizers (paper Sec. 4.3)."""
import jax.numpy as jnp
import numpy as np

from repro.core import costs, mps, sampling

PW = (0, 2, 4, 8)
PX = (8,)


def _geom(cout=16, cin=8, k=3, hw=10, kind="conv", in_gamma=None):
    return costs.LayerGeom(name="l", kind=kind, cin=cin, cout=cout,
                           kx=k, ky=k, out_h=hw, out_w=hw, gamma="g",
                           in_gamma=in_gamma, in_delta=None)


def _onehot_gamma(cout, idx):
    return jnp.full((cout, len(PW)), -40.0).at[:, idx].set(40.0)


CTX = mps.SearchCtx(sampling.SOFTMAX, 1.0)


class TestSizeCost:
    def test_hand_computed_8bit(self):
        g = _geom()
        gammas = {"g": _onehot_gamma(16, 3)}   # all 8-bit
        c = costs.size_cost(g, gammas, {}, PW, PX, CTX)
        # cin * k * k * cout * 8 bits / 8 = bytes
        assert np.isclose(float(c), 8 * 9 * 16, rtol=1e-4)

    def test_pruned_channels_cost_zero(self):
        g = _geom()
        gammas = {"g": _onehot_gamma(16, 0)}   # all pruned
        assert float(costs.size_cost(g, gammas, {}, PW, PX, CTX)) < 1e-2

    def test_cin_eff_propagates_producer_pruning(self):
        producer = jnp.concatenate([_onehot_gamma(4, 0),
                                    _onehot_gamma(4, 3)])   # half pruned
        g = _geom(cin=8, in_gamma="p")
        gammas = {"g": _onehot_gamma(16, 3), "p": producer}
        c = costs.size_cost(g, gammas, {}, PW, PX, CTX)
        assert np.isclose(float(c), 4 * 9 * 16, rtol=1e-3)  # cin_eff = 4

    def test_monotone_in_bits(self):
        vals = [float(costs.size_cost(_geom(), {"g": _onehot_gamma(16, i)},
                                      {}, PW, PX, CTX)) for i in range(4)]
        assert vals[0] < vals[1] < vals[2] < vals[3]


class TestMPIC:
    def test_lut_structure(self):
        # homogeneous: 32/width SIMD lanes; w8a8 = 4 MACs/cycle
        assert costs.MPIC_LUT[(8, 8)] == 4.0
        assert costs.MPIC_LUT[(2, 2)] == 16.0
        # mixed precision faster than the slowest homogeneous operand pair
        assert costs.MPIC_LUT[(8, 2)] > costs.MPIC_LUT[(8, 8)]

    def test_weak_incentive_below_8bit_with_a8(self):
        """Fig. 8 insight: with 8-bit acts, MPIC barely rewards 4/2-bit
        weights (cost ratio << the 4x of the size model) -> pruning is the
        main lever."""
        g = _geom()
        c8 = float(costs.mpic_cost(g, {"g": _onehot_gamma(16, 3)}, {},
                                   PW, PX, CTX))
        c2 = float(costs.mpic_cost(g, {"g": _onehot_gamma(16, 1)}, {},
                                   PW, PX, CTX))
        assert 1.0 < c8 / c2 < 1.5    # vs 4.0 for the size regularizer
        c0 = float(costs.mpic_cost(g, {"g": _onehot_gamma(16, 0)}, {},
                                   PW, PX, CTX))
        assert c0 < 1e-3              # pruning removes the MACs entirely


class TestNE16:
    def test_32_channel_granularity_step(self):
        """Fig. 8 insight: 33 channels at one precision cost ~2 PE groups;
        the 33rd channel is nearly free to promote."""
        g33 = _geom(cout=33)
        g32 = _geom(cout=32)
        c33 = float(costs.ne16_cost(g33, {"g": _onehot_gamma(33, 3)}, {},
                                    PW, PX, CTX))
        c32 = float(costs.ne16_cost(g32, {"g": _onehot_gamma(32, 3)}, {},
                                    PW, PX, CTX))
        c64 = float(costs.ne16_cost(_geom(cout=64),
                                    {"g": _onehot_gamma(64, 3)}, {},
                                    PW, PX, CTX))
        # 33 channels cost much closer to 64 than to 32 (ceil step)
        assert (c33 - c32) > 0.5 * (c64 - c33)

    def test_latency_proportional_to_weight_bits(self):
        g = _geom(cout=32)
        c8 = float(costs.ne16_cost(g, {"g": _onehot_gamma(32, 3)}, {},
                                   PW, PX, CTX))
        c2 = float(costs.ne16_cost(g, {"g": _onehot_gamma(32, 1)}, {},
                                   PW, PX, CTX))
        assert 2.0 < c8 / c2 <= 4.5   # bit-serial PE: ~4x from 8b -> 2b

    def test_discrete_matches_soft_at_onehot(self):
        g = _geom(cout=32)
        soft = float(costs.ne16_cost(g, {"g": _onehot_gamma(32, 2)}, {},
                                     PW, PX, CTX))
        disc = costs.ne16_cycles_discrete(g, np.full(32, 4), cin_eff=8)
        assert np.isclose(soft, disc, rtol=1e-3)


class TestTPU:
    def test_sub8bit_does_not_cut_compute_but_cuts_memory(self):
        # big layer -> compute-bound: 8b vs 2b same cost
        g = _geom(cout=512, cin=512, k=3, hw=64)
        c8 = float(costs.tpu_cost(g, {"g": _onehot_gamma(512, 3)}, {},
                                  PW, PX, CTX))
        c2 = float(costs.tpu_cost(g, {"g": _onehot_gamma(512, 1)}, {},
                                  PW, PX, CTX))
        assert np.isclose(c8, c2, rtol=1e-5)
        # tiny spatial extent -> memory-bound: 2b is ~4x cheaper
        gm = _geom(cout=512, cin=512, k=3, hw=1)
        m8 = float(costs.tpu_cost(gm, {"g": _onehot_gamma(512, 3)}, {},
                                  PW, PX, CTX))
        m2 = float(costs.tpu_cost(gm, {"g": _onehot_gamma(512, 1)}, {},
                                  PW, PX, CTX))
        assert m8 / m2 > 3.0

    def test_pruning_cuts_compute(self):
        g = _geom(cout=512, cin=512, k=3, hw=64)
        half = jnp.concatenate([_onehot_gamma(256, 0),
                                _onehot_gamma(256, 3)])
        c_full = float(costs.tpu_cost(g, {"g": _onehot_gamma(512, 3)}, {},
                                      PW, PX, CTX))
        c_half = float(costs.tpu_cost(g, {"g": half}, {}, PW, PX, CTX))
        assert np.isclose(c_half, c_full / 2, rtol=0.05)


class TestBitops:
    def test_scales_with_both_precisions(self):
        g = _geom()
        deltas = {}
        c88 = float(costs.bitops_cost(g, {"g": _onehot_gamma(16, 3)},
                                      deltas, PW, (8,), CTX))
        c28 = float(costs.bitops_cost(g, {"g": _onehot_gamma(16, 1)},
                                      deltas, PW, (8,), CTX))
        assert np.isclose(c88 / c28, 4.0, rtol=1e-3)


def test_total_cost_dispatch_all_models():
    g = [_geom()]
    gammas = {"g": _onehot_gamma(16, 2)}
    for m in costs.COST_MODELS:
        v = float(costs.total_cost(g, gammas, {}, PW, PX, CTX, m))
        assert v > 0, m
