"""Tiny property-based testing shim (hypothesis is not installed in this
container). Provides `@given(...)` running the test over N seeded random
draws; strategies are plain callables (rng) -> value. No shrinking."""
from __future__ import annotations

import functools

import numpy as np

N_EXAMPLES = 25


def integers(lo, hi):
    return lambda rng: int(rng.integers(lo, hi + 1))


def floats(lo, hi):
    return lambda rng: float(rng.uniform(lo, hi))


def sampled_from(seq):
    seq = list(seq)
    return lambda rng: seq[int(rng.integers(0, len(seq)))]


def arrays(shape_strategy, lo=-3.0, hi=3.0, dtype=np.float32):
    def strat(rng):
        shape = shape_strategy(rng) if callable(shape_strategy) \
            else shape_strategy
        return rng.uniform(lo, hi, size=shape).astype(dtype)
    return strat


def shapes(max_rank=2, max_dim=64, min_dim=1):
    def strat(rng):
        rank = int(rng.integers(1, max_rank + 1))
        return tuple(int(rng.integers(min_dim, max_dim + 1))
                     for _ in range(rank))
    return strat


def given(**strategies):
    def deco(fn):
        # NOTE: no functools.wraps -- pytest must not see the test's real
        # signature, or it would treat the strategy args as fixtures
        def wrapper(*args, **kwargs):
            for i in range(N_EXAMPLES):
                rng = np.random.default_rng(1000 + i)
                drawn = {k: s(rng) for k, s in strategies.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except AssertionError as e:
                    raise AssertionError(
                        f"property failed on example {i}: "
                        f"{ {k: getattr(v, 'shape', v) for k, v in drawn.items()} }"
                    ) from e
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
