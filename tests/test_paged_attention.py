"""Paged-attention decode kernel tests.

The contract under test (see src/repro/kernels/README.md):
  * kernel.py (interpret mode) is bitwise identical to ref.py's
    paged_attention_ref under jit -- same per-page dots, same
    online-softmax update order;
  * ref.py's paged_attention_view (the off-TPU production path) is
    bitwise identical to blocks.decode_attention over the equivalent
    dense row (the PR 3 invariant);
  * null / never-written pages are skipped, not masked-after-read: a
    NaN-poisoned null page cannot reach the output;
  * the result depends only on the LOGICAL cache content -- physical
    page permutations, garbage in partial last pages, and freed
    mid-batch slots do not change live slots' outputs.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import kernel as pk
from repro.kernels.paged_attention import ops as pops
from repro.kernels.paged_attention import ref as pref
from repro.nn import blocks

import proptest as pt


def make_case(rng, lens, *, h=4, hkv=2, hd=16, ps=8, n_pb=4,
              n_pages=None, poison_null=False, poison_tail=None):
    """Build a pool + block tables for slots holding `lens` tokens each.

    Physical pages are drawn from a random permutation of the pool (so
    logical order != physical order); zero-length slots get an all-null
    table row (a freed / inactive slot).  ``poison_tail`` writes the
    given value into every allocated page position BEYOND the slot's
    live length (partial-last-page garbage)."""
    b = len(lens)
    if n_pages is None:
        n_pages = b * n_pb
    pool_k = rng.normal(size=(n_pages + 1, ps, hkv, hd)).astype(np.float32)
    pool_v = rng.normal(size=(n_pages + 1, ps, hkv, hd)).astype(np.float32)
    if poison_null:
        pool_k[0] = np.nan
        pool_v[0] = np.nan
    tables = np.zeros((b, n_pb), np.int32)
    perm = rng.permutation(np.arange(1, n_pages + 1))
    idx = 0
    pos = np.zeros((b,), np.int32)
    for bi, n in enumerate(lens):
        npg = -(-n // ps)
        for p in range(npg):
            tables[bi, p] = perm[idx]
            idx += 1
        pos[bi] = max(n - 1, 0)
        if poison_tail is not None and npg:
            last = tables[bi, npg - 1]
            off = n - (npg - 1) * ps
            pool_k[last, off:] = poison_tail
            pool_v[last, off:] = poison_tail
    q = rng.normal(size=(b, h, hd)).astype(np.float32)
    return (jnp.asarray(q), jnp.asarray(pool_k), jnp.asarray(pool_v),
            jnp.asarray(tables), jnp.asarray(pos))


def run(impl, case, **kw):
    fns = {"kernel": functools.partial(pk.paged_attention_fwd,
                                       interpret=True),
           "ref": pref.paged_attention_ref,
           "view": pref.paged_attention_view}
    return np.asarray(jax.jit(functools.partial(fns[impl], **kw))(*case))


class TestKernelVsRef:
    """kernel.py (interpret) must be bitwise equal to the mirror ref."""

    @pytest.mark.parametrize("hkv", [1, 2, 4])
    def test_gqa_group_sizes(self, hkv):
        rng = np.random.default_rng(hkv)
        case = make_case(rng, (5, 17, 0), hkv=hkv, poison_null=True)
        np.testing.assert_array_equal(run("kernel", case),
                                      run("ref", case))

    @pytest.mark.parametrize("window,chunked,cap", [
        (0, False, 0.0), (6, False, 0.0), (8, True, 0.0),
        (0, False, 30.0), (3, False, 50.0)])
    def test_mask_variants(self, window, chunked, cap):
        rng = np.random.default_rng(0)
        case = make_case(rng, (5, 17, 31), poison_null=True)
        kw = dict(window=window, chunked=chunked, cap=cap)
        np.testing.assert_array_equal(run("kernel", case, **kw),
                                      run("ref", case, **kw))

    @pt.given(seed=pt.integers(0, 10**6))
    def test_property_random_layouts(self, seed):
        """Random slot counts, lengths, page sizes and physical page
        permutations: kernel == ref bitwise, both ~= the gathered view."""
        rng = np.random.default_rng(seed)
        ps = int(rng.choice([1, 2, 4, 8]))
        n_pb = int(rng.integers(1, 5))
        max_len = ps * n_pb
        b = int(rng.integers(1, 4))
        lens = tuple(int(rng.integers(0, max_len + 1)) for _ in range(b))
        hkv = int(rng.choice([1, 2]))
        q, pool_k, pool_v, tables, pos = make_case(
            rng, lens, hkv=hkv, ps=ps, n_pb=n_pb)
        poisoned = (q, pool_k.at[0].set(jnp.nan),
                    pool_v.at[0].set(jnp.nan), tables, pos)
        out_k = run("kernel", poisoned)
        out_r = run("ref", poisoned)
        np.testing.assert_array_equal(out_k, out_r)
        assert np.isfinite(out_k).all()
        out_v = run("view", (q, pool_k, pool_v, tables, pos))
        for bi, n in enumerate(lens):
            if n > 0:           # view leaves inactive slots undefined
                np.testing.assert_allclose(out_k[bi], out_v[bi],
                                           rtol=2e-5, atol=2e-5)


class TestPoolSemantics:
    def test_view_bitwise_matches_dense_decode_attention(self):
        """Gathering the pages into logical order and running the dense
        decode-attention math must equal blocks.decode_attention on the
        equivalent dense cache row bit-for-bit (PR 3 invariant)."""
        rng = np.random.default_rng(1)
        lens = (5, 17, 26)
        ps, n_pb, hkv, hd = 8, 4, 2, 16
        q, pool_k, pool_v, tables, pos = make_case(
            rng, lens, hkv=hkv, hd=hd, ps=ps, n_pb=n_pb)
        # dense rows = the gathered view (stale content at masked
        # positions is irrelevant by construction of the mask)
        ck = np.asarray(pool_k)[np.asarray(tables)].reshape(
            len(lens), -1, hkv, hd)
        cv = np.asarray(pool_v)[np.asarray(tables)].reshape(
            len(lens), -1, hkv, hd)
        dense = jax.jit(blocks.decode_attention)(
            q[:, None], jnp.asarray(ck), jnp.asarray(cv), pos)
        view = jax.jit(pref.paged_attention_view)(
            q, pool_k, pool_v, tables, pos)
        np.testing.assert_array_equal(np.asarray(dense[:, 0]),
                                      np.asarray(view))

    def test_partial_last_page_garbage_is_ignored(self):
        lens = (5, 13)
        clean = make_case(np.random.default_rng(2), lens)
        dirty = make_case(np.random.default_rng(2), lens,
                          poison_tail=1e9)
        for impl in ("kernel", "ref", "view"):
            np.testing.assert_array_equal(run(impl, clean),
                                          run(impl, dirty))

    def test_null_page_is_skipped_not_masked(self):
        """NaN in the reserved null page must be unreachable: dead pages
        are skipped before any arithmetic (0 * NaN would still be NaN,
        so masking-after-read could not pass this)."""
        lens = (5, 17, 0)
        clean = make_case(np.random.default_rng(3), lens)
        poisoned = make_case(np.random.default_rng(3), lens,
                             poison_null=True)
        for impl in ("kernel", "ref"):
            out = run(impl, poisoned)
            assert np.isfinite(out).all()
            np.testing.assert_array_equal(out, run(impl, clean))

    def test_freed_slot_mid_batch(self):
        """Zeroing one slot's table row (free/preempt between steps)
        gives that slot a finite all-zero output and leaves the other
        slots bitwise untouched."""
        lens = (9, 20, 7)
        q, pk_, pv_, tables, pos = make_case(np.random.default_rng(4),
                                             lens, poison_null=True)
        freed_np = np.asarray(tables).copy()
        freed_np[1] = 0
        freed = jnp.asarray(freed_np)
        for impl in ("kernel", "ref"):
            before = run(impl, (q, pk_, pv_, tables, pos))
            after = run(impl, (q, pk_, pv_, freed, pos))
            np.testing.assert_array_equal(after[0], before[0])
            np.testing.assert_array_equal(after[2], before[2])
            np.testing.assert_array_equal(
                after[1], np.zeros_like(after[1]))

    def test_physical_permutation_invariance(self):
        """Two pools holding the same logical KV under different
        physical page layouts produce identical outputs."""
        rng = np.random.default_rng(5)
        lens = (9, 20)
        ps, n_pb, hkv, hd = 4, 8, 2, 16
        q, pk_a, pv_a, tables_a, pos = make_case(
            rng, lens, ps=ps, n_pb=n_pb, hkv=hkv, hd=hd)
        n_pages = pk_a.shape[0] - 1
        relayout = np.random.default_rng(6).permutation(
            np.arange(1, n_pages + 1))
        remap = np.zeros(n_pages + 1, np.int64)
        remap[1:] = relayout
        pk_b = np.zeros_like(np.asarray(pk_a))
        pv_b = np.zeros_like(np.asarray(pv_a))
        pk_b[remap[1:]] = np.asarray(pk_a)[1:]
        pv_b[remap[1:]] = np.asarray(pv_a)[1:]
        tables_b = remap[np.asarray(tables_a)].astype(np.int32)
        tables_b[np.asarray(tables_a) == 0] = 0
        case_b = (q, jnp.asarray(pk_b), jnp.asarray(pv_b),
                  jnp.asarray(tables_b), pos)
        for impl in ("kernel", "ref", "view"):
            np.testing.assert_array_equal(
                run(impl, (q, pk_a, pv_a, tables_a, pos)),
                run(impl, case_b))


class TestDispatch:
    def test_resolve_and_force(self):
        assert pops.resolve_impl("kernel") == "kernel"
        assert pops.resolve_impl() == ("kernel" if jax.default_backend()
                                       == "tpu" else "view")
        with pops.force_impl("ref"):
            assert pops.resolve_impl() == "ref"
        assert pops.resolve_impl() != "ref"
        with pytest.raises(ValueError, match="impl"):
            pops.resolve_impl("bogus")

    def test_ops_entry_point_all_impls_agree(self):
        case = make_case(np.random.default_rng(7), (6, 11))
        outs = {impl: np.asarray(jax.jit(functools.partial(
            pops.paged_attention, impl=impl))(*case))
            for impl in ("kernel", "ref", "view")}
        np.testing.assert_array_equal(outs["kernel"], outs["ref"])
        np.testing.assert_allclose(outs["kernel"], outs["view"],
                                   rtol=2e-5, atol=2e-5)
