"""Chaos subsystem tests: the fault-schedule parser and injector,
parameter poisoning, the health monitor's observational state machine,
retry backoff, and the headline robustness invariant -- a mid-decode
replica crash (or NaN quarantine) with failover leaves every surviving
and recovered request's token stream byte-identical to the fault-free
run, across dense/paged caches and float/plan-quantized tiers."""
import json

import jax
import numpy as np
import pytest

from repro import chaos
from repro.chaos import inject as chaos_inject
from repro.configs import registry
from repro.fleet import (HEALTH_STATES, Fleet, FleetRequest,
                         HealthMonitor, Replica, TierSpec)
from repro.models import lm
from repro.obs import RequestTracer
from repro.obs.validate import validate_trace_lines
from repro.serve import engine
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import Request


@pytest.fixture(scope="module")
def llama():
    cfg = registry.get("llama3.2-1b-smoke")
    return cfg, lm.init_params(cfg, jax.random.key(0))


@pytest.fixture(scope="module")
def schema():
    with open("tests/obs_schema.json") as f:
        return json.load(f)


@pytest.fixture(scope="module")
def fleets(llama):
    """Homogeneous two-replica fleets (recovery must land on an
    identical tier for byte-identity), built lazily and cached per
    (cache, plan) combo so each compiles once per module."""
    cfg, params = llama
    cache: dict = {}

    def get(backend: str, plan_kind: str) -> Fleet:
        key = (backend, plan_kind)
        if key not in cache:
            plan = (None if plan_kind == "float"
                    else engine.synthetic_plan(cfg, params, bits=None,
                                               seed=0))
            pairs = []
            for name in ("a", "b"):
                tier = TierSpec(name=name, plan=plan, step_ms=8.0,
                                quality=16.0)
                srv = engine.InferenceServer(
                    cfg, params, plan=plan, max_len=64, max_batch=2,
                    cache=backend, page_size=8, pages=None)
                pairs.append((tier, srv))
            cache[key] = Fleet(pairs, policy="round_robin")
        return cache[key]

    return get


def _trace(cfg, n=6, *, deadline_ms=None, retry_budget=1, max_tokens=8):
    rng = np.random.default_rng(0)
    return [FleetRequest(
        request=Request(
            uid=i,
            prompt=np.asarray(rng.integers(1, cfg.vocab, 6), np.int32),
            sampling=SamplingParams(temperature=0.8, top_k=8,
                                    max_tokens=max_tokens, seed=7)),
        arrival_ms=5.0 * i, deadline_ms=deadline_ms,
        retry_budget=retry_budget) for i in range(n)]


def _run(flt, cfg, *, chaos_sched=None, failover=True, **kw):
    flt.chaos = (chaos.ChaosInjector(chaos_sched)
                 if chaos_sched is not None else None)
    flt.failover = failover
    try:
        return flt.run(_trace(cfg, **kw))
    finally:
        flt.chaos = None
        flt.failover = True


# ---------------------------------------------------------------------------
# schedule parser + injector
# ---------------------------------------------------------------------------

class TestParser:
    def test_same_seed_same_schedule(self):
        a = chaos.parse_chaos("crash+slow", targets=["x", "y"], seed=3)
        b = chaos.parse_chaos("crash+slow", targets=["x", "y"], seed=3)
        assert a == b
        c = chaos.parse_chaos("crash+slow", targets=["x", "y"], seed=4)
        assert a != c

    def test_pinned_fields_stay_pinned(self):
        (spec,) = chaos.parse_chaos("crash@40-200:x", targets=["x", "y"],
                                    seed=0)
        assert spec.kind == "crash" and spec.target == "x"
        assert spec.t_ms == 40.0 and spec.until_ms == 200.0
        # pinning one token's fields must not shift another's draws
        a = chaos.parse_chaos("crash@40:x+slow", targets=["x"], seed=1)
        b = chaos.parse_chaos("crash@90:x+slow", targets=["x"], seed=1)
        assert a[1] == b[1]

    def test_modifiers(self):
        (slow,) = chaos.parse_chaos("slow@10-50:x6:y", targets=["y"],
                                    seed=0)
        assert slow.factor == 6.0 and slow.target == "y"
        (pool,) = chaos.parse_chaos("pool_pressure@10-50:p3",
                                    targets=["x"], seed=0)
        assert pool.pages == 3

    def test_errors(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            chaos.parse_chaos("melt", targets=["x"], seed=0)
        with pytest.raises(ValueError, match="target"):
            chaos.parse_chaos("crash:nope", targets=["x"], seed=0)
        with pytest.raises(ValueError):
            chaos.FaultSpec(kind="slow", target="x", t_ms=50.0,
                            until_ms=10.0)

    def test_describe_round_trips_fields(self):
        (spec,) = chaos.parse_chaos("crash", targets=["x"], seed=0,
                                    horizon_ms=1000.0)
        assert "crash" in spec.describe() and "x" in spec.describe()
        assert 200.0 <= spec.t_ms <= 500.0     # [0.2, 0.5] * horizon


class TestInjector:
    def test_due_is_once_and_ordered(self):
        sched = [
            chaos.FaultSpec(kind="slow", target="x", t_ms=10.0,
                            until_ms=50.0, factor=2.0),
            chaos.FaultSpec(kind="crash", target="y", t_ms=30.0,
                            until_ms=90.0),
        ]
        inj = chaos.ChaosInjector(sched)
        assert inj.next_time() == 10.0
        assert [p for p, _ in inj.due(10.0)] == ["inject"]
        assert inj.due(10.0) == []                     # delivered once
        assert inj.next_time() == 30.0
        got = inj.due(100.0)
        assert [(p, s.kind) for p, s in got] == [
            ("inject", "crash"), ("restore", "slow"),
            ("restore", "crash")]
        assert inj.exhausted and inj.next_time() is None

    def test_poison_params_and_undo(self):
        class Srv:
            pass
        srv = Srv()
        w = np.ones((4, 4), np.float32)
        srv.params = {"blocks": [{"attn": {"wq": w}}],
                      "emb": np.ones((8, 4), np.float32)}
        undo = chaos_inject.poison_params(srv)
        assert np.isnan(srv.params["blocks"][0]["attn"]["wq"]).all()
        # only the first matching leaf is poisoned; nothing else moves
        assert not np.isnan(srv.params["emb"]).any()
        assert not np.isnan(w).any()           # original untouched
        undo()
        assert srv.params["blocks"][0]["attn"]["wq"] is w

    def test_poison_params_hits_packed_scales(self, llama):
        cfg, params = llama
        srv = engine.InferenceServer(
            cfg, params,
            plan=engine.synthetic_plan(cfg, params, bits=8),
            max_len=32, max_batch=1, cache="dense")
        old = srv.params
        undo = chaos_inject.poison_params(srv)
        leaves = jax.tree_util.tree_leaves(srv.params["blocks"])
        assert any(np.isnan(np.asarray(x)).any() for x in leaves
                   if np.asarray(x).dtype.kind == "f")
        undo()
        assert srv.params is old


# ---------------------------------------------------------------------------
# health monitor (observational: driven by fake load reports)
# ---------------------------------------------------------------------------

class _FakeServer:
    def __init__(self):
        self.load = {"queued": 0, "active": 1, "queued_tokens": 0,
                     "active_tokens": 4, "pages_in_use": 1,
                     "pages_free": 3, "steps": 0}

    def load_report(self):
        return dict(self.load)


def _fake_rep(step_ms=8.0):
    return Replica(tier=TierSpec(name="r", plan=None, step_ms=step_ms,
                                 quality=16.0), server=_FakeServer())


class TestHealthMonitor:
    def test_watchdog_degrades_and_heals(self):
        hm = HealthMonitor(watchdog_factor=3.0)
        rep = _fake_rep()
        hm.start(["r"])
        t = 0.0
        for _ in range(3):                    # healthy cadence: 8 ms
            rep.server.load["steps"] += 1
            t += 8.0
            hm.observe(rep, t)
        assert hm.state("r") == "healthy"
        rep.server.load["steps"] += 1
        t += 50.0                             # stalled: 50 ms spacing
        hm.observe(rep, t)
        assert hm.state("r") == "degraded"
        assert hm.eta_multiplier("r") == pytest.approx(50.0 / 8.0)
        rep.server.load["steps"] += 1
        t += 8.0
        hm.observe(rep, t)
        assert hm.state("r") == "healthy"
        assert hm.eta_multiplier("r") == 1.0

    def test_idle_gap_is_not_a_stall(self):
        hm = HealthMonitor()
        rep = _fake_rep()
        hm.start(["r"])
        rep.server.load["steps"] = 5
        hm.observe(rep, 8.0)
        rep.server.load.update(active=0, queued=0)   # burst drained
        hm.observe(rep, 500.0)
        rep.server.load.update(active=1, steps=6)    # next burst
        hm.observe(rep, 508.0)
        assert hm.state("r") == "healthy"

    def test_down_warming_probe_cycle(self):
        hm = HealthMonitor()
        rep = _fake_rep()
        hm.start(["r"])
        rep.down = True
        hm.observe(rep, 10.0)
        assert hm.state("r") == "down"
        assert not hm.routable("r")
        rep.down = False                      # session reopened
        hm.observe(rep, 20.0)
        assert hm.state("r") == "warming"
        assert not hm.routable("r")           # gated on the probe
        hm.observe(rep, 25.0)
        assert hm.state("r") == "warming"
        hm.probe_done("r", True, 30.0)
        assert hm.state("r") == "healthy" and hm.routable("r")

    def test_draining_on_pool_starvation(self):
        hm = HealthMonitor()
        rep = _fake_rep()
        hm.start(["r"])
        rep.server.load.update(pages_free=0, queued=2)
        hm.observe(rep, 5.0)
        assert hm.state("r") == "draining" and not hm.routable("r")
        rep.server.load.update(pages_free=2)
        hm.observe(rep, 10.0)
        assert hm.state("r") == "healthy"

    def test_states_and_validation(self):
        hm = HealthMonitor()
        hm.start(["r"])
        with pytest.raises(ValueError, match="unknown health state"):
            hm.mark("r", "zombie", 0.0)
        assert set(HEALTH_STATES) == {"healthy", "degraded", "down",
                                      "draining", "warming"}
        with pytest.raises(ValueError, match="watchdog_factor"):
            HealthMonitor(watchdog_factor=1.0)


# ---------------------------------------------------------------------------
# trace grammar: fault terminals + recovered
# ---------------------------------------------------------------------------

class TestFaultLifecycleGrammar:
    def test_crash_recover_episode_chain(self):
        ok = ["enqueued", "admitted", "prefilled", "first_token",
              "crashed", "recovered", "enqueued", "admitted",
              "prefilled", "first_token", "decode", "finished"]
        assert RequestTracer.check_lifecycle(ok) is None

    def test_stream_may_end_at_recovered(self):
        # per-replica stream: the marker lives on the struck replica's
        # tracer, the re-enqueue on the survivor's
        assert RequestTracer.check_lifecycle(
            ["enqueued", "crashed", "recovered"]) is None

    def test_recovered_needs_fault_terminal(self):
        err = RequestTracer.check_lifecycle(
            ["enqueued", "timeout", "recovered", "enqueued",
             "finished"])
        assert err is not None and "recovered" in err

    def test_fault_terminal_ends_episode(self):
        assert RequestTracer.check_lifecycle(
            ["enqueued", "quarantined"]) is None
        err = RequestTracer.check_lifecycle(
            ["enqueued", "crashed", "decode"])
        assert err is not None


# ---------------------------------------------------------------------------
# the headline invariant
# ---------------------------------------------------------------------------

class TestCrashByteIdentity:
    """A mid-decode crash with failover must not change a single token:
    per-uid sampling streams are pure functions of (seed, uid,
    token_index), so recompute-style recovery on an identical tier
    replays them byte-identically."""

    @pytest.mark.parametrize("backend,plan_kind", [
        ("dense", "float"), ("dense", "plan"),
        ("paged", "float"), ("paged", "plan")])
    def test_crash_recovery_is_byte_identical(self, fleets, llama,
                                              schema, backend,
                                              plan_kind):
        cfg, _ = llama
        flt = fleets(backend, plan_kind)
        ref = _run(flt, cfg)
        assert all(r.status == "finished" for r in ref.values())
        sched = [chaos.FaultSpec(kind="crash", target="a", t_ms=30.0,
                                 until_ms=120.0)]
        got = _run(flt, cfg, chaos_sched=sched)
        recovered = [u for u, r in got.items()
                     if any(a.cause == "recovered:crashed"
                            for a in r.attempts)]
        assert recovered, "the crash must catch requests in flight"
        for uid, r in got.items():
            assert r.status == "finished"
            assert np.array_equal(r.tokens, ref[uid].tokens), uid
        # the struck replica came back through warming -> probe
        assert flt.health.states() == {"a": "healthy", "b": "healthy"}
        # zero page leaks: every replica's pool drained back to empty
        for rep in flt.replicas:
            mem = rep.server.backend.memory_report()
            assert mem.get("pages_in_use", 0) == 0
            assert mem.get("pages_withheld", 0) == 0
        # merged + per-replica streams satisfy the lifecycle grammar
        lines = [json.dumps(d, sort_keys=True)
                 for d in flt.trace_events()]
        assert validate_trace_lines(lines, schema) == []
        for rep in flt.replicas:
            evs = [json.dumps(e.to_json(), sort_keys=True)
                   for e in rep.server.obs.tracer.events]
            assert validate_trace_lines(evs, schema) == []

    def test_nan_quarantine_is_byte_identical(self, fleets, llama,
                                              schema):
        """The NaN-poisoned plan trips the engine's sampling-boundary
        guard; the poisoned step's tokens are discarded, so recovered
        streams still match the fault-free run bit-for-bit."""
        cfg, _ = llama
        flt = fleets("paged", "plan")
        ref = _run(flt, cfg)
        sched = [chaos.FaultSpec(kind="nan_plan", target="a", t_ms=30.0,
                                 until_ms=150.0)]
        got = _run(flt, cfg, chaos_sched=sched)
        assert any(a.cause == "recovered:quarantined"
                   for r in got.values() for a in r.attempts)
        for uid, r in got.items():
            assert np.array_equal(r.tokens, ref[uid].tokens), uid
        snap = flt.registry.snapshot()
        assert "fault_nan_detected_total" in snap
        lines = [json.dumps(d, sort_keys=True)
                 for d in flt.trace_events()]
        assert validate_trace_lines(lines, schema) == []


# ---------------------------------------------------------------------------
# failover off, pool pressure, slow faults, backoff
# ---------------------------------------------------------------------------

class TestFaultBehaviors:
    def test_no_failover_requests_die_crashed(self, fleets, llama):
        cfg, _ = llama
        flt = fleets("paged", "float")
        sched = [chaos.FaultSpec(kind="crash", target="a", t_ms=30.0,
                                 until_ms=120.0)]
        got = _run(flt, cfg, chaos_sched=sched, failover=False,
                   deadline_ms=500.0)
        crashed = [r for r in got.values() if r.status == "crashed"]
        assert crashed
        assert all(not r.deadline_met for r in crashed)
        assert all(r.status in ("finished", "crashed")
                   for r in got.values())

    def test_pool_pressure_withholds_and_restores(self, fleets, llama):
        cfg, _ = llama
        flt = fleets("paged", "float")
        ref = _run(flt, cfg)
        sched = [chaos.FaultSpec(kind="pool_pressure", target="a",
                                 t_ms=10.0, until_ms=100.0, pages=100)]
        got = _run(flt, cfg, chaos_sched=sched)
        for uid, r in got.items():       # squeezed, never corrupted
            assert r.status == "finished"
            assert np.array_equal(r.tokens, ref[uid].tokens), uid
        for rep in flt.replicas:
            assert rep.server.backend.memory_report().get(
                "pages_withheld", 0) == 0

    def test_slow_fault_degrades_then_heals(self, fleets, llama):
        cfg, _ = llama
        flt = fleets("paged", "float")
        sched = [chaos.FaultSpec(kind="slow", target="a", t_ms=20.0,
                                 until_ms=200.0, factor=6.0)]
        got = _run(flt, cfg, chaos_sched=sched)
        assert all(r.status == "finished" for r in got.values())
        snap = flt.registry.snapshot()
        series = snap["health_transitions_total"]["series"]
        states = {(s["labels"]["replica"], s["labels"]["state"])
                  for s in series}
        assert ("a", "degraded") in states
        assert flt.health.states()["a"] == "healthy"

    def test_retry_backoff_is_bounded_exponential(self, fleets, llama):
        cfg, _ = llama
        flt = fleets("paged", "float")
        got = _run(flt, cfg, n=2, deadline_ms=40.0, retry_budget=3,
                   max_tokens=12)
        delays = [ev["retry_delay_ms"] for ev in flt.trace_events()
                  if ev["kind"] == "enqueued"
                  and "retry_delay_ms" in ev]
        assert delays, "the tight deadline must force retries"
        # doubling from the base, capped
        for i, d in enumerate(sorted(set(delays))):
            assert d == min(25.0 * 2 ** i, 400.0)
        for rec in got.values():
            for prev, nxt in zip(rec.attempts, rec.attempts[1:]):
                assert nxt.t_start >= prev.t_start + 25.0

    def test_store_corrupt_is_not_a_fleet_fault(self, fleets, llama):
        cfg, _ = llama
        flt = fleets("paged", "float")
        sched = [chaos.FaultSpec(kind="store_corrupt", target="a",
                                 t_ms=1.0)]
        with pytest.raises(ValueError, match="PlanStore"):
            _run(flt, cfg, chaos_sched=sched)


class TestEngineNaNGuard:
    def test_solo_serve_raises_on_poisoned_params(self, llama):
        cfg, params = llama
        srv = engine.InferenceServer(cfg, params, max_len=32,
                                     max_batch=1, cache="dense")
        req = Request(uid=0,
                      prompt=np.asarray([1, 2, 3], np.int32),
                      sampling=SamplingParams(max_tokens=4))
        out = srv.serve([req])          # sane params: fine
        assert out[0].size == 4
        undo = chaos_inject.poison_params(srv)
        try:
            with pytest.raises(RuntimeError, match="NaN"):
                srv.serve([req])
        finally:
            undo()
        out2 = srv.serve([req])         # restored: identical again
        assert np.array_equal(out2[0], out[0])
