"""Tests for the composable compression API: CompressionPlan round-trip,
plan-driven serving export, the pluggable cost-model registry, phase/config
validation, and checkpoint/resume through the Compressor."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro import api
from repro.checkpoint.checkpoint import CheckpointManager
from repro.core import costs, pipeline
from repro.data import synthetic
from repro.models import cnn
from repro.serve import engine


def _toy_assignment(rng, groups=("a", "b"), c=24):
    gamma = {g: rng.choice([0, 2, 4, 8], size=c) for g in groups}
    delta = {f"n{i}": int(b) for i, b in enumerate((8, 4))}
    alpha = {f"n{i}": float(a) for i, a in enumerate((5.5, 3.25))}
    return {"gamma": gamma, "delta": delta, "alpha": alpha}


class TestCompressionPlan:
    def test_save_load_round_trip_exact(self, tmp_path):
        rng = np.random.default_rng(0)
        plan = api.CompressionPlan.from_assignment(
            _toy_assignment(rng), pw=(0, 2, 4, 8), px=(4, 8),
            meta={"cost_model": "size", "lam": 2.5})
        npz = plan.save(str(tmp_path / "plan"))
        loaded = api.CompressionPlan.load(npz)
        assert plan.equals(loaded)
        assert loaded.pw == (0, 2, 4, 8) and loaded.px == (4, 8)
        assert loaded.meta == {"cost_model": "size", "lam": 2.5}
        for grp in plan.channel_bits:
            np.testing.assert_array_equal(plan.channel_bits[grp],
                                          loaded.channel_bits[grp])
            np.testing.assert_array_equal(plan.permutations[grp],
                                          loaded.permutations[grp])
        assert loaded.alphas == plan.alphas
        assert loaded.act_bits == plan.act_bits

    def test_equals_detects_mutation(self, tmp_path):
        rng = np.random.default_rng(1)
        plan = api.CompressionPlan.from_assignment(
            _toy_assignment(rng), pw=(0, 2, 4, 8), px=(8,))
        loaded = api.CompressionPlan.load(plan.save(str(tmp_path / "p")))
        loaded.channel_bits["a"][0] = 8 if loaded.channel_bits["a"][0] != 8 \
            else 4
        assert not plan.equals(loaded)

    def test_assignment_round_trip_and_metrics(self):
        rng = np.random.default_rng(2)
        assignment = _toy_assignment(rng)
        plan = api.CompressionPlan.from_assignment(assignment,
                                                   pw=(0, 2, 4, 8), px=(8,))
        back = plan.to_assignment()
        for grp, bits in assignment["gamma"].items():
            np.testing.assert_array_equal(back["gamma"][grp], bits)
        assert back["delta"] == assignment["delta"]
        assert back["alpha"] == pytest.approx(assignment["alpha"])
        all_bits = np.concatenate(list(assignment["gamma"].values()))
        assert plan.prune_fraction() == pytest.approx(
            float(np.mean(all_bits == 0)))
        for grp, segs in plan.sublayer_split().items():
            sorted_bits = assignment["gamma"][grp][plan.permutations[grp]]
            for b, start, stop in segs:
                assert set(sorted_bits[start:stop]) == {b}

    def test_loaded_plan_serves_identically(self, tmp_path):
        """A plan that went through disk must drive the Fig. 3 serving
        export to byte-identical packed layers."""
        rng = np.random.default_rng(3)
        plan = api.CompressionPlan.from_assignment(
            _toy_assignment(rng, c=40), pw=(0, 2, 4, 8), px=(8,))
        loaded = api.CompressionPlan.load(plan.save(str(tmp_path / "p")))
        weights = {g: rng.normal(size=(40, 32)).astype(np.float32) * 0.2
                   for g in plan.channel_bits}
        mem = engine.export_plan_layers(plan, weights)
        disk = engine.export_plan_layers(loaded, weights)
        for grp in weights:
            packed_m, perm_m, kept_m = mem[grp]
            packed_d, perm_d, kept_d = disk[grp]
            assert kept_m == kept_d
            np.testing.assert_array_equal(perm_m, perm_d)
            assert len(packed_m) == len(packed_d)
            for (bm, wm, sm), (bd, wd, sd) in zip(packed_m, packed_d):
                assert bm == bd
                np.testing.assert_array_equal(np.asarray(wm),
                                              np.asarray(wd))
                np.testing.assert_array_equal(np.asarray(sm),
                                              np.asarray(sd))
            # and the packed groups actually serve
            y = engine.mixed_precision_matmul(
                jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32)),
                packed_d)
            assert y.shape == (4, kept_d)


class _ConstantishCost:
    """Toy hardware model: total kept-channel count (differentiable)."""

    name = "test-keptcount"

    def expected(self, geom, gammas, deltas, pw, px, ctx):
        from repro.core import mps
        keep = mps.keep_probability(gammas[geom.gamma], pw, ctx)
        if keep.shape[0] == 1:
            return keep[0] * float(geom.cout)
        return jnp.sum(keep)

    def discrete(self, geom, channel_bits, cin_eff, act_bits=8):
        return float(np.sum(np.asarray(channel_bits) > 0))


class TestCostModelRegistry:
    def test_builtins_registered(self):
        assert set(costs.COST_MODELS) <= set(api.available_cost_models())
        for name in costs.COST_MODELS:
            model = api.get_cost_model(name)
            assert model.name == name

    def test_unknown_name_is_clear_error(self):
        with pytest.raises(KeyError, match="unknown cost model"):
            api.get_cost_model("no-such-hw")

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            api.register_cost_model(
                api.FunctionCostModel("size", lambda *a: 0.0,
                                      lambda *a: 0.0))

    def test_custom_model_usable_by_name_in_search(self):
        """A model registered OUTSIDE core/costs.py drives total_cost and a
        real (tiny) search by registry name."""
        if "test-keptcount" not in api.available_cost_models():
            api.register_cost_model(_ConstantishCost())
        g = cnn.dscnn(width=8)
        geoms = cnn.cost_geoms(g)
        mps_params = cnn.init_mps_params(g, (0, 2, 4, 8), (8,))
        from repro.core import mps, sampling
        ctx = mps.SearchCtx(sampling.SOFTMAX, 1.0)
        total = float(costs.total_cost(geoms, mps_params["gamma"],
                                       mps_params["delta"], (0, 2, 4, 8),
                                       (8,), ctx, model="test-keptcount"))
        n_channels = sum(gm.cout for gm in geoms)
        assert 0 < total <= n_channels

        comp = api.Compressor(g, synthetic.GSC_LIKE, batch=8, seed=0)
        res = comp.run([api.Warmup(steps=4),
                        api.JointSearch(steps=4, lam=1.0,
                                        cost_model="test-keptcount"),
                        api.Finetune(steps=2)])
        assert res.plan is not None
        assert res.plan.meta["cost_model"] == "test-keptcount"


class TestValidation:
    def test_search_config_rejects_bad_values(self):
        with pytest.raises(ValueError, match="nonzero precision"):
            pipeline.SearchConfig(pw=(0,))
        with pytest.raises(ValueError, match="tau_end"):
            pipeline.SearchConfig(tau_end=2.0)
        with pytest.raises(ValueError, match="search_steps"):
            pipeline.SearchConfig(search_steps=0)
        with pytest.raises(ValueError, match="px"):
            pipeline.SearchConfig(px=())
        with pytest.raises(ValueError, match="sampler"):
            pipeline.SearchConfig(sampler="dice")
        with pytest.raises(ValueError, match="batch"):
            pipeline.SearchConfig(batch=0)

    def test_phase_configs_reject_bad_values(self):
        with pytest.raises(ValueError, match="steps"):
            api.Warmup(steps=-1)
        with pytest.raises(ValueError, match="anneal"):
            api.JointSearch(tau_end=2.0)
        with pytest.raises(ValueError, match="steps"):
            api.JointSearch(steps=0)
        with pytest.raises(ValueError, match="lr"):
            api.Finetune(lr=0.0)

    def test_compressor_rejects_bad_spaces(self):
        g = cnn.dscnn(width=8)
        with pytest.raises(ValueError, match="nonzero"):
            api.Compressor(g, synthetic.GSC_LIKE, pw=(0,))
        with pytest.raises(ValueError, match="px"):
            api.Compressor(g, synthetic.GSC_LIKE, px=())

    def test_search_without_warmup_is_clear_error(self):
        g = cnn.dscnn(width=8)
        comp = api.Compressor(g, synthetic.GSC_LIKE, batch=8)
        with pytest.raises(RuntimeError, match="Warmup"):
            comp.run([api.JointSearch(steps=2)])


class TestCheckpointResume:
    def test_interrupted_search_resumes_to_identical_plan(self, tmp_path):
        g = cnn.dscnn(width=8)
        comp = api.Compressor(g, synthetic.GSC_LIKE, batch=8, seed=0)
        mk = lambda: [api.Warmup(steps=8),                       # noqa: E731
                      api.JointSearch(steps=16, lam=5.0),
                      api.Finetune(steps=4)]
        reference = comp.run(mk())

        class Boom(api.Hook):
            def on_step(self, phase, state, step, metrics, train_state):
                if phase.name == "search" and step == 11:
                    raise RuntimeError("boom")

        mgr = CheckpointManager(str(tmp_path), keep=3)
        with pytest.raises(RuntimeError, match="boom"):
            comp.run(mk(), hooks=[Boom()], checkpoint=mgr,
                     checkpoint_every=4)
        mgr.wait()
        assert mgr.all_steps()          # something was checkpointed

        resumed = comp.run(mk(), checkpoint=CheckpointManager(
            str(tmp_path), keep=3), checkpoint_every=4)
        assert resumed.plan.equals(reference.plan)
        assert resumed.acc_final == reference.acc_final

    def test_resumed_run_does_not_reemit_replayed_metrics(self, tmp_path):
        """Metric emission must be idempotent under checkpoint resume: a
        resume restores to an earlier checkpoint and replays the steps up
        to the crash point, and those replayed steps flow through the
        hooks again -- the shared registry must not double-count them."""
        from repro.obs import MetricsRegistry

        g = cnn.dscnn(width=8)
        comp = api.Compressor(g, synthetic.GSC_LIKE, batch=8, seed=0)
        noop = lambda *_args, **_kw: None                    # noqa: E731
        mk = lambda: [api.Warmup(steps=8),                   # noqa: E731
                      api.JointSearch(steps=16, lam=5.0),
                      api.Finetune(steps=4)]

        # reference: uninterrupted run, every step logged once
        ref_reg = MetricsRegistry()
        comp.run(mk(), hooks=[api.MetricsLog(every=1, printer=noop)],
                 registry=ref_reg)
        ref_pts = ref_reg.counter("compress_step_points_total",
                                  labels=("phase", "metric"))
        assert ref_pts.value(phase="search", metric="task") == 16
        assert ref_pts.value(phase="warmup", metric="loss") == 8

        class Boom(api.Hook):
            def on_step(self, phase, state, step, metrics, train_state):
                if phase.name == "search" and step == 11:
                    raise RuntimeError("boom")

        # crash at search step 11 (checkpoints every 4 -> restore to
        # step 8, replaying steps 8-11), then resume with the SAME
        # registry -- the process-survives-the-crash scenario
        reg = MetricsRegistry()
        mgr = CheckpointManager(str(tmp_path), keep=3)
        with pytest.raises(RuntimeError, match="boom"):
            comp.run(mk(),
                     hooks=[api.MetricsLog(every=1, printer=noop), Boom()],
                     checkpoint=mgr, checkpoint_every=4, registry=reg)
        mgr.wait()
        resumed = comp.run(
            mk(), hooks=[api.MetricsLog(every=1, printer=noop)],
            checkpoint=CheckpointManager(str(tmp_path), keep=3),
            checkpoint_every=4, registry=reg)

        pts = reg.counter("compress_step_points_total",
                          labels=("phase", "metric"))
        for phase, metric, total in [("warmup", "loss", 8),
                                     ("search", "task", 16),
                                     ("search", "reg", 16),
                                     ("finetune", "loss", 4)]:
            assert pts.value(phase=phase, metric=metric) == total, \
                (phase, metric)
        # phase wall time reached the registry too
        assert reg.gauge("compress_phase_seconds", labels=("phase",)) \
            .value(phase="search") > 0
        # and the compression outcome is untouched by the registry
        assert resumed.plan is not None

    def test_resume_bit_exact_with_activation_mps(self, tmp_path):
        """Regression: the cost normalizer must be rebuilt from the INITIAL
        delta logits on resume. With px > 1 option and a delta-dependent
        cost model, reading the trained deltas instead would change
        cost_scale and diverge the resumed run."""
        g = cnn.dscnn(width=8)
        comp = api.Compressor(g, synthetic.GSC_LIKE, px=(2, 4, 8), batch=8,
                              seed=0)
        mk = lambda: [api.Warmup(steps=4),                       # noqa: E731
                      api.JointSearch(steps=12, lam=5.0,
                                      cost_model="bitops"),
                      api.Finetune(steps=2)]
        reference = comp.run(mk())

        class Boom(api.Hook):
            def on_step(self, phase, state, step, metrics, train_state):
                if phase.name == "search" and step == 9:
                    raise RuntimeError("boom")

        mgr = CheckpointManager(str(tmp_path), keep=3)
        with pytest.raises(RuntimeError, match="boom"):
            comp.run(mk(), hooks=[Boom()], checkpoint=mgr,
                     checkpoint_every=4)
        mgr.wait()
        resumed = comp.run(mk(), checkpoint=CheckpointManager(
            str(tmp_path), keep=3), checkpoint_every=4)
        assert resumed.plan.equals(reference.plan)
        assert resumed.acc_final == reference.acc_final

    def test_in_phase_checkpoints_are_incremental(self, tmp_path):
        """In-phase saves must carry the train state plus only changed
        carry leaves (delta vs. the pinned phase-start snapshot) -- not a
        full carry copy per save -- and still resume."""
        import numpy as np

        g = cnn.dscnn(width=8)
        comp = api.Compressor(g, synthetic.GSC_LIKE, batch=8, seed=0)
        mgr = CheckpointManager(str(tmp_path), keep=3)
        comp.run([api.Warmup(steps=4), api.JointSearch(steps=8, lam=5.0)],
                 checkpoint=mgr, checkpoint_every=4)
        mgr.wait()

        tag = 1_000_004                      # search phase, step 4
        assert tag in mgr.all_steps()
        meta = mgr.peek_meta(tag)
        assert meta["carry_base_tag"] == 1_000_000
        assert meta["carry_delta_keys"] == []     # carry static in-phase
        with np.load(mgr._fname(tag), allow_pickle=False) as z:
            keys = [k for k in z.files if k != "__meta__"]
        assert keys and all(k.startswith("train/") for k in keys)
        # the pinned base holds the full carry and survives retention GC
        base_meta = mgr.peek_meta(1_000_000)
        assert base_meta["boundary"] and base_meta["has_folded"]

    def test_hooks_record_metrics(self):
        g = cnn.dscnn(width=8)
        comp = api.Compressor(g, synthetic.GSC_LIKE, batch=8, seed=0)
        logged = []
        res = comp.run(
            [api.Warmup(steps=4), api.JointSearch(steps=4, lam=1.0),
             api.Finetune(steps=2)],
            hooks=[api.MetricsLog(every=2, printer=logged.append),
                   api.PeriodicEval(every=4, n_batches=1)])
        assert any(line.startswith("  search") for line in logged)
        assert "search" in res.metrics
        assert any("acc_quant" in m for m in res.metrics["search"])
