"""CacheBackend tests: PagedCache page bookkeeping, the scheduler's
memory-aware admission contract (pool-exhaustion queuing, preemption
requeue ordering), page free-on-retire leak checks, paged-vs-dense
token-for-token parity across mixed prompt lengths (float + quantized,
greedy + seeded device sampling, streaming + preemption, gathered-view
AND Pallas-kernel attention impls), the paged-PREFILL conformance
matrix (solo/batched/streaming/preempted re-prefill under every prefill
impl, page-boundary prompt footprints, one bounded table upload per
admission), the device-resident block tables (no per-step host sync),
and the on-device sampling path vs. the host fallback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.kernels.paged_attention import ops as paged_ops
from repro.models import lm
from repro.serve import cache as cache_mod
from repro.serve import engine
from repro.serve.sampling import SamplingParams, make_rng, \
    sample_tokens_device
from repro.serve.scheduler import PendingEntry, Request, Scheduler, \
    SlotState


@pytest.fixture(scope="module")
def llama():
    cfg = registry.get("llama3.2-1b-smoke")
    return cfg, lm.init_params(cfg, jax.random.key(0))


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, size=s).astype(np.int32)
            for s in lens]


def _reqs(cfg, lens, sp, gap=0, seed=0):
    return [Request(uid=i, prompt=p, sampling=sp, arrival=gap * i)
            for i, p in enumerate(_prompts(cfg, lens, seed))]


# ---------------------------------------------------------------------------
# PagedCache bookkeeping (no model forward involved)
# ---------------------------------------------------------------------------

class TestPagedBookkeeping:
    def _backend(self, cfg, **kw):
        kw.setdefault("page_size", 8)
        kw.setdefault("n_pages", 6)
        kw.setdefault("reserve_pages", 1)
        return cache_mod.PagedCache(cfg, max_batch=2, max_len=32, **kw)

    def test_page_size_must_divide_max_len(self, llama):
        cfg, _ = llama
        with pytest.raises(ValueError, match="divide"):
            cache_mod.PagedCache(cfg, max_batch=2, max_len=32, page_size=5)
        with pytest.raises(ValueError, match="backend"):
            cache_mod.make_backend("ring", cfg, 2, 32)
        with pytest.raises(ValueError, match="no options"):
            cache_mod.make_backend("dense", cfg, 2, 32, page_size=8)

    def test_alloc_append_free_accounting(self, llama):
        cfg, _ = llama
        b = self._backend(cfg)
        base = b.memory_report()
        assert base["pages_in_use"] == 0
        # prompt of 7 + first decode write -> pages_for(8) = 1 page
        h = b.alloc(uid=0, slot=0, n_prompt=7)
        assert len(h.pages) == 1 and b.pages_in_use == 1
        b.append(h)             # next write pos 8 -> page boundary
        assert len(h.pages) == 2 and b.pages_in_use == 2
        for _ in range(7):
            b.append(h)         # pos 9..15: same page
        assert len(h.pages) == 2
        b.free(h)
        after = b.memory_report()
        assert after["pages_in_use"] == 0
        assert after["cache_bytes_in_use"] == 0
        assert after["peak_pages_in_use"] == 2
        assert after["peak_cache_bytes"] < after["dense_equivalent_bytes"]

    def test_admission_contract_and_exhaustion(self, llama):
        cfg, _ = llama
        b = self._backend(cfg)                     # 6 pages, reserve 1
        # 17-token prompt + first write -> 3 pages; +1 reserve -> needs 4
        assert b.can_admit(17)
        h0 = b.alloc(0, 0, 17)
        assert b.pages_in_use == 3
        assert not b.can_admit(17)                 # 3 free < 3 + reserve
        assert b.can_admit(7)                      # 1 + 1 reserve <= 3
        h1 = b.alloc(1, 1, 15)                     # 2 pages
        assert b.pages_in_use == 5
        # drive h0 to a boundary crossing with one free page: ok
        for _ in range(7):
            b.append(h0)                           # pos 18..24 (cross at 24)
        assert b.pages_in_use == 6
        # next crossing for h1 must raise
        with pytest.raises(cache_mod.PoolExhausted):
            for _ in range(16):
                b.append(h1)
        b.free(h0)
        b.free(h1)
        assert b.memory_report()["pages_in_use"] == 0

    def test_check_feasible(self, llama):
        cfg, _ = llama
        b = self._backend(cfg, n_pages=3)
        with pytest.raises(ValueError, match="never"):
            # 25 + 7 = 32 tokens -> 4 pages + 1 reserve > 3-page pool
            b.check_feasible(n_prompt=25, max_tokens=7)
        b.check_feasible(n_prompt=9, max_tokens=6)    # 2 pages + 1 fits

    def test_ssm_arch_needs_no_pages(self):
        cfg = registry.get("mamba2-780m-smoke")
        b = cache_mod.PagedCache(cfg, max_batch=2, max_len=32, page_size=8,
                                 n_pages=1)
        assert b.pages_for(100) == 0
        assert b.can_admit(31)
        assert b.memory_report()["bytes_per_page"] == 0
        assert b.memory_report()["ssm_slot_bytes"] > 0


# ---------------------------------------------------------------------------
# scheduler: memory-aware admission + preemption bookkeeping
# ---------------------------------------------------------------------------

class TestMemoryAwareScheduler:
    def _req(self, uid, s0=4, arrival=0, max_tokens=4):
        return Request(uid=uid, prompt=np.arange(s0, dtype=np.int32),
                       sampling=SamplingParams(max_tokens=max_tokens),
                       arrival=arrival)

    def _state(self, entry, slot):
        req = entry.request
        return SlotState(request=req, slot=slot,
                         pos=entry.tokens().size,
                         remaining=req.sampling.max_tokens,
                         last_token=0, out=list(entry.tokens()[
                             req.prompt.size:]),
                         rng=make_rng(req.sampling, req.uid))

    def test_memory_blocked_head_queues_fcfs(self):
        sched = Scheduler(max_batch=4, max_len=32)
        sched.submit(self._req(0, s0=20))     # big head
        sched.submit(self._req(1, s0=2))      # small behind it
        # gate rejects the big head -> nothing admits (no skip-ahead)
        assert sched.pop_admissible(
            0, can_admit=lambda e: e.tokens().size < 10) is None
        # gate opens -> FIFO resumes with the head
        entry, slot = sched.pop_admissible(0, can_admit=lambda e: True)
        assert entry.request.uid == 0

    def test_preempt_requeues_front_with_stream(self):
        sched = Scheduler(max_batch=2, max_len=32)
        for uid in range(2):
            sched.submit(self._req(uid))
        sched.submit(self._req(7, arrival=0))     # waits behind
        e0, s0 = sched.pop_admissible(0)
        st0 = self._state(e0, s0)
        st0.order = 1
        sched.activate(s0, st0)
        e1, s1 = sched.pop_admissible(0)
        st1 = self._state(e1, s1)
        st1.order = 2
        sched.activate(s1, st1)
        st1.out.extend([5, 6])                    # generated so far
        sched.preempt(s1)
        assert sched.preemptions == 1
        # the preempted request is FIRST in line (ahead of uid 7) and its
        # resume tokens carry prompt + generated stream
        entry, _ = sched.pop_admissible(0)
        assert entry.request.uid == 1 and entry.resume is st1
        np.testing.assert_array_equal(
            entry.tokens(),
            np.concatenate([entry.request.prompt, [5, 6]]).astype(np.int32))

    def test_successive_preemptions_keep_fcfs(self):
        sched = Scheduler(max_batch=2, max_len=32)
        for uid in range(2):
            sched.submit(self._req(uid))
        e0, s0 = sched.pop_admissible(0)
        st0 = self._state(e0, s0); st0.order = 1
        sched.activate(s0, st0)
        e1, s1 = sched.pop_admissible(0)
        st1 = self._state(e1, s1); st1.order = 2
        sched.activate(s1, st1)
        sched.preempt(s1)                  # youngest first
        sched.preempt(s0)                  # then the older one
        uids = [e.request.uid for e in sched.pending]
        assert uids == [0, 1]              # older resumes first

    def test_preempted_uid_still_counts_as_duplicate(self):
        sched = Scheduler(max_batch=1, max_len=32)
        sched.submit(self._req(3))
        e, s = sched.pop_admissible(0)
        sched.activate(s, self._state(e, s))
        sched.preempt(s)
        with pytest.raises(ValueError, match="duplicate"):
            sched.submit(self._req(3))


# ---------------------------------------------------------------------------
# end-to-end: paged == dense, token for token
# ---------------------------------------------------------------------------

class TestPagedDenseParity:
    def test_mixed_prompt_lengths_greedy_and_sampled(self, llama):
        cfg, params = llama
        dense = engine.InferenceServer(cfg, params, max_len=48, max_batch=2)
        paged = engine.InferenceServer(cfg, params, max_len=48, max_batch=2,
                                       cache="paged", page_size=8,
                                       pages=10)
        for sp, gap, seed in [
                (SamplingParams(max_tokens=6), 0, 0),
                (SamplingParams(temperature=0.8, top_k=12, max_tokens=5,
                                seed=11), 3, 1)]:
            lens = (4, 13, 7, 9)
            ref = dense.serve(_reqs(cfg, lens, sp, seed=seed))
            out = paged.serve(_reqs(cfg, lens, sp, gap=gap, seed=seed))
            for i in range(len(lens)):
                np.testing.assert_array_equal(ref[i], out[i])
        mem = paged.stats["memory"]
        assert mem["peak_cache_bytes"] < mem["dense_equivalent_bytes"]
        assert mem["pages_in_use"] == 0          # free-on-retire: no leak

    def test_quantized_plan_paged_parity_and_memory(self, llama):
        cfg, params = llama
        plan = engine.synthetic_plan(cfg, params, bits=None, seed=0)
        dense = engine.InferenceServer(cfg, params, plan=plan, max_len=48,
                                       max_batch=2)
        paged = engine.InferenceServer(cfg, params, plan=plan, max_len=48,
                                       max_batch=2, cache="paged",
                                       page_size=8, pages=9)
        sp = SamplingParams(max_tokens=6)
        lens = (5, 11, 8)
        ref = dense.serve(_reqs(cfg, lens, sp, seed=2))
        out = paged.serve(_reqs(cfg, lens, sp, gap=2, seed=2))
        for i in range(len(lens)):
            np.testing.assert_array_equal(ref[i], out[i])
        mem = paged.stats["memory"]
        assert mem["pages_in_use"] == 0
        assert 0 < mem["peak_cache_bytes"] < mem["dense_equivalent_bytes"]

    def test_pool_exhaustion_preempts_and_stays_exact(self, llama):
        cfg, params = llama
        sp = SamplingParams(temperature=0.6, top_k=10, max_tokens=8,
                            seed=3)
        lens = (4, 9, 6, 13)
        dense = engine.InferenceServer(cfg, params, max_len=32,
                                       max_batch=3)
        ref = dense.serve(_reqs(cfg, lens, sp))
        tiny = engine.InferenceServer(cfg, params, max_len=32, max_batch=3,
                                      cache="paged", page_size=4, pages=7)
        out = tiny.serve(_reqs(cfg, lens, sp))
        assert tiny.stats["preemptions"] > 0
        for i in range(len(lens)):
            np.testing.assert_array_equal(ref[i], out[i])
        assert tiny.stats["memory"]["pages_in_use"] == 0

    def test_page_size_one_under_preemption(self, llama):
        """Worst case for append idempotency: every token is a page
        boundary, and the engine's preempt-and-retry loop must not
        double-advance a handle whose append raised PoolExhausted."""
        cfg, params = llama
        sp = SamplingParams(max_tokens=6)
        lens = (4, 7, 5)
        dense = engine.InferenceServer(cfg, params, max_len=16,
                                       max_batch=2)
        ref = dense.serve(_reqs(cfg, lens, sp, seed=7))
        tiny = engine.InferenceServer(cfg, params, max_len=16, max_batch=2,
                                      cache="paged", page_size=1,
                                      pages=14)
        out = tiny.serve(_reqs(cfg, lens, sp, seed=7))
        assert tiny.stats["preemptions"] > 0
        for i in range(len(lens)):
            np.testing.assert_array_equal(ref[i], out[i])
        assert tiny.stats["memory"]["pages_in_use"] == 0

    def test_infeasible_request_rejected_up_front(self, llama):
        cfg, params = llama
        srv = engine.InferenceServer(cfg, params, max_len=32, max_batch=2,
                                     cache="paged", page_size=4, pages=3)
        sp = SamplingParams(max_tokens=12)
        with pytest.raises(ValueError, match="never"):
            srv.serve(_reqs(cfg, (16,), sp))

    def test_hybrid_arch_kv_pages_plus_ssm_slots(self):
        """jamba: attention layers page, mamba layers use the slot pool,
        prefill stays exact-length (padding would pollute the SSM state)."""
        cfg = registry.get("jamba-1.5-large-398b-smoke")
        params = lm.init_params(cfg, jax.random.key(0))
        dense = engine.InferenceServer(cfg, params, max_len=48,
                                       max_batch=2)
        paged = engine.InferenceServer(cfg, params, max_len=48,
                                       max_batch=2, cache="paged",
                                       page_size=8, pages=8)
        # hybrid: pool-direct prefill, but at EXACT length (q-chunk
        # padding would pollute the SSM state)
        assert paged._paged_kv and paged._has_ssm
        sp = SamplingParams(max_tokens=4)
        ref = dense.serve(_reqs(cfg, (7, 12), sp, seed=3))
        out = paged.serve(_reqs(cfg, (7, 12), sp, seed=3))
        for i in range(2):
            np.testing.assert_array_equal(ref[i], out[i])
        mem = paged.stats["memory"]
        assert mem["ssm_slot_bytes"] > 0 and mem["peak_pages_in_use"] > 0

    def test_ssm_arch_on_paged_backend(self):
        cfg = registry.get("mamba2-780m-smoke")
        params = lm.init_params(cfg, jax.random.key(1))
        dense = engine.InferenceServer(cfg, params, max_len=48,
                                       max_batch=2)
        paged = engine.InferenceServer(cfg, params, max_len=48,
                                       max_batch=2, cache="paged",
                                       page_size=8)
        sp = SamplingParams(max_tokens=4)
        ref = dense.serve(_reqs(cfg, (33, 17), sp, seed=2))
        out = paged.serve(_reqs(cfg, (33, 17), sp, seed=2))
        for i in range(2):
            np.testing.assert_array_equal(ref[i], out[i])


class TestPagedKernelParity:
    """The Pallas paged-attention kernel (interpret mode on CPU) must
    reproduce the dense backend's token streams exactly -- the PR 3
    invariant survives the in-place pool read."""

    def test_kernel_impl_matches_dense_tokens(self, llama):
        cfg, params = llama
        sp_greedy = SamplingParams(max_tokens=4)
        sp_seeded = SamplingParams(temperature=0.8, top_k=7, max_tokens=4,
                                   seed=3)
        lens = (5, 11)
        dense = engine.InferenceServer(cfg, params, max_len=16,
                                       max_batch=2)
        ref_g = dense.serve(_reqs(cfg, lens, sp_greedy, seed=1))
        ref_s = dense.serve(_reqs(cfg, lens, sp_seeded, seed=1))
        with paged_ops.force_impl("kernel"):
            # fresh server: its decode step traces (and therefore bakes
            # in the forced impl) on first use inside this block
            paged = engine.InferenceServer(cfg, params, max_len=16,
                                           max_batch=2, cache="paged",
                                           page_size=8)
            out_g = paged.serve(_reqs(cfg, lens, sp_greedy, seed=1))
            out_s = paged.serve(_reqs(cfg, lens, sp_seeded, seed=1))
        for i in range(len(lens)):
            np.testing.assert_array_equal(ref_g[i], out_g[i])
            np.testing.assert_array_equal(ref_s[i], out_s[i])

    def test_mirror_ref_impl_matches_dense_tokens(self, llama):
        cfg, params = llama
        sp = SamplingParams(max_tokens=4)
        lens = (5, 11)
        dense = engine.InferenceServer(cfg, params, max_len=16,
                                       max_batch=2)
        ref = dense.serve(_reqs(cfg, lens, sp, seed=1))
        with paged_ops.force_impl("ref"):
            paged = engine.InferenceServer(cfg, params, max_len=16,
                                           max_batch=2, cache="paged",
                                           page_size=8)
            out = paged.serve(_reqs(cfg, lens, sp, seed=1))
        for i in range(len(lens)):
            np.testing.assert_array_equal(ref[i], out[i])


class TestPagedPrefillConformance:
    """PR 10: admission-time prefill runs the q-chunked paged kernel
    straight over the page pool (no dense scatter round-trip).  The
    dense-vs-paged token-equality invariant must survive it across the
    full serving matrix, for every prefill impl in the fallback ladder.
    """

    @pytest.mark.parametrize("impl", ["kernel", "view"])
    def test_float_solo_batched_streaming(self, llama, impl):
        """solo == batched == streaming-arrivals == dense, with prompt
        lengths hitting an exact page multiple (16), a multiple-minus-1
        (15), an odd length and a single token."""
        cfg, params = llama
        sp = SamplingParams(max_tokens=5)
        lens = (13, 16, 1, 15)
        dense = engine.InferenceServer(cfg, params, max_len=48, max_batch=2)
        ref_b = dense.serve(_reqs(cfg, lens, sp, seed=9))
        ref_s = dense.serve([_reqs(cfg, lens, sp, seed=9)[1]])
        with paged_ops.force_impl(impl):
            paged = engine.InferenceServer(cfg, params, max_len=48,
                                           max_batch=2, cache="paged",
                                           page_size=8, pages=12)
            out_b = paged.serve(_reqs(cfg, lens, sp, seed=9))
            out_s = paged.serve([_reqs(cfg, lens, sp, seed=9)[1]])
            out_g = paged.serve(_reqs(cfg, lens, sp, gap=2, seed=9))
        for i in range(len(lens)):
            np.testing.assert_array_equal(ref_b[i], out_b[i])
            np.testing.assert_array_equal(ref_b[i], out_g[i])
        np.testing.assert_array_equal(ref_s[1], out_s[1])
        assert paged.stats["memory"]["pages_in_use"] == 0

    def test_quantized_preempted_reprefill_kernel_impl(self, llama):
        """Plan-quantized weights + a pool small enough to preempt: the
        resumed requests re-prefill prompt+generated through the paged
        KERNEL and every stream stays byte-identical to dense."""
        cfg, params = llama
        plan = engine.synthetic_plan(cfg, params, bits=None, seed=0)
        sp = SamplingParams(temperature=0.6, top_k=10, max_tokens=8,
                            seed=3)
        lens = (4, 9, 6, 13)
        dense = engine.InferenceServer(cfg, params, plan=plan, max_len=32,
                                       max_batch=3)
        ref = dense.serve(_reqs(cfg, lens, sp, seed=5))
        with paged_ops.force_impl("kernel"):
            tiny = engine.InferenceServer(cfg, params, plan=plan,
                                          max_len=32, max_batch=3,
                                          cache="paged", page_size=4,
                                          pages=7)
            out = tiny.serve(_reqs(cfg, lens, sp, seed=5))
        assert tiny.stats["preemptions"] > 0     # re-prefill exercised
        for i in range(len(lens)):
            np.testing.assert_array_equal(ref[i], out[i])
        assert tiny.stats["memory"]["pages_in_use"] == 0

    def test_page_boundary_prompts_same_footprint(self, llama):
        """Stale-bucket hazard regression: prompts of exactly
        page_size*k and page_size*k - 1 tokens land in the same
        written-page footprint -- identical memory_report() page counts.
        (The old padded bucketed prefill scattered the padded length, so
        a boundary-straddling bucket could touch one page more than the
        admission priced.)"""
        cfg, params = llama
        sp = SamplingParams(max_tokens=3)
        reports = {}
        for n in (16, 15):                       # page_size*2, *2 - 1
            paged = engine.InferenceServer(cfg, params, max_len=48,
                                           max_batch=2, cache="paged",
                                           page_size=8, pages=10)
            dense = engine.InferenceServer(cfg, params, max_len=48,
                                           max_batch=2)
            ref = dense.serve(_reqs(cfg, (n,), sp, seed=1))
            out = paged.serve(_reqs(cfg, (n,), sp, seed=1))
            np.testing.assert_array_equal(ref[0], out[0])
            reports[n] = paged.stats["memory"]
        for key in ("pages_in_use", "peak_pages_in_use"):
            assert reports[16][key] == reports[15][key], key
        # prompt+decode spans positions 0..18 -> exactly 3 pages peak
        assert reports[16]["peak_pages_in_use"] == 3
        assert reports[16]["pages_in_use"] == 0

    def test_one_bounded_upload_per_admission_no_retrace(self, llama):
        """Admission uploads exactly ONE table row (alloc's incremental
        patch); the paged prefill itself slices the slot's row on device
        and performs no further host->device table traffic.  A warm
        second session must not re-trace any cache updater."""
        cfg, params = llama
        sp = SamplingParams(max_tokens=4)
        lens = (13, 9, 13, 9)
        paged = engine.InferenceServer(cfg, params, max_len=48,
                                       max_batch=2, cache="paged",
                                       page_size=8, pages=12)
        paged.serve(_reqs(cfg, lens, sp, seed=3))
        mem = paged.stats["memory"]
        assert paged.stats["preemptions"] == 0   # pool is ample
        assert mem["table_host_uploads"] == paged.stats["admitted"] == 4
        # warm server, same lengths: no new traces of the jitted table
        # updaters or the prefill/insert path
        traces = dict(cache_mod.TRACE_COUNTS)
        paged.serve(_reqs(cfg, lens, sp, seed=4))
        assert dict(cache_mod.TRACE_COUNTS) == traces
        assert paged.stats["memory"]["table_host_uploads"] == 4


class TestDeviceTables:
    """The block tables live on device across steps; decode must not
    re-upload or re-trace anything per step."""

    def test_no_per_step_host_sync(self, llama):
        cfg, _ = llama
        b = cache_mod.PagedCache(cfg, max_batch=2, max_len=32,
                                 page_size=8, n_pages=6)
        h = b.alloc(uid=0, slot=0, n_prompt=5)
        uploads0 = b.table_host_uploads
        t0 = b.device_tables()
        # steady-state decode inside a page: the SAME device array is
        # handed out every step -- no upload, no update, no new trace
        traces0 = dict(cache_mod.TRACE_COUNTS)
        for _ in range(2):
            b.append(h)                      # pos 6, 7: within page 0
            assert b.device_tables() is t0
        assert b.table_host_uploads == uploads0
        assert dict(cache_mod.TRACE_COUNTS) == traces0
        # page-boundary crossing patches ONE entry via the jitted
        # updater (no full-table host upload)
        b.append(h)                          # next write pos 8: new page
        t1 = b.device_tables()
        assert t1 is not t0
        assert b.table_host_uploads == uploads0
        np.testing.assert_array_equal(np.asarray(t1), b._table)
        # a second crossing must reuse the compiled updater (no retrace)
        entry_traces = cache_mod.TRACE_COUNTS["table_set_entry"]
        for _ in range(8):
            b.append(h)                      # crosses into page 2 at 16
        assert cache_mod.TRACE_COUNTS["table_set_entry"] == entry_traces
        np.testing.assert_array_equal(np.asarray(b.device_tables()),
                                      b._table)
        b.free(h)
        np.testing.assert_array_equal(np.asarray(b.device_tables()), 0)

    def test_tables_track_alloc_and_free(self, llama):
        cfg, _ = llama
        b = cache_mod.PagedCache(cfg, max_batch=3, max_len=32,
                                 page_size=8, n_pages=9)
        h0 = b.alloc(uid=0, slot=0, n_prompt=17)
        h1 = b.alloc(uid=1, slot=2, n_prompt=3)
        np.testing.assert_array_equal(np.asarray(b.device_tables()),
                                      b._table)
        b.free(h0)
        np.testing.assert_array_equal(np.asarray(b.device_tables()),
                                      b._table)
        assert np.asarray(b.device_tables())[0].sum() == 0
        assert np.asarray(b.device_tables())[2].sum() > 0
        b.free(h1)


# ---------------------------------------------------------------------------
# on-device sampling vs. the host fallback
# ---------------------------------------------------------------------------

class TestOnDeviceSampling:
    def test_greedy_device_equals_host(self, llama):
        cfg, params = llama
        dev = engine.InferenceServer(cfg, params, max_len=48, max_batch=2)
        host = engine.InferenceServer(cfg, params, max_len=48, max_batch=2,
                                      sample_on_device=False)
        sp = SamplingParams(max_tokens=6)
        a = dev.serve(_reqs(cfg, (5, 9), sp, seed=4))
        b = host.serve(_reqs(cfg, (5, 9), sp, seed=4))
        for i in range(2):
            np.testing.assert_array_equal(a[i], b[i])

    def test_host_fallback_keeps_batched_solo_parity(self, llama):
        cfg, params = llama
        host = engine.InferenceServer(cfg, params, max_len=48, max_batch=2,
                                      sample_on_device=False)
        sp = SamplingParams(temperature=0.9, top_k=8, max_tokens=5,
                            seed=5)
        reqs = _reqs(cfg, (6, 6, 6), sp, seed=5)
        both = host.serve(reqs)
        solo = host.serve([reqs[1]])
        np.testing.assert_array_equal(both[1], solo[1])

    def test_device_sampling_respects_top_k_and_seed(self, llama):
        cfg, params = llama
        srv = engine.InferenceServer(cfg, params, max_len=48, max_batch=2)
        sp1 = SamplingParams(temperature=1.0, top_k=2, max_tokens=8,
                             seed=0)
        sp2 = SamplingParams(temperature=1.0, top_k=2, max_tokens=8,
                             seed=9)
        r1 = srv.serve(_reqs(cfg, (6,), sp1, seed=6))
        r1b = srv.serve(_reqs(cfg, (6,), sp1, seed=6))
        r2 = srv.serve(_reqs(cfg, (6,), sp2, seed=6))
        np.testing.assert_array_equal(r1[0], r1b[0])   # deterministic
        assert not np.array_equal(r1[0], r2[0])        # seed matters

    def test_top_k_sort_skip_is_exact(self):
        """need_top_k=False (no row truncates) must draw the identical
        tokens as the sorting path: pure-temperature and top_k >= vocab
        rows keep the whole support either way."""
        rng = np.random.default_rng(0)
        v = 64
        logits = jnp.asarray(rng.normal(size=(3, v)).astype(np.float32))
        temps = jnp.asarray([0.9, 0.0, 1.7], jnp.float32)
        seeds = jnp.asarray([1, 2, 3], jnp.int32)
        uids = jnp.asarray([10, 11, 12], jnp.int32)
        tidx = jnp.asarray([0, 5, 9], jnp.int32)
        for topks in ([0, 0, 0], [v, 0, v + 7]):
            tk = jnp.asarray(topks, jnp.int32)
            with_sort = sample_tokens_device(logits, temps, tk, seeds,
                                             uids, tidx, need_top_k=True)
            skipped = sample_tokens_device(logits, temps, tk, seeds,
                                           uids, tidx, need_top_k=False)
            np.testing.assert_array_equal(np.asarray(with_sort),
                                          np.asarray(skipped))

    def test_pure_temperature_serve_uses_skip_path(self, llama):
        """End-to-end: a pure-temperature batch (top_k=0) is served and
        stays deterministic; a later truncating batch on the same server
        still truncates (the static flag recompiles, not corrupts)."""
        cfg, params = llama
        srv = engine.InferenceServer(cfg, params, max_len=48, max_batch=2)
        sp = SamplingParams(temperature=1.1, max_tokens=6, seed=2)
        a = srv.serve(_reqs(cfg, (6, 9), sp, seed=8))
        b = srv.serve(_reqs(cfg, (6, 9), sp, seed=8))
        for i in range(2):
            np.testing.assert_array_equal(a[i], b[i])
        spk = SamplingParams(temperature=1.1, top_k=2, max_tokens=6,
                             seed=2)
        c = srv.serve(_reqs(cfg, (6, 9), spk, seed=8))
        assert not all(np.array_equal(a[i], c[i]) for i in range(2))
