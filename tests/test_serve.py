"""Plan-driven serving stack tests: SamplingParams, the continuous-
batching scheduler, PackedLinear vs. the per-layer mixed_precision_matmul
oracle, float/quantized InferenceServer parity (batched == one-by-one ==
streaming), plan round-trips into quantized decode, fully-pruned layers,
and the PeriodicEval assignment cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import proptest as pt
from repro.configs import registry
from repro.launch import steps
from repro.models import lm
from repro.nn import quantized as nnq
from repro.serve import engine
from repro.serve.sampling import SamplingParams, make_rng, sample_token
from repro.serve.scheduler import Request, Scheduler


@pytest.fixture(scope="module")
def llama():
    cfg = registry.get("llama3.2-1b-smoke")
    return cfg, lm.init_params(cfg, jax.random.key(0))


@pytest.fixture(scope="module")
def llama_plan(llama):
    """A deterministic 'searched' plan: gamma-carrying params with
    randomized selection logits, discretized through lm.extract_plan."""
    cfg, _ = llama
    params = lm.init_params(cfg, jax.random.key(0), mps_on=True)
    key = jax.random.key(7)

    def randomize(node):
        nonlocal key
        if isinstance(node, dict):
            if "gamma" in node:
                key, sub = jax.random.split(key)
                node["gamma"] = jax.random.normal(
                    sub, node["gamma"].shape) * 3.0
            for v in node.values():
                randomize(v)

    params = jax.tree.map(lambda x: x, params)
    randomize(params)
    return params, lm.extract_plan(cfg, params)


def _prompts(cfg, n, s0, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab, size=(n, s0)).astype(np.int32)


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

class TestSampling:
    def test_validation(self):
        with pytest.raises(ValueError):
            SamplingParams(temperature=-1.0)
        with pytest.raises(ValueError):
            SamplingParams(top_k=-1)
        with pytest.raises(ValueError):
            SamplingParams(max_tokens=0)

    def test_greedy_is_argmax(self):
        logits = np.asarray([0.1, 3.0, -2.0, 1.5])
        sp = SamplingParams()
        assert sp.greedy
        assert sample_token(logits, sp, make_rng(sp, 0)) == 1

    def test_seeded_sampling_deterministic(self):
        logits = np.random.default_rng(0).normal(size=64)
        sp = SamplingParams(temperature=0.9, top_k=8, seed=3)
        draws1 = [sample_token(logits, sp, make_rng(sp, 5))
                  for _ in range(4)]
        # a fresh generator from the same (seed, uid) replays the stream
        rng = make_rng(sp, 5)
        draws2 = [sample_token(logits, sp, rng) for _ in range(4)]
        assert [draws1[0]] * 4 == draws1          # same rng state each call
        rng = make_rng(sp, 5)
        seq = [sample_token(logits, sp, rng) for _ in range(4)]
        rng = make_rng(sp, 5)
        assert seq == [sample_token(logits, sp, rng) for _ in range(4)]
        assert draws2[0] == seq[0]

    def test_top_k_restricts_support(self):
        logits = np.asarray([10.0, 9.0, -50.0, -50.0])
        sp = SamplingParams(temperature=1.0, top_k=2, seed=0)
        rng = make_rng(sp, 0)
        draws = {sample_token(logits, sp, rng) for _ in range(50)}
        assert draws <= {0, 1}


# ---------------------------------------------------------------------------
# scheduler (pure bookkeeping)
# ---------------------------------------------------------------------------

class TestScheduler:
    def _req(self, uid, arrival=0, s0=4, max_tokens=4):
        return Request(uid=uid, prompt=np.arange(s0, dtype=np.int32),
                       sampling=SamplingParams(max_tokens=max_tokens),
                       arrival=arrival)

    def test_fifo_admission_and_slot_reuse(self):
        sched = Scheduler(max_batch=2, max_len=32)
        for uid in range(3):
            sched.submit(self._req(uid))
        e0, s0 = sched.pop_admissible(0)
        sched.activate(s0, _dummy_state(e0, s0))
        e1, s1 = sched.pop_admissible(0)
        sched.activate(s1, _dummy_state(e1, s1))
        assert (e0.request.uid, e1.request.uid) == (0, 1)
        assert sched.pop_admissible(0) is None     # slots full
        sched.complete(s0)
        e2, s2 = sched.pop_admissible(0)
        assert e2.request.uid == 2 and s2 == s0    # freed slot reused
        assert sched.has_work

    def test_arrival_gating(self):
        sched = Scheduler(max_batch=2, max_len=32)
        sched.submit(self._req(0, arrival=5))
        assert sched.pop_admissible(4) is None
        assert sched.next_arrival == 5
        entry, _ = sched.pop_admissible(5)
        assert entry.request.uid == 0

    def test_validation(self):
        sched = Scheduler(max_batch=1, max_len=8)
        with pytest.raises(ValueError):     # prompt + max_tokens > max_len
            sched.submit(self._req(0, s0=6, max_tokens=4))
        sched.submit(self._req(1))
        with pytest.raises(ValueError):     # duplicate uid
            sched.submit(self._req(1))

    def test_cancel_pending_and_active(self):
        sched = Scheduler(max_batch=1, max_len=32)
        for uid in range(3):
            sched.submit(self._req(uid))
        e0, s0 = sched.pop_admissible(0)
        sched.activate(s0, _dummy_state(e0, s0))
        where, state = sched.cancel(0, kind="timeout")
        assert where == "active" and state.request.uid == 0
        assert sched.slots[s0] is None       # slot freed immediately
        where, entry = sched.cancel(2)
        assert where == "pending" and entry.request.uid == 2
        assert sched.cancel(0) is None       # no longer live
        assert sched.cancel(99) is None      # never existed
        with pytest.raises(ValueError):
            sched.cancel(1, kind="vanished")
        # uid 1 is the only survivor and admits next
        entry, _ = sched.pop_admissible(0)
        assert entry.request.uid == 1

    def test_load_counts_remaining_tokens(self):
        sched = Scheduler(max_batch=2, max_len=32)
        sched.submit(self._req(0, max_tokens=4))
        sched.submit(self._req(1, max_tokens=6))
        e0, s0 = sched.pop_admissible(0)
        st = _dummy_state(e0, s0)
        sched.activate(s0, st)
        load = sched.load()
        assert load == {"queued": 1, "active": 1,
                        "queued_tokens": 6, "active_tokens": 4}
        st.remaining = 1                     # 3 tokens decoded
        sched.preempt(s0)
        load = sched.load()                  # resume carries remaining=1
        assert load == {"queued": 2, "active": 0,
                        "queued_tokens": 7, "active_tokens": 0}

    @pt.given(seed=pt.integers(0, 10**9), max_batch=pt.integers(1, 3),
              n_req=pt.integers(4, 9))
    def test_fcfs_property_under_mixed_ops(self, seed, max_batch, n_req):
        """Strict FCFS survives any interleaving of admissions,
        completions, preemptions, cancellations, timeouts and replica
        crash/recover cycles: each admission must pop the model queue's
        exact head (preempted requests re-admit from the FRONT, fresh
        ones in submit order, cancelled ones never), a crash that
        cancels every live request and front-re-enqueues them in
        reverse seniority (the fleet's failover path) restores the
        exact pre-crash order, and load() mirrors the model throughout.
        The prefill_admit op models the engine's memory-gated paged
        admission: can_admit is consulted on the HEAD only, a blocked
        head blocks the whole queue (no skip-ahead), and an admitted
        entry's tokens() is exactly what prefill re-runs.
        Complements the trace-replay FRONT-order check in test_obs."""
        rng = np.random.default_rng(seed)
        sched = Scheduler(max_batch=max_batch, max_len=32)
        for uid in range(n_req):
            sched.submit(self._req(uid))
        queue = [uid for uid in range(n_req)]     # model: exact order
        active: dict[int, object] = {}            # slot -> uid
        done = set()
        admit_seq = 0                             # admission order
        for _ in range(60):
            if not queue and not active:
                break
            op = rng.choice(["admit", "prefill_admit", "complete",
                             "preempt", "cancel", "crash"])
            if op == "admit":
                res = sched.pop_admissible(0)
                if len(active) == max_batch or not queue:
                    assert res is None
                    continue
                entry, slot = res
                assert entry.request.uid == queue[0], \
                    f"admitted {entry.request.uid}, head was {queue}"
                queue.pop(0)
                st = _dummy_state(entry, slot)
                st.order = admit_seq
                admit_seq += 1
                sched.activate(slot, st)
                active[slot] = st
            elif op == "prefill_admit":
                # the engine's paged-prefill admission: the backend's
                # memory gate sees the head only -- blocked head, blocked
                # queue (strict FCFS, no probe of later entries)
                blocked = bool(rng.integers(0, 2))
                probed = []

                def gate(entry, probed=probed, blocked=blocked):
                    probed.append(entry.request.uid)
                    return not blocked

                res = sched.pop_admissible(0, can_admit=gate)
                if len(active) == max_batch or not queue:
                    assert res is None
                    continue
                assert probed == [queue[0]]      # gate saw only the head
                if blocked:
                    assert res is None           # head-of-line blocking
                    continue
                entry, slot = res
                assert entry.request.uid == queue[0]
                # a resumed entry re-prefills prompt + generated stream
                exp = entry.request.prompt.size + (
                    len(entry.resume.out) if entry.resume else 0)
                assert entry.tokens().size == exp
                queue.pop(0)
                st = _dummy_state(entry, slot)
                st.order = admit_seq
                admit_seq += 1
                sched.activate(slot, st)
                active[slot] = st
            elif op == "crash" and (queue or active):
                # seniority: actives by admission order, then queue
                model_live = ([st.request.uid for st in
                               sorted(active.values(),
                                      key=lambda s: s.order)] + queue)
                assert sched.live_uids() == model_live
                for uid in model_live:
                    assert sched.cancel(uid, kind="crashed") is not None
                # zero leaks: every slot must be released, else its
                # cache handle (freed keyed on slot state) would strand
                assert sched.active == []
                active.clear()
                # recompute-style recovery: fresh requests, re-enqueued
                # to the FRONT in reverse seniority -> original order
                for uid in reversed(model_live):
                    sched.submit(self._req(uid), front=True)
                queue = list(model_live)
                assert [e.request.uid for e in sched.pending] == queue
            elif op == "complete" and active:
                slot = int(rng.choice(list(active)))
                done.add(active.pop(slot).request.uid)
                sched.complete(slot)
            elif op == "preempt" and active:
                slot = int(rng.choice(list(active)))
                st = active.pop(slot)
                assert sched.preempt(slot) is st
                queue.insert(0, st.request.uid)   # FRONT re-admission
            elif op == "cancel" and (queue or active):
                live = queue + [st.request.uid for st in active.values()]
                uid = int(rng.choice(live))
                kind = str(rng.choice(["cancelled", "timeout"]))
                where, _ = sched.cancel(uid, kind=kind)
                if uid in queue:
                    assert where == "pending"
                    queue.remove(uid)
                else:
                    assert where == "active"
                    active = {s: st for s, st in active.items()
                              if st.request.uid != uid}
            load = sched.load()
            assert load["queued"] == len(queue)
            assert load["active"] == len(active)
        assert set(sched.finished) == done


def _dummy_state(entry, slot):
    from repro.serve.scheduler import SlotState
    req = entry.request
    return SlotState(request=req, slot=slot, pos=req.prompt.size,
                     remaining=req.sampling.max_tokens, last_token=0,
                     out=[], rng=make_rng(req.sampling, req.uid))


# ---------------------------------------------------------------------------
# PackedLinear vs. the per-layer oracle
# ---------------------------------------------------------------------------

class TestPackedLinear:
    def test_matches_mixed_precision_matmul_oracle(self):
        """The in-forward PackedLinear path must serve exactly the packed
        groups the per-layer export produces: bitwise-equal to scattering
        engine.mixed_precision_matmul output back to channel order."""
        rng = np.random.default_rng(0)
        k, n = 32, 48
        w = rng.normal(size=(k, n)).astype(np.float32) * 0.2
        bits = rng.choice([0, 2, 4, 8], size=n, p=[0.2, 0.2, 0.3, 0.3])
        pl = nnq.PackedLinear.from_dense(w, bits)
        packed, perm, kept = engine.export_mixed_precision_layer(w.T, bits)
        x = jnp.asarray(rng.normal(size=(5, k)).astype(np.float32))
        y_pl = pl(x)
        y_oracle = engine.mixed_precision_matmul(x, packed)   # (5, kept)
        scatter = np.zeros((5, n), np.float32)
        scatter[:, np.asarray(perm)[:kept]] = np.asarray(y_oracle)
        np.testing.assert_array_equal(np.asarray(y_pl), scatter)
        # pruned channels are exactly zero
        assert np.all(np.asarray(y_pl)[:, bits == 0] == 0.0)

    def test_per_row_activation_scales_are_batch_invariant(self):
        rng = np.random.default_rng(1)
        w = rng.normal(size=(16, 8)).astype(np.float32)
        bits = np.full(8, 4, np.int64)
        pl = nnq.PackedLinear.from_dense(w, bits)
        x = rng.normal(size=(4, 16)).astype(np.float32)
        # row 2 served alone == row 2 served in the batch (incl. a row
        # with a much larger magnitude that would shift a per-tensor scale)
        x[0] *= 100.0
        full = np.asarray(pl(jnp.asarray(x)))
        solo = np.asarray(pl(jnp.asarray(x[2:3])))
        np.testing.assert_array_equal(full[2:3], solo)

    def test_fully_pruned_layer(self):
        w = np.ones((8, 6), np.float32)
        bits = np.zeros(6, np.int64)
        packed, perm, kept = engine.export_mixed_precision_layer(w.T, bits)
        assert packed == [] and kept == 0
        y = engine.mixed_precision_matmul(jnp.ones((3, 8)), packed)
        assert y.shape == (3, 0)                   # well-defined zero-width
        pl = nnq.PackedLinear.from_dense(w, bits)
        out = pl(jnp.ones((2, 3, 8)))
        assert out.shape == (2, 3, 6)
        assert np.all(np.asarray(out) == 0.0)

    def test_quantized_linear_apply_empty(self):
        from repro.kernels.quant_matmul import ops as qops
        y = qops.quantized_linear_apply(jnp.ones((4, 8)), [])
        assert y.shape == (4, 0)

    def test_packed_linear_is_a_pytree(self):
        w = np.random.default_rng(2).normal(size=(8, 8)).astype(np.float32)
        pl = nnq.PackedLinear.from_dense(w, np.asarray([0, 2, 2, 4, 4, 8,
                                                        8, 8]))
        leaves, treedef = jax.tree_util.tree_flatten(pl)
        pl2 = jax.tree_util.tree_unflatten(treedef, leaves)
        x = jnp.ones((2, 8))
        np.testing.assert_array_equal(np.asarray(pl(x)),
                                      np.asarray(pl2(x)))
        y = jax.jit(lambda m, v: m(v))(pl, x)      # crosses a jit boundary
        np.testing.assert_array_equal(np.asarray(y), np.asarray(pl(x)))


# ---------------------------------------------------------------------------
# InferenceServer: float continuous batching
# ---------------------------------------------------------------------------

class TestContinuousBatching:
    def test_batched_equals_one_by_one(self, llama):
        cfg, params = llama
        server = engine.InferenceServer(cfg, params, max_len=48,
                                        max_batch=2)
        prompts = _prompts(cfg, 3, 6)
        sp = SamplingParams(temperature=0.8, top_k=16, max_tokens=6,
                            seed=11)
        reqs = [Request(uid=i, prompt=prompts[i], sampling=sp)
                for i in range(3)]
        together = server.serve(reqs)       # 3 requests over 2 slots
        assert server.stats["admitted"] == 3
        for r in reqs:
            solo = server.serve([Request(uid=r.uid, prompt=r.prompt,
                                         sampling=sp)])
            np.testing.assert_array_equal(together[r.uid], solo[r.uid])

    def test_streaming_arrivals_match_all_at_once(self, llama):
        cfg, params = llama
        server = engine.InferenceServer(cfg, params, max_len=48,
                                        max_batch=4)
        prompts = _prompts(cfg, 3, 5, seed=4)
        sp = SamplingParams(max_tokens=5)   # greedy
        batch = server.serve([Request(uid=i, prompt=prompts[i],
                                      sampling=sp) for i in range(3)])
        stream = server.serve([Request(uid=i, prompt=prompts[i],
                                       sampling=sp, arrival=3 * i)
                               for i in range(3)])
        for i in range(3):
            np.testing.assert_array_equal(batch[i], stream[i])

    def test_variable_prompt_lengths_and_budgets(self, llama):
        cfg, params = llama
        server = engine.InferenceServer(cfg, params, max_len=48,
                                        max_batch=2)
        reqs = [Request(uid=0, prompt=_prompts(cfg, 1, 4)[0],
                        sampling=SamplingParams(max_tokens=3)),
                Request(uid=1, prompt=_prompts(cfg, 1, 9, seed=1)[0],
                        sampling=SamplingParams(max_tokens=7)),
                Request(uid=2, prompt=_prompts(cfg, 1, 6, seed=2)[0],
                        sampling=SamplingParams(max_tokens=1))]
        out = server.serve(reqs)
        assert {len(out[i]) for i in range(3)} == {3, 7, 1}
        assert all(out[i].max() < cfg.vocab for i in range(3))

    def test_generate_matches_serve_and_is_deterministic(self, llama):
        cfg, params = llama
        server = engine.InferenceServer(cfg, params, max_len=48,
                                        max_batch=4)
        prompts = _prompts(cfg, 2, 5, seed=9)
        out1 = server.generate(prompts, n_tokens=4)
        out2 = server.generate(prompts, n_tokens=4)
        np.testing.assert_array_equal(out1, out2)
        assert out1.shape == (2, 4)

    def test_ssm_arch_with_awkward_prompt_length(self):
        # 33 is not a multiple of the smoke ssm_chunk (32): the prefill
        # chunking falls back to a divisor, no padding pollution
        cfg = registry.get("mamba2-780m-smoke")
        params = lm.init_params(cfg, jax.random.key(1))
        server = engine.InferenceServer(cfg, params, max_len=48,
                                        max_batch=2)
        prompts = _prompts(cfg, 2, 33, seed=2)
        sp = SamplingParams(max_tokens=4)
        both = server.serve([Request(uid=i, prompt=prompts[i], sampling=sp)
                             for i in range(2)])
        solo = server.serve([Request(uid=0, prompt=prompts[0],
                                     sampling=sp)])
        np.testing.assert_array_equal(both[0], solo[0])

    def test_rejects_unsupported_archs(self):
        cfg = registry.get("seamless-m4t-medium-smoke")
        with pytest.raises(NotImplementedError):
            engine.InferenceServer(cfg, params=None)


# ---------------------------------------------------------------------------
# request cancellation (the session API)
# ---------------------------------------------------------------------------

class TestCancellation:
    def _server(self, llama, **kw):
        cfg, params = llama
        kw.setdefault("max_len", 32)
        kw.setdefault("max_batch", 2)
        return engine.InferenceServer(cfg, params, cache="paged",
                                      page_size=4, pages=24, **kw)

    def _reqs(self, cfg, n, max_tokens=6):
        rng = np.random.default_rng(2)
        sp = SamplingParams(max_tokens=max_tokens)
        return [Request(uid=i, sampling=sp,
                        prompt=rng.integers(0, cfg.vocab, size=6)
                        .astype(np.int32))
                for i in range(n)]

    def test_cancel_queued_request(self, llama):
        cfg, _ = llama
        server = self._server(llama, max_batch=1)
        reqs = self._reqs(cfg, 2)
        server.begin(reqs)
        server.step()                       # uid 0 admitted, uid 1 queued
        toks = server.cancel(1)
        assert toks is not None and toks.size == 0   # nothing generated
        while server.has_work:
            server.step()
        out = server.end()
        assert set(out) == {0}
        assert server.stats["cancelled"] == 1
        assert server.stats["timeouts"] == 0

    def test_cancel_inflight_frees_pages_to_baseline(self, llama):
        """The leak check: cancelling an in-flight request frees its
        cache pages immediately, and the backend returns to its
        pre-admission baseline -- while the surviving request's stream
        stays byte-identical to a solo run."""
        cfg, _ = llama
        server = self._server(llama)
        reqs = self._reqs(cfg, 2)
        server.begin(reqs)
        assert server.backend.memory_report()["pages_in_use"] == 0
        server.step()                       # both admitted
        server.step()                       # a couple of decode steps
        held = server.backend.memory_report()["pages_in_use"]
        assert held > 0
        toks = server.cancel(1, reason="timeout")
        assert 0 < toks.size < reqs[1].sampling.max_tokens  # partial
        after = server.backend.memory_report()["pages_in_use"]
        assert after < held                 # pages freed right away
        while server.has_work:
            server.step()
        out = server.end()
        assert server.backend.memory_report()["pages_in_use"] == 0
        assert server.stats["timeouts"] == 1
        solo = server.serve([reqs[0]])
        np.testing.assert_array_equal(out[0], solo[0])

    def test_cancel_everything_restores_baseline_immediately(self, llama):
        cfg, _ = llama
        server = self._server(llama)
        reqs = self._reqs(cfg, 3)           # 2 active + 1 queued
        server.begin(reqs)
        server.step()
        for uid in range(3):
            server.cancel(uid)
        assert server.backend.memory_report()["pages_in_use"] == 0
        assert not server.has_work
        out = server.end()
        assert out == {}
        assert server.stats["cancelled"] == 3

    def test_cancel_validation_and_result(self, llama):
        cfg, _ = llama
        server = self._server(llama)
        reqs = self._reqs(cfg, 1, max_tokens=3)
        server.begin(reqs)
        with pytest.raises(ValueError):
            server.cancel(0, reason="evaporated")
        assert server.cancel(7) is None     # unknown uid
        assert server.result(0) is None     # not finished yet
        while server.has_work:
            server.step()
        toks = server.result(0)
        assert toks is not None and toks.size == 3
        assert server.cancel(0) is None     # finished: not cancellable
        server.end()
        with pytest.raises(RuntimeError):   # session closed
            server.cancel(0)


# ---------------------------------------------------------------------------
# plan-driven quantized decode
# ---------------------------------------------------------------------------

class TestQuantizedServing:
    def test_extract_plan_roundtrip(self, llama, llama_plan, tmp_path):
        cfg, _ = llama
        mps_params, plan = llama_plan
        loaded = type(plan).load(plan.save(str(tmp_path / "lmplan")))
        assert loaded.equals(plan)
        groups = lm.serve_weight_groups(cfg, mps_params)
        assert set(groups) == set(plan.channel_bits)
        for grp, w in groups.items():
            assert w.shape[0] == plan.channel_bits[grp].size

    def test_loaded_plan_decodes_like_the_oracle_loop(self, llama,
                                                      llama_plan,
                                                      tmp_path):
        """End-to-end acceptance: a saved+loaded plan, bound into the LM,
        serves token-for-token what a naive fused-prefill + one-token
        decode_step loop over the same per-layer packed weights produces
        -- under continuous batching with staggered arrivals."""
        cfg, params = llama
        _, plan = llama_plan
        loaded = type(plan).load(plan.save(str(tmp_path / "p")))

        max_len, n_tok = 48, 6
        prompts = _prompts(cfg, 3, 6, seed=5)
        server = engine.InferenceServer(cfg, params, plan=loaded,
                                        max_len=max_len, max_batch=2)
        sp = SamplingParams(max_tokens=n_tok)   # greedy
        served = server.serve([Request(uid=i, prompt=prompts[i],
                                       sampling=sp, arrival=2 * i)
                               for i in range(3)])

        # oracle: same plan bound per-layer, naive single-request loop
        qparams = engine.apply_plan(cfg, params, loaded)
        prefill = jax.jit(steps.make_prefill_step(cfg))
        decode = jax.jit(lambda p, t, c, pos: lm.decode_step(cfg, p, t, c,
                                                             pos))
        for i in range(3):
            caches = lm.init_caches(cfg, 1, max_len)
            logits, pc = prefill(qparams, {"tokens":
                                           jnp.asarray(prompts[i:i + 1])})
            caches = jax.tree.map(
                lambda big, small: jax.lax.dynamic_update_slice(
                    big, small.astype(big.dtype), (0,) * big.ndim),
                caches, pc)
            tok = int(np.argmax(np.asarray(
                logits.astype(jnp.float32))[0, -1, :cfg.vocab]))
            out = [tok]
            pos = prompts.shape[1]
            for _ in range(n_tok - 1):
                logits, caches = decode(
                    qparams, {"tokens": jnp.asarray([[tok]], jnp.int32)},
                    caches, jnp.asarray(pos))
                tok = int(np.argmax(np.asarray(
                    logits.astype(jnp.float32))[0, -1, :cfg.vocab]))
                out.append(tok)
                pos += 1
            np.testing.assert_array_equal(served[i], np.asarray(out))

    def test_quantized_batched_equals_one_by_one(self, llama, llama_plan):
        cfg, params = llama
        _, plan = llama_plan
        server = engine.InferenceServer(cfg, params, plan=plan,
                                        max_len=48, max_batch=2)
        prompts = _prompts(cfg, 2, 5, seed=6)
        sp = SamplingParams(temperature=0.7, top_k=12, max_tokens=5,
                            seed=2)
        both = server.serve([Request(uid=i, prompt=prompts[i], sampling=sp)
                             for i in range(2)])
        for i in range(2):
            solo = server.serve([Request(uid=i, prompt=prompts[i],
                                         sampling=sp)])
            np.testing.assert_array_equal(both[i], solo[i])

    def test_quantization_changes_decode(self, llama):
        """Sanity: a heavily-quantized plan actually drives the forward
        (2-bit weights on a random net must alter greedy decode)."""
        cfg, params = llama
        plan = engine.synthetic_plan(cfg, params, bits=2)
        s_float = engine.InferenceServer(cfg, params, max_len=48,
                                         max_batch=2)
        s_quant = engine.InferenceServer(cfg, params, plan=plan,
                                         max_len=48, max_batch=2)
        prompts = _prompts(cfg, 2, 6, seed=8)
        out_f = s_float.generate(prompts, n_tokens=8)
        out_q = s_quant.generate(prompts, n_tokens=8)
        assert not np.array_equal(out_f, out_q)

    def test_fully_pruned_group_serves(self, llama):
        cfg, params = llama
        plan = engine.synthetic_plan(cfg, params, bits=4)
        grp = sorted(plan.channel_bits)[0]
        plan.channel_bits[grp][:] = 0
        import repro.core.discretize as discretize
        plan.permutations[grp] = discretize.reorder_permutations(
            {"gamma": {grp: plan.channel_bits[grp]}})[grp]
        server = engine.InferenceServer(cfg, params, plan=plan,
                                        max_len=32, max_batch=2)
        out = server.generate(_prompts(cfg, 2, 4, seed=3), n_tokens=4)
        assert out.shape == (2, 4)
        assert out.max() < cfg.vocab

    def test_apply_plan_strict_on_missing_group(self, llama):
        cfg, params = llama
        plan = engine.synthetic_plan(cfg, params, bits=4)
        grp = sorted(plan.channel_bits)[0]
        del plan.channel_bits[grp]
        with pytest.raises(KeyError):
            engine.apply_plan(cfg, params, plan)
        qparams = engine.apply_plan(cfg, params, plan, strict=False)
        assert isinstance(qparams["blocks"], tuple)


# ---------------------------------------------------------------------------
# PeriodicEval assignment caching
# ---------------------------------------------------------------------------

class TestPeriodicEvalCache:
    def test_unchanged_gammas_discretize_once(self, monkeypatch):
        from repro import api
        from repro.api import phases as phases_mod
        from repro.core import discretize
        from repro.data import synthetic
        from repro.models import cnn

        g = cnn.dscnn(width=8)
        state = phases_mod.CompressionState(
            graph=g, spec=synthetic.GSC_LIKE, pw=(0, 2, 4, 8), px=(8,),
            batch=8, seed=0)
        state.folded = cnn.fold_batchnorm(
            g, cnn.init_params(g, jax.random.key(0)))
        js = api.JointSearch(steps=1)
        ts = js.init_train_state(state)

        calls = {"n": 0}
        real = discretize.assign

        def counting(*a, **k):
            calls["n"] += 1
            return real(*a, **k)

        monkeypatch.setattr(discretize, "assign", counting)
        pe = api.PeriodicEval(every=1, n_batches=1)
        r1 = pe.on_step(js, state, 0, {}, ts)
        r2 = pe.on_step(js, state, 1, {}, ts)
        assert calls["n"] == 1                  # second eval hit the cache
        assert len(state.metrics[js.name]) == 2
        # changed gammas invalidate the fingerprint
        ts["sp"]["mps"]["gamma"] = {
            k: v + 1.0 for k, v in ts["sp"]["mps"]["gamma"].items()}
        pe.on_step(js, state, 2, {}, ts)
        assert calls["n"] == 2
