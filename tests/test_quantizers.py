"""Unit + property tests for the quantizers (paper Eq. 1 variants)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantizers as q

import proptest as pt


class TestSymmetricWeights:
    @pt.given(w=pt.arrays(pt.shapes(max_rank=2, min_dim=2, max_dim=48)),
              bits=pt.sampled_from([2, 4, 8]))
    def test_range_and_grid(self, w, bits):
        w = jnp.asarray(w)
        if w.ndim == 1:
            w = w[None, :]
        out = q.quantize_weights_symmetric(w, bits, 0)
        # quantized values never exceed the per-channel absmax
        absmax = jnp.max(jnp.abs(w), axis=1, keepdims=True)
        assert bool(jnp.all(jnp.abs(out) <= absmax + 1e-6))
        # values lie on the integer grid: out / scale is integral
        qmax = 2 ** (bits - 1) - 1
        scale = jnp.maximum(absmax, 1e-8) / qmax
        ratio = out / scale
        assert np.allclose(ratio, jnp.round(ratio), atol=1e-3)

    def test_zero_bits_prunes(self):
        w = jnp.ones((4, 7))
        assert bool(jnp.all(q.quantize_weights_symmetric(w, 0) == 0))

    def test_8bit_small_error(self):
        w = jax.random.normal(jax.random.key(0), (16, 64))
        out = q.quantize_weights_symmetric(w, 8, 0)
        scale = jnp.max(jnp.abs(w), axis=1, keepdims=True) / 127.0
        assert bool(jnp.all(jnp.abs(out - w) <= scale / 2 + 1e-7))

    def test_monotone_error_in_bits(self):
        w = jax.random.normal(jax.random.key(1), (8, 128))
        errs = [float(jnp.mean(jnp.abs(
            q.quantize_weights_symmetric(w, b, 0) - w)))
            for b in (2, 4, 8)]
        assert errs[0] > errs[1] > errs[2]

    def test_ste_gradient_identity(self):
        w = jax.random.normal(jax.random.key(2), (4, 8))
        g = jax.grad(lambda x: jnp.sum(
            q.quantize_weights_symmetric(x, 4, 0)))(w)
        # STE: gradient ~1 inside the clip range; elements exactly on the
        # boundary (each row's absmax) get the clip's split gradient 0.5
        assert bool(jnp.all((g == 1.0) | (g == 0.5)))
        assert float(jnp.mean(g)) > 0.85

    def test_channel_axis(self):
        w = jax.random.normal(jax.random.key(3), (6, 10))
        a = q.quantize_weights_symmetric(w, 4, 0)
        b = q.quantize_weights_symmetric(w.T, 4, 1).T
        assert np.allclose(a, b, atol=1e-6)


class TestPACT:
    @pt.given(alpha=pt.floats(0.5, 8.0), bits=pt.sampled_from([2, 4, 8]))
    def test_clip_and_levels(self, alpha, bits):
        x = jnp.linspace(-2.0, 12.0, 97)
        out = q.pact_quantize(x, jnp.asarray(alpha), bits)
        assert float(jnp.min(out)) >= 0.0
        assert float(jnp.max(out)) <= alpha + 1e-5
        levels = jnp.unique(jnp.round(out / (alpha / (2 ** bits - 1))))
        assert levels.shape[0] <= 2 ** bits

    def test_alpha_gradient_flows(self):
        x = jnp.asarray([0.5, 5.0, 10.0])
        g = jax.grad(lambda a: jnp.sum(q.pact_quantize(x, a, 8)))(
            jnp.asarray(2.0))
        # gradient w.r.t. alpha comes from the clipped region (x > alpha)
        assert float(g) > 0.5


class TestIntegerize:
    @pt.given(bits=pt.sampled_from([2, 4, 8]))
    def test_roundtrip_matches_fake_quant(self, bits):
        w = jax.random.normal(jax.random.key(5), (12, 33))
        qi, scale = q.integerize_weights(w, bits, 0)
        assert qi.dtype == jnp.int8
        recon = qi.astype(jnp.float32) * scale
        fake = q.quantize_weights_symmetric(w, bits, 0)
        assert np.allclose(recon, fake, atol=1e-6)
        assert int(jnp.max(jnp.abs(qi.astype(jnp.int32)))) <= \
            2 ** (bits - 1) - 1
