"""Paged-attention prefill kernel tests (the decode matrix of
test_paged_attention.py, re-run for the q-chunked prefill kernel).

The contract under test (see src/repro/kernels/README.md):
  * prefill.py's kernel (interpret mode) is bitwise identical to
    paged_prefill_ref under jit -- same per-(q-chunk, page) dots, same
    online-softmax update order -- and bitwise independent of the
    q-chunk width (each output row is an independent reduction);
  * paged_prefill_view (the off-TPU production path) is bitwise
    identical to blocks.flash_attention over the gathered dense rows
    whenever the gathered view is shape-matched to the dense input
    (q length == table_width * page_size) -- the prefill analogue of
    the decode PR 3 invariant;
  * null / never-written pages are skipped, not masked-after-read: a
    NaN-poisoned null page cannot reach any output row;
  * the result depends only on the LOGICAL pool content -- physical
    page permutations, garbage beyond a slot's live length, and freed
    mid-batch slots do not change live rows' outputs.  Rows at or
    beyond a slot's ``lens`` are discarded padding and carry no
    guarantees beyond finiteness.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import ops as pops
from repro.kernels.paged_attention import prefill as pf
from repro.nn import blocks

import proptest as pt


def make_case(rng, lens, *, s=None, h=4, hkv=2, hd=16, ps=8, n_pb=4,
              n_pages=None, poison_null=False, poison_tail=None):
    """Pool + block tables for slots holding `lens` prompt tokens each,
    plus a (B, S) query batch (S covers the longest prompt, padded to a
    PREFILL_Q boundary unless given).  Physical pages are drawn from a
    random permutation (logical order != physical order); zero-length
    slots get an all-null table row.  ``poison_tail`` overwrites every
    allocated page position BEYOND the slot's live length."""
    b = len(lens)
    if s is None:
        s = -(-max(max(lens), 1) // pops.PREFILL_Q) * pops.PREFILL_Q
    if n_pages is None:
        n_pages = b * n_pb
    pool_k = rng.normal(size=(n_pages + 1, ps, hkv, hd)).astype(np.float32)
    pool_v = rng.normal(size=(n_pages + 1, ps, hkv, hd)).astype(np.float32)
    if poison_null:
        pool_k[0] = np.nan
        pool_v[0] = np.nan
    tables = np.zeros((b, n_pb), np.int32)
    perm = rng.permutation(np.arange(1, n_pages + 1))
    idx = 0
    for bi, n in enumerate(lens):
        npg = -(-n // ps)
        for p in range(npg):
            tables[bi, p] = perm[idx]
            idx += 1
        if poison_tail is not None and npg:
            last = tables[bi, npg - 1]
            off = n - (npg - 1) * ps
            pool_k[last, off:] = poison_tail
            pool_v[last, off:] = poison_tail
    q = rng.normal(size=(b, s, h, hd)).astype(np.float32)
    return (jnp.asarray(q), jnp.asarray(pool_k), jnp.asarray(pool_v),
            jnp.asarray(tables), jnp.asarray(lens, dtype=jnp.int32))


def run(impl, case, **kw):
    qc = pops.prefill_q_chunk(int(case[0].shape[1]))
    fns = {"kernel": functools.partial(pf.paged_prefill_fwd,
                                       interpret=True, q_chunk=qc),
           "ref": functools.partial(pf.paged_prefill_ref, q_chunk=qc),
           "view": pf.paged_prefill_view}
    return np.asarray(jax.jit(functools.partial(fns[impl], **kw))(*case))


def _real_rows(out_a, out_b, lens):
    for bi, n in enumerate(lens):
        yield out_a[bi, :n], out_b[bi, :n]


class TestKernelVsRef:
    """prefill.py (interpret) must be bitwise equal to the mirror ref."""

    @pytest.mark.parametrize("hkv", [1, 2, 4])
    def test_gqa_group_sizes(self, hkv):
        rng = np.random.default_rng(hkv)
        case = make_case(rng, (5, 17, 0), hkv=hkv, poison_null=True)
        np.testing.assert_array_equal(run("kernel", case),
                                      run("ref", case))

    @pytest.mark.parametrize("window,chunked,cap", [
        (0, False, 0.0), (6, False, 0.0), (8, True, 0.0),
        (0, False, 30.0), (3, False, 50.0)])
    def test_mask_variants(self, window, chunked, cap):
        rng = np.random.default_rng(0)
        case = make_case(rng, (5, 17, 31), poison_null=True)
        kw = dict(window=window, chunked=chunked, cap=cap)
        np.testing.assert_array_equal(run("kernel", case, **kw),
                                      run("ref", case, **kw))

    @pytest.mark.parametrize("q_chunk", [1, 2, 4, 8, 16])
    def test_q_chunk_width_invariance(self, q_chunk):
        """Every output row is an independent online-softmax reduction,
        so the tile width must not change a single bit."""
        rng = np.random.default_rng(9)
        case = make_case(rng, (5, 17, 31), poison_null=True)
        np.testing.assert_array_equal(
            run("kernel", case, q_chunk=q_chunk),
            run("ref", case, q_chunk=16))

    @pt.given(seed=pt.integers(0, 10**6))
    def test_property_random_layouts(self, seed):
        """Random slot counts, prompt lengths, page sizes, GQA group
        sizes and physical page permutations: kernel == ref bitwise
        (NaN-poisoned null page), finite everywhere, both ~= the
        gathered view on real rows."""
        rng = np.random.default_rng(seed)
        ps = int(rng.choice([1, 2, 4, 8]))
        n_pb = int(rng.integers(1, 5))
        max_len = ps * n_pb
        b = int(rng.integers(1, 4))
        lens = tuple(int(rng.integers(0, max_len + 1)) for _ in range(b))
        hkv = int(rng.choice([1, 2]))
        q, pool_k, pool_v, tables, lens_a = make_case(
            rng, lens, s=max_len, hkv=hkv, ps=ps, n_pb=n_pb)
        poisoned = (q, pool_k.at[0].set(jnp.nan),
                    pool_v.at[0].set(jnp.nan), tables, lens_a)
        out_k = run("kernel", poisoned)
        out_r = run("ref", poisoned)
        np.testing.assert_array_equal(out_k, out_r)
        assert np.isfinite(out_k).all()
        out_v = run("view", (q, pool_k, pool_v, tables, lens_a))
        for a, v in _real_rows(out_k, out_v, lens):
            np.testing.assert_allclose(a, v, rtol=2e-5, atol=2e-5)


class TestPoolSemantics:
    def test_view_bitwise_matches_dense_flash_attention(self):
        """Gathering the pages into logical order and running the dense
        flash-attention op sequence must equal blocks.flash_attention on
        the equivalent dense rows bit-for-bit when the gathered length
        matches the query length (the prefill PR 3 invariant; the
        serving parity matrix covers the padded general case at token
        granularity)."""
        rng = np.random.default_rng(1)
        for s in (16, 32, 48):
            ps, hkv, hd = 8, 2, 16
            n_pb = s // ps
            lens = (s, max(s - 7, 1), max(s - 19, 1))
            q, pool_k, pool_v, tables, lens_a = make_case(
                rng, lens, s=s, hkv=hkv, hd=hd, ps=ps, n_pb=n_pb,
                n_pages=3 * n_pb)
            ck = np.asarray(pool_k)[np.asarray(tables)].reshape(
                len(lens), -1, hkv, hd)
            cv = np.asarray(pool_v)[np.asarray(tables)].reshape(
                len(lens), -1, hkv, hd)
            dense = jax.jit(functools.partial(
                blocks.flash_attention, causal=True))(
                q, jnp.asarray(ck), jnp.asarray(cv))
            view = jax.jit(pf.paged_prefill_view)(
                q, pool_k, pool_v, tables, lens_a)
            np.testing.assert_array_equal(np.asarray(dense),
                                          np.asarray(view))

    def test_partial_last_page_garbage_is_ignored(self):
        """Real rows never see allocated-page positions at or beyond
        the slot's length (the causal mask excludes them), so garbage
        there cannot change them in ANY implementation."""
        lens = (5, 13)
        clean = make_case(np.random.default_rng(2), lens)
        dirty = make_case(np.random.default_rng(2), lens,
                          poison_tail=1e9)
        for impl in ("kernel", "ref", "view"):
            for a, b in _real_rows(run(impl, clean), run(impl, dirty),
                                   lens):
                np.testing.assert_array_equal(a, b)

    def test_null_page_is_skipped_not_masked(self):
        """NaN in the reserved null page must be unreachable: dead pages
        are skipped before any arithmetic (0 * NaN would still be NaN,
        so masking-after-read could not pass this)."""
        lens = (5, 17, 0)
        clean = make_case(np.random.default_rng(3), lens)
        poisoned = make_case(np.random.default_rng(3), lens,
                             poison_null=True)
        for impl in ("kernel", "ref"):
            out = run(impl, poisoned)
            assert np.isfinite(out).all()
            np.testing.assert_array_equal(out, run(impl, clean))

    def test_freed_slot_mid_batch(self):
        """Zeroing one slot's table row (free/preempt between requests)
        gives that slot finite all-zero rows and leaves the other
        slots bitwise untouched."""
        lens = (9, 20, 7)
        q, pk_, pv_, tables, lens_a = make_case(np.random.default_rng(4),
                                                lens, poison_null=True)
        freed_np = np.asarray(tables).copy()
        freed_np[1] = 0
        freed = jnp.asarray(freed_np)
        lens_freed = jnp.asarray([9, 0, 7], jnp.int32)
        for impl in ("kernel", "ref"):
            before = run(impl, (q, pk_, pv_, tables, lens_a))
            after = run(impl, (q, pk_, pv_, freed, lens_freed))
            np.testing.assert_array_equal(after[0], before[0])
            np.testing.assert_array_equal(after[2], before[2])
            np.testing.assert_array_equal(
                after[1], np.zeros_like(after[1]))

    def test_physical_permutation_invariance(self):
        """Two pools holding the same logical KV under different
        physical page layouts produce identical outputs."""
        rng = np.random.default_rng(5)
        lens = (9, 20)
        ps, n_pb, hkv, hd = 4, 8, 2, 16
        q, pk_a, pv_a, tables_a, lens_a = make_case(
            rng, lens, ps=ps, n_pb=n_pb, hkv=hkv, hd=hd)
        n_pages = pk_a.shape[0] - 1
        relayout = np.random.default_rng(6).permutation(
            np.arange(1, n_pages + 1))
        remap = np.zeros(n_pages + 1, np.int64)
        remap[1:] = relayout
        pk_b = np.zeros_like(np.asarray(pk_a))
        pv_b = np.zeros_like(np.asarray(pv_a))
        pk_b[remap[1:]] = np.asarray(pk_a)[1:]
        pv_b[remap[1:]] = np.asarray(pv_a)[1:]
        tables_b = remap[np.asarray(tables_a)].astype(np.int32)
        tables_b[np.asarray(tables_a) == 0] = 0
        case_b = (q, jnp.asarray(pk_b), jnp.asarray(pv_b),
                  jnp.asarray(tables_b), lens_a)
        for impl in ("kernel", "ref", "view"):
            for a, b in _real_rows(
                    run(impl, (q, pk_a, pv_a, tables_a, lens_a)),
                    run(impl, case_b), lens):
                np.testing.assert_array_equal(a, b)


class TestDispatch:
    def test_prefill_q_chunk(self):
        assert pops.prefill_q_chunk(16) == 16
        assert pops.prefill_q_chunk(48) == 16
        assert pops.prefill_q_chunk(24) == 8
        assert pops.prefill_q_chunk(21) == 1

    def test_force_impl_pins_prefill_entry_point(self):
        case = make_case(np.random.default_rng(6), (6, 11))
        with pops.force_impl("ref"):
            pinned = np.asarray(jax.jit(pops.paged_prefill_attention)(
                *case))
        np.testing.assert_array_equal(pinned, run("ref", case))

    def test_ops_entry_point_all_impls_agree(self):
        lens = (6, 11)
        case = make_case(np.random.default_rng(7), lens)
        outs = {impl: np.asarray(jax.jit(functools.partial(
            pops.paged_prefill_attention, impl=impl))(*case))
            for impl in ("kernel", "ref", "view")}
        np.testing.assert_array_equal(outs["kernel"], outs["ref"])
        for a, b in _real_rows(outs["kernel"], outs["view"], lens):
            np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)
