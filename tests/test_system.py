"""End-to-end system tests: the paper's 3-phase recipe on the composable
Compressor API, quantized mixed-precision serving (Fig. 3 path), and the
LM serve engine. The deprecated ``run_pipeline`` shim gets a smoke test."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs import registry
from repro.core import pipeline
from repro.data import synthetic
from repro.models import cnn, lm
from repro.serve import engine


@pytest.fixture(scope="module")
def tiny_pipeline_result():
    g = cnn.resnet9(width=8)
    comp = api.Compressor(g, synthetic.CIFAR10_LIKE, pw=(0, 2, 4, 8),
                          px=(8,), batch=32, seed=0)
    res = comp.run([api.Warmup(steps=120),
                    api.JointSearch(steps=120, lam=10.0),
                    api.Finetune(steps=60)])
    return g, res


class TestPipeline:
    def test_accuracy_learns_and_survives_quantization(
            self, tiny_pipeline_result):
        _, res = tiny_pipeline_result
        assert res.acc_float > 0.55             # learnable synthetic task
        assert res.acc_final > res.acc_float - 0.1

    def test_size_reduced_vs_w8(self, tiny_pipeline_result):
        g, res = tiny_pipeline_result
        params = cnn.init_params(g, jax.random.key(0))
        w8_bytes = sum(int(np.prod(p["w"].shape)) for p in params.values())
        assert res.size_bytes < w8_bytes        # beats uniform 8-bit

    def test_higher_lambda_smaller_model(self):
        g = cnn.dscnn(width=8)
        comp = api.Compressor(g, synthetic.GSC_LIKE, batch=32)
        sizes = []
        for lam in (1.0, 25.0):
            res = comp.run([api.Warmup(steps=40),
                            api.JointSearch(steps=80, lam=lam),
                            api.Finetune(steps=10)])
            sizes.append(res.size_bytes)
        assert sizes[1] < sizes[0]

    def test_bits_histogram_valid(self, tiny_pipeline_result):
        _, res = tiny_pipeline_result
        for grp, h in res.bits_histogram.items():
            assert abs(sum(h.values()) - 1) < 1e-6

    def test_plan_is_the_result_artifact(self, tiny_pipeline_result):
        g, res = tiny_pipeline_result
        plan = res.plan
        assert isinstance(plan, api.CompressionPlan)
        assert plan.meta["cost_model"] == "size"
        geoms = cnn.cost_geoms(g)
        assert plan.size_bytes(geoms) == res.size_bytes
        for grp, bits in plan.channel_bits.items():
            assert set(np.unique(bits)) <= {0, 2, 4, 8}
            assert sorted(plan.permutations[grp]) == list(range(len(bits)))

    def test_run_pipeline_shim_matches_legacy_shape(self):
        g = cnn.dscnn(width=8)
        cfg = pipeline.SearchConfig(warmup_steps=4, search_steps=4,
                                    finetune_steps=2, batch=8)
        with pytest.deprecated_call():
            res = pipeline.run_pipeline(g, synthetic.GSC_LIKE, cfg)
        assert set(res) >= {"acc_float", "acc_final", "size_bytes",
                            "prune_fraction", "bits_histogram",
                            "assignment", "net", "timings", "total_s"}
        assert set(res["assignment"]) == {"gamma", "delta", "alpha"}
        assert {"warmup_s", "search_s", "finetune_s"} <= set(res["timings"])


class TestQuantizedServing:
    def test_mixed_precision_layer_matches_fakequant(self):
        """Fig. 3 export: reorder + pack + per-precision matmuls must match
        the discretized fake-quant layer up to activation-quant error."""
        rng = np.random.default_rng(0)
        w = rng.normal(size=(48, 64)).astype(np.float32) * 0.2
        bits = rng.choice([0, 2, 4, 8], size=48, p=[0.2, 0.2, 0.3, 0.3])
        x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
        packed, perm, kept = engine.export_mixed_precision_layer(w, bits)
        y = engine.mixed_precision_matmul(x, packed)
        assert y.shape == (8, kept)
        # reference: per-channel fake-quant then matmul, reordered
        from repro.core import quantizers
        w_sorted = w[perm]
        bits_sorted = bits[perm]
        cols = []
        for i in range(48):
            b = int(bits_sorted[i])
            if b == 0:
                continue
            wq = quantizers.quantize_weights_symmetric(
                jnp.asarray(w_sorted[i:i + 1]), b, 0)
            cols.append(np.asarray(x @ wq.T))
        ref = np.concatenate(cols, axis=1)
        rel = np.linalg.norm(np.asarray(y) - ref) / np.linalg.norm(ref)
        assert rel < 0.02   # int8 activation quantization error only

    def test_pruned_channels_dropped(self):
        w = np.ones((16, 32), np.float32)
        bits = np.zeros(16, np.int64)
        bits[:4] = 8
        packed, perm, kept = engine.export_mixed_precision_layer(w, bits)
        assert kept == 4
        assert sum(p[1].shape[0] for p in packed) == 4


class TestServeEngine:
    def test_greedy_generation_deterministic(self):
        cfg = registry.reduced(registry.ARCHS["llama3.2-1b"])
        params = lm.init_params(cfg, jax.random.key(0))
        eng = engine.ServeEngine(cfg, params, max_len=32)
        prompts = np.asarray([[1, 2, 3], [4, 5, 6]], np.int32)
        out1 = eng.generate(prompts, n_tokens=4)
        out2 = eng.generate(prompts, n_tokens=4)
        np.testing.assert_array_equal(out1, out2)
        assert out1.shape == (2, 4)
        assert out1.max() < cfg.vocab
